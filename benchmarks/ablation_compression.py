"""Beyond-paper ablation: the compression ratio r drives the payload
s = r·d·p and therefore the whole communication/learning tradeoff of 𝒫₁.

Part 1 sweeps r through the solver alone and reports the optimal
(B*, T, E) — showing where the system flips from communication-bound to
compute-bound.  Part 2 is the *trained* ablation on the declarative API:
one ``grid(base, compression=[...], compress=[True, False])`` study —
compression-on cells split buckets (the top-k fraction is compiled in),
the whole compression-off column shares ONE bucket (ratio only moves the
planned payload there) — run under ``AsyncExecutor`` so bucket planning
overlaps device execution.  Part 3 is the tau>1 multiple-local-updates
extension (paper §VII future work) as a ``local_steps`` grid axis."""
from __future__ import annotations

import numpy as np

from repro.api import AsyncExecutor, Experiment, ScenarioSpec, grid
from repro.channels.model import Cell
from repro.core import DeviceProfile, gradient_bits, solve_period
from repro.data.pipeline import ClassificationData


def main(fast: bool = True):
    devs = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7, 0.7, 1.4, 1.4, 2.1, 2.1])
    cell = Cell.make(0)
    _, up, down = cell.sample_rates(6)
    rows = []
    for r in [0.001, 0.005, 0.02, 0.1, 1.0]:
        s = gradient_bits(7_000_000, compression=r)
        sol = solve_period(devs, up, down, s, 0.010, 0.010, xi=0.05,
                           b_max=128)
        rows.append((f"ablation_r/{r}", 0.0,
                     f"B={sol.global_batch:.0f};T={sol.latency:.3f}s;"
                     f"E={sol.efficiency:.4f}"))

    # trained compression grid (one line of axes; buckets: one per
    # compression-on ratio + one shared compression-off bucket)
    full = ClassificationData.synthetic(n=1800, dim=128, seed=0, spread=6.0)
    data, test = full.split(300)
    periods = 40 if fast else 200
    base = ScenarioSpec(fleet=devs, name="ablation", partition="iid",
                        b_max=64, base_lr=0.1, seeds=(0,))
    ratios = [0.005, 0.1] if fast else [0.001, 0.005, 0.02, 0.1]
    study = grid(base, compression=ratios, compress=[True, False])
    res = Experiment(data, test, study).run(periods,
                                            executor=AsyncExecutor())
    for r in ratios:
        for on in (True, False):
            c = res.sel(compression=r, compress=on)
            rows.append((f"ablation_train_r/{r}/{'on' if on else 'off'}",
                         float(c.times[0, -1]) * 1e6,
                         f"acc={float(c.final_acc[0]):.4f};"
                         f"simT={float(c.times[0, -1]):.1f}s"))

    # tau > 1 local updates (paper §VII) — local_steps splits buckets,
    # AsyncExecutor pipelines them
    taus = [1, 4] if fast else [1, 2, 4, 8]
    res_tau = Experiment(data, test, grid(base, local_steps=taus)).run(
        periods, executor=AsyncExecutor())
    for tau in taus:
        c = res_tau.sel(local_steps=tau)
        rows.append((f"ablation_tau/{tau}", float(c.times[0, -1]) * 1e6,
                     f"acc={float(c.final_acc[0]):.4f};"
                     f"simT={float(c.times[0, -1]):.1f}s"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))

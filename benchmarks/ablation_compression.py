"""Beyond-paper ablation: the compression ratio r drives the payload
s = r·d·p and therefore the whole communication/learning tradeoff of 𝒫₁.
Sweeps r and reports the solver's optimal (B*, T, E) — showing where the
system flips from communication-bound to compute-bound, plus the tau>1
multiple-local-updates extension (paper §VII future work)."""
from __future__ import annotations

import numpy as np

from repro.channels.model import Cell
from repro.core import DeviceProfile, gradient_bits, solve_period
from repro.data.pipeline import ClassificationData
from repro.fed.trainer import FeelSimulation


def main(fast: bool = True):
    devs = [DeviceProfile(kind="cpu", f_cpu=f * 1e9)
            for f in [0.7, 0.7, 1.4, 1.4, 2.1, 2.1]]
    cell = Cell.make(0)
    _, up, down = cell.sample_rates(6)
    rows = []
    for r in [0.001, 0.005, 0.02, 0.1, 1.0]:
        s = gradient_bits(7_000_000, compression=r)
        sol = solve_period(devs, up, down, s, 0.010, 0.010, xi=0.05,
                           b_max=128)
        rows.append((f"ablation_r/{r}", 0.0,
                     f"B={sol.global_batch:.0f};T={sol.latency:.3f}s;"
                     f"E={sol.efficiency:.4f}"))

    # tau > 1 local updates (paper §VII)
    full = ClassificationData.synthetic(n=1800, dim=128, seed=0, spread=6.0)
    data, test = full.split(300)
    for tau in ([1, 4] if fast else [1, 2, 4, 8]):
        sim = FeelSimulation(devs, data, test, partition="iid", b_max=64,
                             base_lr=0.1, local_steps=tau)
        res = sim.run(40 if fast else 200, eval_every=20)
        rows.append((f"ablation_tau/{tau}", res.times[-1] * 1e6,
                     f"acc={res.accs[-1]:.4f};simT={res.times[-1]:.1f}s"))
    return rows


if __name__ == "__main__":
    for row in main():
        print(",".join(map(str, row)))

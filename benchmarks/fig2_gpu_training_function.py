"""Fig. 2 / Assumption 1 — the GPU training function, adapted to TPU.

The paper measures per-batch training latency on GTX-1080Ti and fits the
piecewise-linear t(B) = max(t_ℓ, c·(B−B_th)+t_ℓ).  On TPU we derive the
same curve from the roofline: per-step latency = max(memory-bound floor,
compute term), using the analytic FLOPs/bytes of one fwd+bwd step of a
reduced transformer.  The data-bound region = memory/overhead-bound floor
(B too small to fill the MXU); compute-bound = FLOPs-linear region.
We then fit (t_ℓ, c, B_th) by least squares and report R² — validating
that Assumption 1 transfers to TPU (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def tpu_step_latency(n_params: float, batch: np.ndarray, seq: int,
                     d_model: int) -> np.ndarray:
    """Roofline latency of one training step vs batch."""
    flops = 6.0 * n_params * batch * seq
    # bytes: params + grads + opt state traffic (B-independent) +
    # activations (B-linear, ~14*L*S*d ≈ use 20x params-equivalent scaling)
    fixed_bytes = 3 * 2 * n_params          # read params/grads, write upd
    act_bytes = 40.0 * batch * seq * d_model * 2
    t_compute = flops / PEAK_FLOPS
    t_memory = (fixed_bytes + act_bytes) / HBM_BW
    return np.maximum(t_compute, t_memory)


def fit_training_function(batch: np.ndarray, lat: np.ndarray):
    """Least-squares fit of the paper's (t_ℓ, c, B_th) over candidate
    breakpoints."""
    best = None
    for bth in batch[1:-1]:
        flat = lat[batch <= bth]
        t_l = float(flat.mean())
        hi = batch > bth
        if hi.sum() < 2:
            continue
        A = np.vstack([batch[hi] - bth, np.ones(hi.sum())]).T
        coef, *_ = np.linalg.lstsq(A, lat[hi], rcond=None)
        c = float(coef[0])
        pred = np.where(batch <= bth, t_l, c * (batch - bth) + coef[1])
        sse = float(np.sum((pred - lat) ** 2))
        if best is None or sse < best[0]:
            best = (sse, t_l, c, int(bth))
    sse, t_l, c, bth = best
    sst = float(np.sum((lat - lat.mean()) ** 2))
    r2 = 1 - sse / max(sst, 1e-30)
    return {"t_low": t_l, "slope": c, "b_th": bth, "r2": r2}


def main(fast: bool = True):
    rows = []
    for arch in ["qwen1.5-4b", "mistral-nemo-12b", "granite-34b"]:
        cfg = get_arch(arch)
        n = cfg.param_count()
        batch = np.arange(1, 129)
        lat = tpu_step_latency(n, batch, seq=512, d_model=cfg.d_model)
        fit = fit_training_function(batch, lat)
        rows.append((f"fig2_gpu_fn/{arch}", fit["t_low"] * 1e6,
                     f"B_th={fit['b_th']};slope={fit['slope']:.2e};"
                     f"R2={fit['r2']:.4f}"))
        assert fit["r2"] > 0.95, "Assumption 1 should fit the TPU roofline"
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

"""Fig. 3 — generalization across model classes and learning rates:
the proposed scheme converges for every (model × lr) combination.
DenseNet/ResNet/MobileNet are stood in by three MLP capacities.

Declarative-API driver: the (model × lr) plane is ONE ``grid`` study —
``model`` is a labeled axis bundling (hidden, depth), so each capacity
lowers to its own shape bucket and ``base_lr`` rides along inside it —
run under ``AsyncExecutor`` so each model's host planning overlaps the
previous model's device execution."""
from __future__ import annotations

import numpy as np

from repro.api import AsyncExecutor, Experiment, ScenarioSpec, grid
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData

MODELS = {"densenet_stand_in": dict(hidden=512, depth=4),
          "resnet_stand_in": dict(hidden=256, depth=3),
          "mobilenet_stand_in": dict(hidden=128, depth=2)}


def main(fast: bool = True):
    periods = 80 if fast else 2000
    devs = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7] * 4 + [1.4] * 4 + [2.1] * 4)
    full = ClassificationData.synthetic(n=2600, dim=128, seed=0, spread=6.0)
    data, test = full.split(400)
    base = ScenarioSpec(fleet=devs, name="fig3", partition="noniid",
                        policy="proposed", b_max=64, seeds=(0,))
    study = grid(base, model=MODELS, base_lr=[0.1, 0.05])
    res = Experiment(data, test, study).run(periods,
                                            executor=AsyncExecutor())
    assert res.n_buckets == len(MODELS)           # one bucket per capacity
    rows = []
    for mname in MODELS:
        for lr in [0.1, 0.05]:
            c = res.sel(model=mname, base_lr=lr)
            losses, accs = c.losses[0], c.accs[0]
            converged = losses[-1] < losses[0] * 0.8
            rows.append((f"fig3/{mname}/lr{lr}",
                         float(c.times[0, -1]) * 1e6,
                         f"acc={float(accs[-1]):.4f};"
                         f"loss={float(losses[-1]):.4f};"
                         f"converged={converged}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

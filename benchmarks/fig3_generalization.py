"""Fig. 3 — generalization across model classes and learning rates:
the proposed scheme converges for every (model × lr) combination.
DenseNet/ResNet/MobileNet are stood in by three MLP capacities."""
from __future__ import annotations

import numpy as np

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.trainer import FeelSimulation


def main(fast: bool = True):
    periods = 80 if fast else 2000
    devs = [DeviceProfile(kind="cpu", f_cpu=f * 1e9)
            for f in [0.7] * 4 + [1.4] * 4 + [2.1] * 4]
    full = ClassificationData.synthetic(n=2600, dim=128, seed=0, spread=6.0)
    data, test = full.split(400)
    models = {"densenet_stand_in": (512, 4), "resnet_stand_in": (256, 3),
              "mobilenet_stand_in": (128, 2)}
    rows = []
    for mname, (hidden, depth) in models.items():
        for lr in [0.1, 0.05]:
            sim = FeelSimulation(devs, data, test, partition="noniid",
                                 policy="proposed", b_max=64, base_lr=lr,
                                 hidden=hidden, depth=depth)
            r = sim.run(periods, eval_every=periods // 4)
            converged = r.losses[-1] < r.losses[0] * 0.8
            rows.append((f"fig3/{mname}/lr{lr}", r.times[-1] * 1e6,
                         f"acc={r.accs[-1]:.4f};loss={r.losses[-1]:.4f};"
                         f"converged={converged}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

"""Figs. 4-5 — GPU scenario: proposed joint policy vs online (B=1),
full (B=Bmax), random batchsize, on loss/accuracy vs simulated time,
IID and non-IID — driven by the batched sweep API (one vmapped
``lax.scan`` per policy×partition cell, seeds batched on device)."""
from __future__ import annotations

import numpy as np

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.sweep import run_sweep


def gpu_fleet(k=6):
    return [DeviceProfile(kind="gpu", gpu_t_low=0.02 + 0.005 * (i % 3),
                          gpu_slope=4e-4, gpu_b_th=16) for i in range(k)]


def main(fast: bool = True):
    periods = 60 if fast else 1500
    seeds = range(2, 4) if fast else range(2, 10)
    full = ClassificationData.synthetic(n=2200, dim=128, seed=0, spread=6.0)
    data, test = full.split(300)
    results = run_sweep(
        {"gpu6": gpu_fleet()}, data, test,
        policies=("proposed", "online", "full", "random"),
        partitions=("iid", "noniid"), seeds=seeds, periods=periods,
        b_max=128, base_lr=0.15)
    rows = []
    for part in ["iid", "noniid"]:
        t60 = {}
        for pol in ["proposed", "online", "full", "random"]:
            cell = results[f"gpu6/{part}/{pol}"]
            t60[pol] = float(np.median(cell.speed(0.6)))
            rows.append((f"fig45/{part}/{pol}",
                         float(cell.times[:, -1].mean()) * 1e6,
                         f"acc={cell.final_acc.mean():.4f}"
                         f"±{cell.final_acc.std():.4f};"
                         f"loss={cell.losses[:, -1].mean():.4f};"
                         f"t60={t60[pol]:.1f}s"))
        # the proposed policy must reach the target first (paper's claim)
        best = min(t60, key=t60.get)
        rows.append((f"fig45/{part}/winner", 0.0,
                     f"first_to_60pct={best}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

"""Figs. 4-5 — GPU scenario: proposed joint policy vs online (B=1),
full (B=Bmax), random batchsize, on loss/accuracy vs simulated time,
IID and non-IID."""
from __future__ import annotations

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.trainer import FeelSimulation


def gpu_fleet(k=6):
    return [DeviceProfile(kind="gpu", gpu_t_low=0.02 + 0.005 * (i % 3),
                          gpu_slope=4e-4, gpu_b_th=16) for i in range(k)]


def main(fast: bool = True):
    periods = 60 if fast else 1500
    full = ClassificationData.synthetic(n=2200, dim=128, seed=0, spread=6.0)
    data, test = full.split(300)
    rows = []
    for part in ["iid", "noniid"]:
        results = {}
        for pol in ["proposed", "online", "full", "random"]:
            sim = FeelSimulation(gpu_fleet(), data, test, partition=part,
                                 policy=pol, b_max=128, base_lr=0.15,
                                 seed=2)
            r = sim.run(periods, eval_every=max(1, periods // 5))
            results[pol] = r
            rows.append((f"fig45/{part}/{pol}", r.times[-1] * 1e6,
                         f"acc={r.accs[-1]:.4f};loss={r.losses[-1]:.4f};"
                         f"t60={r.speed(0.6):.1f}s"))
        # the proposed policy must reach the target first (paper's claim)
        t = {k: v.speed(0.6) for k, v in results.items()}
        best = min(t, key=t.get)
        rows.append((f"fig45/{part}/winner", 0.0, f"first_to_60pct={best}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

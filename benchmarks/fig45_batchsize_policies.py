"""Figs. 4-5 — GPU scenario: proposed joint policy vs online (B=1),
full (B=Bmax), random batchsize, on loss/accuracy vs simulated time,
IID and non-IID — on the declarative API: all 8 (policy × partition)
cells are shape-compatible, so the whole figure is ONE compiled program
with the (cell × seed) grid flattened along the batch axis."""
from __future__ import annotations

import numpy as np

from repro.api import Experiment, ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData

POLICIES = ["proposed", "online", "full", "random"]


def gpu_fleet(k=6):
    return tuple(DeviceProfile(kind="gpu", gpu_t_low=0.02 + 0.005 * (i % 3),
                               gpu_slope=4e-4, gpu_b_th=16) for i in range(k))


def main(fast: bool = True):
    periods = 60 if fast else 1500
    seeds = tuple(range(2, 4)) if fast else tuple(range(2, 10))
    full = ClassificationData.synthetic(n=2200, dim=128, seed=0, spread=6.0)
    data, test = full.split(300)
    specs = [ScenarioSpec(fleet=gpu_fleet(), name="gpu6", partition=part,
                          policy=pol, b_max=128, base_lr=0.15, seeds=seeds)
             for part in ["iid", "noniid"] for pol in POLICIES]
    res = Experiment(data, test, specs).run(periods)
    assert res.n_buckets == 1                     # the whole figure: 1 program
    rows = []
    for part in ["iid", "noniid"]:
        t60 = {}
        for pol in POLICIES:
            cell = res.sel(partition=part, policy=pol)
            t60[pol] = float(np.median(cell.speed(0.6)))
            rows.append((f"fig45/{part}/{pol}",
                         float(cell.times[:, -1].mean()) * 1e6,
                         f"acc={cell.final_acc.mean():.4f}"
                         f"±{cell.final_acc.std():.4f};"
                         f"loss={cell.losses[:, -1].mean():.4f};"
                         f"t60={t60[pol]:.1f}s"))
        # the proposed policy must reach the target first (paper's claim)
        best = min(t60, key=t60.get)
        rows.append((f"fig45/{part}/winner", 0.0,
                     f"first_to_60pct={best}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

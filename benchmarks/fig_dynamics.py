"""Dynamic worlds — the value-of-feedback figure behind the scenario
dynamics subsystem (PR 9).

Three scheduler-level sweeps over the drifting/faulty/energy-bounded
worlds (host planning only — CI-cheap, no device training):

1. **Drift**: open-loop (stale first-gain belief, the paper's static
   assumption) vs closed-loop (fresh gains at every chunk boundary)
   realized-latency ledgers, swept over Markov-drift seeds and spreads.
   The headline is the mean latency ratio closed/open — re-pricing the
   TDMA airtime at realized gains recovers most of what the stale
   belief wastes, and the win grows with the drift spread.
2. **Faults**: straggler slowdowns stretch the realized ledger by
   exactly the planned-vs-realized gap (the planner allocates blind;
   the ledger pays), and dropout sheds participation at the configured
   rate.
3. **Energy**: a tight per-user budget sheds batch until every kept
   user lands under budget — reported as the shed fraction and the
   max realized spend.

Emits ``BENCH_dynamics.json``.  Run:
``PYTHONPATH=src python -m benchmarks.fig_dynamics``
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import DeviceProfile, FeelScheduler
from repro.dynamics import EnergyBudget, Fading, Faults

CHUNK = 2


def _fleet():
    """Heterogeneous CPU fleet (spread clock rates make the TDMA slot
    split a real decision, so stale gains have something to waste)."""
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in (0.7, 2.1, 1.4, 0.9))


def _sched(**kw):
    kw.setdefault("devices", _fleet())
    kw.setdefault("n_params", 4000)
    kw.setdefault("b_max", 16)
    return FeelScheduler(**kw)


def _drift_pair(seed: int, spread: float, periods: int):
    """(open, closed) realized-latency totals for one drift realization."""
    fad = Fading(states=3, spread=spread, stickiness=0.95)
    open_lat = _sched(seed=seed, fading=fad).plan_horizon(periods).latency
    sch = _sched(seed=seed, fading=fad)
    closed_lat = np.concatenate(
        [sch.plan_horizon(CHUNK, warm_start=(i > 0), closed_loop=True)
         .latency for i in range(periods // CHUNK)])
    return float(open_lat.sum()), float(closed_lat.sum())


def main(fast: bool = True):
    periods = 8 if fast else 16
    seeds = range(6 if fast else 24)

    drift = {}
    for spread in (0.6, 1.2):
        pairs = [_drift_pair(s, spread, periods) for s in seeds]
        ratios = [c / o for o, c in pairs]
        drift[f"spread{spread}"] = {
            "open_s": [o for o, _ in pairs],
            "closed_s": [c for _, c in pairs],
            "mean_ratio_closed_over_open": float(np.mean(ratios)),
            "win_fraction": float(np.mean([r < 1.0 for r in ratios])),
        }

    base = _sched(seed=0).plan_horizon(periods)
    slow = _sched(seed=0, faults=Faults(slow_prob=0.5, slow_factor=4.0)) \
        .plan_horizon(periods)
    drop = _sched(seed=0, faults=Faults(drop_prob=0.3)).plan_horizon(periods)
    faults = {
        "latency_stretch": float(slow.latency.sum() / base.latency.sum()),
        "dropout_keep_rate": float(drop.participation.mean()),
    }

    budget = 0.35
    shed = _sched(seed=0, energy=EnergyBudget(budget_j=budget)) \
        .plan_horizon(periods)
    kept = shed.participation > 0.5
    energy = {
        "budget_j": budget,
        "shed_fraction": float(1.0 - shed.batch.sum() / base.batch.sum()),
        "dropped_fraction": float(1.0 - kept.mean()),
        "max_spend_kept_j": float(shed.energy[kept].max()),
        "under_budget": bool(np.all(shed.energy[kept] <= budget + 1e-9)),
    }

    report = {"periods": periods, "n_seeds": len(list(seeds)),
              "chunk": CHUNK, "drift": drift, "faults": faults,
              "energy": energy}
    with open("BENCH_dynamics.json", "w") as f:
        json.dump(report, f, indent=2)

    for spread, d in drift.items():
        print(f"drift {spread}: closed/open latency "
              f"{d['mean_ratio_closed_over_open']:.3f} "
              f"(wins {d['win_fraction']:.0%} of seeds)")
    print(f"faults: stretch {faults['latency_stretch']:.2f}x, "
          f"keep rate {faults['dropout_keep_rate']:.2f}")
    print(f"energy: shed {energy['shed_fraction']:.0%} of batch, "
          f"max kept spend {energy['max_spend_kept_j']:.3f} J "
          f"(budget {budget} J)")

    assert energy["under_budget"], "energy shedding exceeded the budget"
    assert faults["latency_stretch"] > 1.0, \
        "stragglers did not stretch the realized ledger"
    big = drift["spread1.2"]
    return [("fig_dynamics/drift_spread1.2",
             0.0,
             f"ratio={big['mean_ratio_closed_over_open']:.3f};"
             f"wins={big['win_fraction']:.2f};"
             f"stretch={faults['latency_stretch']:.2f}x;"
             f"shed={energy['shed_fraction']:.2f}")]


if __name__ == "__main__":
    for r in main(fast=True):
        print(",".join(map(str, r)))

"""Model families on the FEEL engine — transformer / Mamba-2 train steps
next to the MLP scan (PR 10).

Runs the ``model_family`` grid end-to-end on the device engine at
CI-cheap shapes, once cold (trace + compile included) and once warm (the
bucket program cache hit), and reports per family: the true parameter
count (what the planner prices the SBC uplink at, ``s = r·d·p``), the
cold and warm wall time per period, and the final training loss.

Emits ``BENCH_models.json``.  Run:
``PYTHONPATH=src python -m benchmarks.fig_models``
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.api import Experiment, ScenarioSpec
from repro.compression.sbc import compressed_bits
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData

FAMILIES = ("feel_mlp", "transformer", "mamba2")


def _spec(fleet, family: str) -> ScenarioSpec:
    return ScenarioSpec(fleet=fleet, name=f"bench-{family}", b_max=12,
                        base_lr=0.15, hidden=8, depth=2, seeds=(0,),
                        model_family=family)


def main(fast: bool = True):
    from repro.api.lowering import _n_params

    periods = 3 if fast else 8
    full = ClassificationData.synthetic(n=160, dim=12, seed=0, spread=6.0)
    data, test = full.split(40)
    fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                  for f in (0.7, 1.4))

    report, rows = {}, []
    for family in FAMILIES:
        spec = _spec(fleet, family)
        exp = Experiment(data, test, [spec])
        t0 = time.time()
        res = exp.run(periods=periods)
        cold_s = time.time() - t0
        t0 = time.time()
        res = Experiment(data, test, [spec]).run(periods=periods)
        warm_s = time.time() - t0

        losses = np.asarray(res.losses)
        final = float(losses.reshape(-1, periods)[0, -1])
        assert np.all(np.isfinite(losses)), f"{family}: non-finite loss"
        n_params = _n_params(spec, data.x.shape[1])
        entry = {
            "n_params": int(n_params),
            "sbc_uplink_bits": compressed_bits(n_params, spec.compression),
            "cold_s_per_period": cold_s / periods,
            "warm_s_per_period": warm_s / periods,
            "final_loss": final,
        }
        report[family] = entry
        print(f"{family}: {n_params} params, cold "
              f"{entry['cold_s_per_period']:.3f} s/period, warm "
              f"{entry['warm_s_per_period']:.3f} s/period, "
              f"final loss {final:.3f}")
        rows.append((f"fig_models/{family}",
                     f"{entry['warm_s_per_period'] * 1e6:.0f}",
                     f"params={n_params};loss={final:.3f}"))

    report["periods"] = periods
    with open("BENCH_models.json", "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in main(fast=True):
        print(",".join(map(str, r)))

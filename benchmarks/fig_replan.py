"""Open- vs closed-loop ξ re-planning — the accuracy and calibration
figure behind ``replan=``.

Two runs of the same GPU-fleet FEEL scenario (interior B*, the paper's
GPU scenario where batch economics are non-trivial):

* **open loop** — the whole horizon planned up front with the prior ξ
  (the paper's known-constant treatment; PR-1..4 behaviour);
* **closed loop** — ``replan=R``: the horizon executes as R-period
  chunks, each chunk's realized loss decays feeding the per-row ξ
  estimator before the next chunk is planned (Algorithm 1 with live
  feedback, warm-started B* grids).

Two results are reported:

1. **Accuracy at equal wall-clock** (simulated seconds).  The headline
   here is an *invariance*: Algorithm-1's decisions are ξ-scale-free
   (the fixed-B allocation depends only on ΔL·E and ΔL·μ, which the
   constraints pin jointly, and the outer argmin of T(B)/(ξ√B) drops
   ξ), so pure ξ re-estimation reproduces the open-loop trajectory and
   the closed-loop curve is ≥ the open-loop curve trivially — closed-
   loop feedback is *free*.  The realized-decay cap (the decision-
   relevant half: credit no candidate more decay than recently
   realized) only steps in when the √B extrapolation is unsupported;
   on a well-specified scenario it leaves the plan untouched.
2. **Calibration**: per-chunk predicted decay ΔL̂ = ξ̂√B against the
   realized decay.  Open loop stays at the prior forever (here a
   mis-specified ξ₀, as any fresh run is); closed loop converges onto
   the realized series — the estimator's actual job, and the reason the
   ledger's efficiency predictions become trustworthy mid-run.

Emits ``BENCH_fig_replan.json``.  Run:
``PYTHONPATH=src python -m benchmarks.fig_replan``
"""
from __future__ import annotations

import json

import numpy as np

from repro.api import Experiment, ScenarioSpec
from repro.api.lowering import BucketRun, group_rows
from repro.core import DeviceProfile

from repro.data.pipeline import ClassificationData

REPLAN = 5
PRIOR_XI = 0.05


def _fleet():
    """The paper's GPU scenario: flat-then-affine latency makes the
    optimal batchsize interior (B* well above the floor), so re-planning
    has a real decision space."""
    return tuple(DeviceProfile(kind="gpu", gpu_t_low=0.02, gpu_slope=5e-4,
                               gpu_b_th=16 + 4 * i) for i in range(4))


def _acc_at(times, accs, t):
    """Last evaluated accuracy at or before simulated second ``t``."""
    i = np.searchsorted(times, t, side="right") - 1
    return float(accs[i]) if i >= 0 else float("nan")


def _closed_loop_trace(spec, data, test, periods):
    """Drive the chunked closed loop at the lowering level, recording
    the ξ estimate at each chunk's plan time (the calibration series)."""
    bucket = group_rows([spec], replan=REPLAN)[0]
    run = BucketRun(bucket, data, test, periods, REPLAN)
    xi_at_plan = []
    while not run.done:
        if run.can_advance:
            xi_at_plan.append(
                [s.xi_est.xi for s in run._planner.schedulers])
            run.advance()
        else:
            run.collect()
    losses, accs, times, gb = run.result()
    # per-period predicted decay: the ξ in force when that chunk was
    # planned × √B of the period's plan
    xi_series = np.concatenate([
        np.repeat(np.asarray(xi)[:, None],
                  min(REPLAN, periods - i * REPLAN), axis=1)
        for i, xi in enumerate(xi_at_plan)], axis=1)
    predicted = xi_series * np.sqrt(gb)
    return (losses, accs, times, gb), predicted, run.realized_decays


def main(fast: bool = True):
    periods = 40 if fast else 100
    seeds = tuple(range(2 if fast else 6))
    full = ClassificationData.synthetic(n=800, dim=32, seed=0, spread=4.0)
    data, test = full.split(160)
    spec = ScenarioSpec(fleet=_fleet(), name="gpu4", partition="noniid",
                        policy="proposed", b_max=128, base_lr=0.1,
                        hidden=64, seeds=seeds)
    exp = Experiment(data, test, [spec])

    open_res = exp.run(periods)                       # prior ξ, one plan
    closed_res = exp.run(periods, replan=REPLAN)      # live ξ feedback

    # accuracy at equal wall-clock: sample both curves on the shared
    # simulated-time budget
    t_end = min(open_res.times[:, -1].min(), closed_res.times[:, -1].min())
    grid_t = np.linspace(0.25 * t_end, t_end, 8)
    acc_open = [float(np.mean([_acc_at(open_res.times[r], open_res.accs[r],
                                       t) for r in range(open_res.rows)]))
                for t in grid_t]
    acc_closed = [float(np.mean([_acc_at(closed_res.times[r],
                                         closed_res.accs[r], t)
                                 for r in range(closed_res.rows)]))
                  for t in grid_t]
    # ≥ with a seed-noise tolerance; the ξ-invariance makes this an
    # equality whenever the decay cap never binds
    margin = float(np.min(np.array(acc_closed) - np.array(acc_open)))

    # calibration: predicted ΔL̂ per period vs realized, one seed's trace
    one = ScenarioSpec(fleet=_fleet(), name="gpu4", partition="noniid",
                       policy="proposed", b_max=128, base_lr=0.1,
                       hidden=64, seeds=(seeds[0],))
    (_, _, _, gb_cl), predicted_cl, realized = _closed_loop_trace(
        one, data, test, periods)
    predicted_open = PRIOR_XI * np.sqrt(gb_cl)        # prior, never updated
    late = realized.shape[1] // 2                     # converged half
    scale = float(np.mean(np.abs(realized[:, late:]))) + 1e-12
    err = lambda pred: float(np.mean(                 # noqa: E731
        np.abs(pred[:, late:] - realized[:, late:]))) / scale
    cal_open, cal_closed = err(predicted_open), err(predicted_cl)

    report = {
        "periods": periods, "n_seeds": len(seeds), "replan": REPLAN,
        "prior_xi": PRIOR_XI,
        "global_batch_open": int(open_res.global_batch[0, 0]),
        "global_batch_closed": int(closed_res.global_batch[0, 0]),
        "equal_wallclock_grid_s": [float(t) for t in grid_t],
        "acc_open": acc_open, "acc_closed": acc_closed,
        "min_margin_closed_minus_open": margin,
        "closed_ge_open_at_equal_wallclock": bool(margin >= -1e-9),
        "calibration_err_open": cal_open,
        "calibration_err_closed": cal_closed,
        "calibration_gain": cal_open / max(cal_closed, 1e-12),
        "note": "Algorithm-1 decisions are xi-scale-invariant, so pure "
                "xi re-estimation is free (identical trajectories); the "
                "closed loop's measurable win is calibration — predicted "
                "per-period decay converges onto realized decay — plus "
                "the decay-cap guard for unsupported sqrt(B) credit.",
    }
    with open("BENCH_fig_replan.json", "w") as f:
        json.dump(report, f, indent=2)

    print(f"{'t (s)':>8} {'acc open':>9} {'acc closed':>10}")
    for t, ao, ac in zip(grid_t, acc_open, acc_closed):
        print(f"{t:>8.2f} {ao:>9.3f} {ac:>10.3f}")
    print(f"calibration |pred-real|/real (late half): "
          f"open={cal_open:.2f} closed={cal_closed:.2f} "
          f"({cal_open / max(cal_closed, 1e-12):.1f}x better)")

    assert margin >= -1e-9, (
        f"closed-loop accuracy fell below open-loop: margin={margin}")
    return [(f"fig_replan/replan{REPLAN}_{len(seeds)}seed_{periods}p",
             0.0,
             f"acc_closed_final={acc_closed[-1]:.3f};"
             f"acc_open_final={acc_open[-1]:.3f};"
             f"min_margin={margin:+.4f};"
             f"calib_gain={cal_open / max(cal_closed, 1e-12):.1f}x")]


if __name__ == "__main__":
    for r in main(fast=True):
        print(",".join(map(str, r)))

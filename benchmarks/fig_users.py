"""Impact of the number of users K — the paper's fleet-size figure, as a
declarative ``users=`` study.

One ``grid(base, users=[...]) × partition`` study sweeps the fleet size
under the proposed Algorithm-1 policy.  Fleet size is non-structural
(padded ragged-fleet buckets), so every K shares ONE compiled program per
partition-independent shape family — the whole figure is a single
``Experiment`` run, with cross-K fused host planning.

For each K the figure reports the mean final accuracy and the simulated
time-to-target (more users → more data per aggregation round → higher
accuracy at a given period count, but longer periods: the efficiency
trade-off the paper's joint batchsize/bandwidth allocation navigates).

Run:  PYTHONPATH=src python -m benchmarks.fig_users
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.api import AsyncExecutor, Experiment, ScenarioSpec, grid
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine

USERS = [2, 4, 6, 8]
TARGET_ACC = 0.60


def _base_fleet():
    """Heterogeneous CPU tiers; users= cycles them round-robin per K."""
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in (0.7, 1.4, 2.1))


def main(fast: bool = True):
    periods = 30 if fast else 100
    seeds = tuple(range(4 if fast else 8))
    full = ClassificationData.synthetic(n=900, dim=48, seed=0, spread=6.0)
    data, test = full.split(150)

    base = ScenarioSpec(fleet=_base_fleet(), name="ku", partition="noniid",
                        policy="proposed", b_max=24, base_lr=0.15,
                        hidden=96, seeds=seeds)
    study = grid(base, users=USERS, partition=["iid", "noniid"])

    exp = Experiment(data, test, study)
    before = engine.trace_count()
    t0 = time.perf_counter()
    res = exp.run(periods, executor=AsyncExecutor())
    wall = time.perf_counter() - t0
    traces = engine.trace_count() - before
    assert res.n_buckets == 1, res.n_buckets     # whole K-sweep: one bucket
    # per-user throughput: user-rows advanced per wall second across the
    # whole fused run (each output row simulates its K users for
    # ``periods`` rounds)
    user_periods = sum(int(k) * periods * res.sel(num_users=k).rows
                       for k in res.unique("num_users"))
    tput = user_periods / wall

    table = {}
    print(f"{'K':>3} {'partition':<8} {'final acc':>16} "
          f"{'t({:.0%})'.format(TARGET_ACC):>10}")
    for k in res.unique("num_users"):
        for part in ("iid", "noniid"):
            cell = res.sel(num_users=k, partition=part)
            acc = cell.final_acc
            speed = cell.speed(TARGET_ACC)
            reached = np.isfinite(speed)
            t_tgt = float(np.mean(speed[reached])) if reached.any() \
                else float("inf")
            table[f"K{k}/{part}"] = {
                "final_acc_mean": float(acc.mean()),
                "final_acc_std": float(acc.std()),
                "time_to_target_s": t_tgt,
                "sim_time_s": float(cell.times[:, -1].mean()),
            }
            print(f"{k:>3} {part:<8} {acc.mean():>8.3f}±{acc.std():<6.3f} "
                  f"{t_tgt:>10.1f}")

    print(f"throughput: {tput:.0f} user-periods/s "
          f"({user_periods} user-rows in {wall:.2f}s)")
    with open("BENCH_fig_users.json", "w") as f:
        json.dump({"users": USERS, "periods": periods,
                   "n_seeds": len(seeds), "target_acc": TARGET_ACC,
                   "n_buckets": res.n_buckets, "traces": traces,
                   "wall_s": wall, "user_periods_per_s": tput,
                   "cells": table}, f, indent=2)

    accs_iid = [table[f"K{k}/iid"]["final_acc_mean"] for k in USERS]
    return [(f"fig_users/{len(USERS)}sizes_{len(seeds)}seed_{periods}p",
             wall,
             f"buckets={res.n_buckets};traces={traces};"
             f"tput={tput:.0f};"
             f"acc_iid_K{USERS[0]}={accs_iid[0]:.3f};"
             f"acc_iid_K{USERS[-1]}={accs_iid[-1]:.3f}")]


if __name__ == "__main__":
    for r in main(fast=True):
        print(",".join(map(str, r)))

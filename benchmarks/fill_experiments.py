"""Regenerate the §Roofline table and §Perf appendix blocks in
EXPERIMENTS.md from results/dryrun/*.jsonl and results/perf/*.jsonl."""
from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")


def roofline_block() -> str:
    from benchmarks.roofline import load, fmt_table
    rows = [r for r in load() if r.get("mesh") == "16x16"]
    lines = ["```", fmt_table(rows), "```", ""]
    # multi-pod summary
    mp = [r for r in load() if r.get("mesh") == "2x16x16"]
    ok = sum(1 for r in mp if "error" not in r)
    lines.append(f"Multi-pod (2×16×16): {ok}/{len(mp)} pairs lower+compile "
                 f"(full table in baseline_multi_pod.jsonl).")
    return "\n".join(lines)


def perf_block() -> str:
    out = ["", "### Variant measurements (raw)", "```"]
    for path in sorted(glob.glob(os.path.join(ROOT, "results/perf",
                                              "*.jsonl"))):
        seen = {}
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if "error" in r:
                    continue
                seen[r.get("variant", "?")] = r     # last run wins
        for v, r in seen.items():
            out.append(
                f"{r['arch']:<22}{r['shape']:<13}{v:<34}"
                f"compute={r['compute_s']:.3e} memory={r['memory_s']:.3e} "
                f"collective={r['collective_s']:.3e}")
    out.append("```")
    return "\n".join(out)


def main(fast: bool = True):
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\nReading of the table)",
                  "<!-- ROOFLINE_TABLE -->\n" + roofline_block() + "\n",
                  text, flags=re.S)
    text = re.sub(r"<!-- PERF_RAW -->.*?(?=\n## |$)",
                  "<!-- PERF_RAW -->\n" + perf_block() + "\n",
                  text, flags=re.S)
    with open(path, "w") as f:
        f.write(text)
    return [("fill_experiments", 0.0, "ok")]


if __name__ == "__main__":
    main()

"""Massive-fleet scaling: per-user throughput vs K and banded vs
monolithic padding (the PR-8 topology subsystem's headline numbers).

Three rungs over a sampled (S-of-K) proposed-policy scenario family with
a cheap shape profile (hidden=16, b_max=8, fading_samples=64 — the point
is the fleet axis, not the model):

1. **banded mixed grid** — ``users=[8, 1024, 10240]`` with ``bands=True``
   lowers to one compiled program per power-of-two K band (8 / 1024 /
   16384; trace-ledger asserted) instead of padding the 8-user row to
   10240 lanes;
2. **monolithic mixed grid** — the same study unbanded: one program, every
   row padded to the grid max.  The banded-vs-monolithic speedup is the
   warm-execution wall ratio (second run of each, compiles excluded);
3. **per-K throughput sweep** — each K solo (``users=10_240`` included),
   reporting per-user throughput in users·periods/s of wall time.

On a multi-device jax runtime (e.g. ``XLA_FLAGS=
--xla_force_host_platform_device_count=8``) every run shards its batch
axis over a ``MeshExecutor`` — the CI ``fleet-scale`` job exercises
exactly that layout.  The seed count scales with the device count so
every mesh slot holds a *real* row (an n=1 bucket on an 8-device mesh
would otherwise pad to 8 copies of the same work), and the throughput
numbers count all rows — mesh scaling shows up as higher
user-periods/s at the same wall clock.

Run:  PYTHONPATH=src python -m benchmarks.fleet_scale
"""
from __future__ import annotations

import json
import time

import jax

from repro.api import Experiment, MeshExecutor, ScenarioSpec, grid
from repro.channels.model import CellConfig
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.topology import Sampling, band_width

USERS = [64, 1024, 10240]
BAND_USERS = [8, 1024, 10240]
PERIODS = 4
COHORT = 32                       # S: per-round participants


def _base_fleet():
    """Heterogeneous CPU tiers; users= cycles them round-robin per K."""
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in (0.7, 1.4, 2.1))


def _executor():
    return MeshExecutor() if jax.device_count() > 1 else None


def _timed_run(exp: Experiment, **kw) -> tuple:
    t0 = time.perf_counter()
    res = exp.run(PERIODS, executor=_executor(), **kw)
    jax.block_until_ready((res.losses, res.accs))
    return res, time.perf_counter() - t0


def main(fast: bool = True):
    max_k = max(max(USERS), max(BAND_USERS))
    full = ClassificationData.synthetic(n=2 * max_k, dim=16, seed=0,
                                        spread=6.0)
    data, test = full.split(min(512, max_k // 2))
    seeds = tuple(range(max(1, jax.device_count())))
    base = ScenarioSpec(fleet=_base_fleet(), name="fleet", partition="iid",
                        policy="proposed", b_max=8, base_lr=0.1, hidden=16,
                        seeds=seeds, cell=CellConfig(fading_samples=64),
                        sampling=Sampling(size=COHORT))

    # ---- rung 1+2: banded vs monolithic mixed-K grid ----------------------
    study = grid(base, users=BAND_USERS)
    exp = Experiment(data, test, study)
    n_bands = len({band_width(k) for k in BAND_USERS})
    assert len(exp.lower(bands=True)) == n_bands
    before = engine.trace_count()
    _, banded_cold = _timed_run(exp, bands=True)
    banded_traces = engine.trace_count() - before
    assert banded_traces == n_bands, \
        f"expected one program per band ({n_bands}), traced {banded_traces}"
    res_b, banded_warm = _timed_run(exp, bands=True)

    before = engine.trace_count()
    _, mono_cold = _timed_run(exp)
    mono_traces = engine.trace_count() - before
    res_m, mono_warm = _timed_run(exp)
    assert res_m.n_buckets == 1, res_m.n_buckets
    speedup = mono_warm / banded_warm
    print(f"mixed K={BAND_USERS}: banded {banded_warm:.2f}s "
          f"({n_bands} programs) vs monolithic {mono_warm:.2f}s "
          f"(pad {band_width(max(BAND_USERS))} vs {max(BAND_USERS)}) "
          f"-> speedup {speedup:.2f}x")

    # ---- rung 3: per-user throughput vs K ---------------------------------
    table = {}
    print(f"{'K':>6} {'wall s':>8} {'user-periods/s':>15}")
    for k in USERS:
        kexp = Experiment(data, test, grid(base, users=[k]))
        res, wall = _timed_run(kexp)       # cold (includes compile)
        res, wall = _timed_run(kexp)       # warm: steady-state throughput
        assert res.n_buckets == 1
        tput = k * PERIODS * res.rows / wall
        table[f"K{k}"] = {"wall_s": wall,
                          "user_periods_per_s": tput,
                          "sim_time_s": float(res.times[:, -1].mean()),
                          "final_acc": float(res.accs[:, -1].mean())}
        print(f"{k:>6} {wall:>8.2f} {tput:>15.0f}")

    out = {"periods": PERIODS, "cohort": COHORT,
           "n_seeds": len(seeds), "devices": jax.device_count(),
           "banded": {"users": BAND_USERS, "n_programs": banded_traces,
                      "cold_s": banded_cold, "warm_s": banded_warm},
           "monolithic": {"k_pad": max(BAND_USERS),
                          "n_programs": mono_traces,
                          "cold_s": mono_cold, "warm_s": mono_warm},
           "banded_speedup": speedup,
           "throughput": table}
    with open("BENCH_fleet.json", "w") as f:
        json.dump(out, f, indent=2)

    kmax = max(USERS)
    return [(f"fleet_scale/K{kmax}_{PERIODS}p", table[f"K{kmax}"]["wall_s"],
             f"tput={table[f'K{kmax}']['user_periods_per_s']:.0f};"
             f"banded_speedup={speedup:.2f};devices={jax.device_count()}")]


if __name__ == "__main__":
    for r in main(fast=True):
        print(",".join(map(str, r)))

"""Beyond-paper validation of eq. (8): measure the empirical per-period
global loss decay ΔL(B) on the synthetic task and fit ΔL = ξ·B^α.
The paper assumes α = 0.5; we report the fitted α and ξ."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ClassificationData
from repro.fed import feel_model


def main(fast: bool = True):
    reps = 6 if fast else 30
    full = ClassificationData.synthetic(n=4096, dim=128, seed=0, spread=6.0)
    data, _ = full.split(96)
    grad = jax.jit(jax.grad(feel_model.loss_fn))
    lossf = jax.jit(feel_model.loss_fn)
    batches = [4, 8, 16, 32, 64, 128, 256]
    decays = []
    rng = np.random.default_rng(0)
    for B in batches:
        d = []
        for r in range(reps):
            params = feel_model.init(jax.random.key(r), 128, depth=2,
                                     input_dim=128)
            # pre-train a few steps so we measure mid-training decay
            for _ in range(5):
                i = rng.integers(0, len(data.y), 64)
                g = grad(params, jnp.asarray(data.x[i]),
                         jnp.asarray(data.y[i]))
                params = jax.tree_util.tree_map(
                    lambda p, gg: p - 0.1 * gg, params, g)
            i = rng.integers(0, len(data.y), B)
            x, y = jnp.asarray(data.x[i]), jnp.asarray(data.y[i])
            l0 = lossf(params, jnp.asarray(data.x), jnp.asarray(data.y))
            lr = 0.1 * np.sqrt(B / 64)              # η ∝ √B (paper scaling)
            g = grad(params, x, y)
            p2 = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
            l1 = lossf(p2, jnp.asarray(data.x), jnp.asarray(data.y))
            d.append(float(l0 - l1))
        decays.append(np.mean(d))
    logb = np.log(batches)
    logd = np.log(np.maximum(decays, 1e-9))
    alpha, logxi = np.polyfit(logb, logd, 1)
    return [("loss_decay_fit", 0.0,
             f"alpha={alpha:.3f};xi={np.exp(logxi):.4f};"
             f"paper_alpha=0.5;decays={['%.4f' % d for d in decays]}")]


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

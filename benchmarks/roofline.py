"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).
Reads results/dryrun/*.jsonl written by repro.launch.dryrun."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(pattern="baseline_*.jsonl"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            for line in f:
                rows.append(json.loads(line))
    return rows


def fmt_table(rows):
    out = []
    hdr = (f"{'arch':<24}{'shape':<13}{'mesh':<9}{'compute_s':>11}"
           f"{'memory_s':>11}{'collect_s':>11}{'dominant':>12}"
           f"{'useful%':>9}")
    out.append(hdr)
    for r in rows:
        if "error" in r:
            out.append(f"{r['arch']:<24}{r['shape']:<13}"
                       f"{r.get('mesh','?'):<9}  ERROR: {r['error'][:60]}")
            continue
        uf = r.get("useful_flops_ratio")
        out.append(
            f"{r['arch']:<24}{r['shape']:<13}{r['mesh']:<9}"
            f"{r['compute_s']:>11.3e}{r['memory_s']:>11.3e}"
            f"{r['collective_s']:>11.3e}"
            f"{r['dominant'].replace('_s',''):>12}"
            f"{(uf*100 if uf else 0):>8.1f}%")
    return "\n".join(out)


def main(fast: bool = True):
    rows = load()
    ok = [r for r in rows if "error" not in r]
    errs = [r for r in rows if "error" in r]
    out = []
    if rows:
        print(fmt_table(rows))
    out.append(("roofline/pairs_ok", 0.0,
                f"ok={len(ok)};fail={len(errs)};total={len(rows)}"))
    for dom in ("compute_s", "memory_s", "collective_s"):
        n = sum(1 for r in ok if r.get("dominant") == dom)
        out.append((f"roofline/dominant_{dom.replace('_s','')}", 0.0,
                    f"count={n}"))
    return out


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

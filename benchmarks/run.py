"""Benchmark harness — one module per paper table/figure (DESIGN.md §8).
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (ablation_compression, fig2_gpu_training_function,
                            fig3_generalization, fig45_batchsize_policies,
                            fig_dynamics, fig_models, fig_replan, fig_users,
                            loss_decay_fit, roofline, serve_load,
                            smoke_experiment, solver_scaling, sweep_speed,
                            table2_schemes)
    modules = [
        ("fig2_gpu_training_function", fig2_gpu_training_function),
        ("solver_scaling", solver_scaling),
        ("loss_decay_fit", loss_decay_fit),
        ("smoke_experiment", smoke_experiment),
        ("table2_schemes", table2_schemes),
        ("fig3_generalization", fig3_generalization),
        ("fig45_batchsize_policies", fig45_batchsize_policies),
        ("ablation_compression", ablation_compression),
        ("fig_users", fig_users),
        ("fig_replan", fig_replan),
        ("fig_dynamics", fig_dynamics),
        ("fig_models", fig_models),
        ("sweep_speed", sweep_speed),
        ("roofline", roofline),
        ("serve_load", serve_load),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            rows = mod.main(fast=True)
            for r in rows:
                print(",".join(str(x) for x in r), flush=True)
            print(f"_module/{name},{(time.time()-t0)*1e6:.0f},ok",
                  flush=True)
        except Exception as e:                               # noqa: BLE001
            failures += 1
            print(f"_module/{name},0,FAIL:{type(e).__name__}:{e}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

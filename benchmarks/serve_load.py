"""Service load generator: seeded arrival processes through the real
:class:`~repro.serve.ExperimentService`, emitting ``BENCH_serve.json``.

The workload is the production shape the ROADMAP's
experiment-as-a-service item names: a **stream** of heterogeneous
scenario requests arriving over time, dominated by *repeat bucket
shapes* (the same few scenario templates revisited), so the persistent
compile cache gets to do its job — plus one long-horizon low-priority
background request submitted first, which the hot foreground arrivals
preempt at chunk boundaries.

Measurement is steady-state: an untimed warm-up drain compiles the hot
program shapes once, then the stats window resets
(:meth:`~repro.serve.ExperimentService.reset_stats`) before the timed
tape starts — so the reported latencies and hit rate describe a warm
service absorbing a stream, not the first-ever compile.

Timing is hybrid-deterministic: arrivals follow a seeded Poisson tape
(``repro.testing.poisson_arrivals``) on a ``VirtualClock`` that advances
by the *measured* wall-clock cost of each service step — so request
ordering and admission grouping are driven by real compute times, result
latencies are real seconds, and there is no ``time.sleep`` anywhere.
When the service goes idle before the next arrival, the clock jumps
straight to it (an idle service costs nothing).

Reported (and asserted, CI-enforced):

* offered arrivals/s vs p50/p99 result latency (+ first-result latency);
* compile-cache hit rate — ≥ 50% on this repeat-shape workload;
* ≥ 1 preemption, and zero ``TraceEvent``s charged to warm admissions.

Run: ``JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.serve_load``
"""
from __future__ import annotations

import json
import time

from repro.api import ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.serve import ExperimentService
from repro.testing import VirtualClock, assign_templates, poisson_arrivals

SEED = 7
RATE = 6.0            # offered arrivals per (virtual) second
N_REQUESTS = 12
HOT_PERIODS = 6
LONG_PERIODS = 24
CHUNK = 2
MAX_BATCH = 2         # admission micro-batch size cap (keeps shapes recurring)


def _fleet(k: int):
    return tuple(DeviceProfile(kind="cpu", f_cpu=(0.7 + 0.7 * (i % 3)) * 1e9)
                 for i in range(k))


def _templates():
    """Two scenario templates sharing one structural ``bucket_key``
    (partition and seeds are values, not shapes) — the repeat-shape
    workload the compile cache wins on."""
    return [
        ScenarioSpec(fleet=_fleet(3), name="hotA", b_max=16, hidden=48,
                     base_lr=0.15, seeds=(0, 1)),
        ScenarioSpec(fleet=_fleet(3), name="hotB", b_max=16, hidden=48,
                     base_lr=0.15, partition="iid", seeds=(2, 3)),
    ]


def main(fast: bool = True):
    full = ClassificationData.synthetic(n=420, dim=32, seed=0, spread=6.0)
    data, test = full.split(84)

    clock = VirtualClock()
    svc = ExperimentService(data, test, chunk_periods=CHUNK,
                            window=0.02, max_batch=MAX_BATCH,
                            clock=clock, audit=True)
    hot_a, hot_b = _templates()

    # untimed warm-up: compile the single-request (2-row) and paired
    # (4-row) hot program shapes once, so the timed stream below
    # exercises the cache rather than the compiler
    svc.submit(hot_a, periods=HOT_PERIODS)
    svc.drain()
    svc.submit(hot_a, periods=HOT_PERIODS)
    svc.submit(hot_b, periods=HOT_PERIODS)
    svc.drain()
    stats = svc.reset_stats()

    # background: long horizon, cold, low priority — the preemption victim
    long_spec = ScenarioSpec(fleet=_fleet(4), name="bg", b_max=24,
                             hidden=64, base_lr=0.1, seeds=(0,))
    bg = svc.submit(long_spec, periods=LONG_PERIODS, priority=5)

    tape = assign_templates(
        poisson_arrivals(RATE, N_REQUESTS, seed=SEED, start=0.05),
        [hot_a, hot_b])
    tickets = [bg]
    i = 0
    while True:
        while i < len(tape) and clock.now() >= tape[i][0]:
            tickets.append(svc.submit(tape[i][1], periods=HOT_PERIODS,
                                      priority=0))
            i += 1
        t0 = time.perf_counter()
        worked = svc.step()
        if worked:
            clock.advance(time.perf_counter() - t0)
        elif i < len(tape):
            clock.advance_to(tape[i][0])    # idle until the next arrival
        else:
            break
    svc.drain()                 # flush any group still inside its window
    assert all(t.done for t in tickets), "load run left unfinished tickets"

    offered = (N_REQUESTS - 1) / float(tape[-1][0] - tape[0][0])
    summary = stats.to_dict()
    summary.update({
        "offered_arrivals_per_s": offered,
        "n_requests": len(tickets),
        "hot_periods": HOT_PERIODS,
        "long_periods": LONG_PERIODS,
        "chunk_periods": CHUNK,
        "max_batch": MAX_BATCH,
        "arrival_seed": SEED,
        "audit_ok": (svc.audit_report is None
                     or not svc.audit_report.errors()),
    })

    # the acceptance contract (CI runs this module)
    assert stats.cache_hit_rate >= 0.5, (
        f"repeat-shape workload should be cache-warm: hit rate "
        f"{stats.cache_hit_rate:.2f} ({stats.cache_hits} hits / "
        f"{stats.cache_misses} misses)")
    assert stats.preemptions >= 1, "hot arrivals never preempted the " \
        "background run"
    assert stats.warm_admission_traces == 0, (
        f"warm admissions recorded {stats.warm_admission_traces} "
        "TraceEvents; the compile cache failed its zero-retrace contract")

    with open("BENCH_serve.json", "w") as f:
        json.dump(summary, f, indent=2)

    lat = summary["latency"]
    print(f"[serve_load] {len(tickets)} requests at "
          f"{offered:.1f} offered/s: p50={lat['p50']:.3f}s "
          f"p99={lat['p99']:.3f}s  cache hit rate "
          f"{stats.cache_hit_rate:.0%}  preemptions={stats.preemptions} "
          f"resumes={stats.resumes}  warm traces="
          f"{stats.warm_admission_traces}")
    return [(f"serve_load/{len(tickets)}req_{RATE:g}ps", 0.0,
             f"p50={lat['p50']:.4f}s;p99={lat['p99']:.4f}s;"
             f"hit_rate={stats.cache_hit_rate:.2f};"
             f"preempt={stats.preemptions};"
             f"warm_traces={stats.warm_admission_traces}")]


if __name__ == "__main__":
    for r in main(fast=True):
        print(",".join(map(str, r)))

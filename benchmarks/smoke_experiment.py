"""CI smoke: a tiny 2-cell declarative experiment end-to-end on CPU.

Asserts the structural guarantees the API makes — single bucket, single
compiled program, mesh-sharded batch axis on whatever devices exist (1 on
CPU CI), finite series, monotone time ledgers — in under a minute.

Run:  PYTHONPATH=src python -m benchmarks.smoke_experiment
"""
from __future__ import annotations

import numpy as np

from repro.api import Experiment, ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.launch.mesh import make_batch_mesh


def main(fast: bool = True):
    full = ClassificationData.synthetic(n=600, dim=48, seed=0, spread=6.0)
    data, test = full.split(120)
    fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                  for f in (0.7, 1.4, 2.1))
    specs = [ScenarioSpec(fleet=fleet, name="cpu3", partition=part,
                          policy="proposed", b_max=32, base_lr=0.15,
                          hidden=128, seeds=(0, 1))
             for part in ("iid", "noniid")]

    before = engine.trace_count()
    res = Experiment(data, test, specs, mesh=make_batch_mesh()).run(
        periods=8)
    traces = engine.trace_count() - before

    assert res.n_buckets == 1, res.n_buckets
    assert traces == 1, f"2-cell grid must compile once, traced {traces}x"
    assert res.rows == 4 and res.periods == 8
    assert np.all(np.isfinite(res.losses))
    assert np.all(np.isfinite(res.accs))
    assert np.all(np.diff(res.times, axis=1) > 0)
    assert set(res.coords["partition"]) == {"iid", "noniid"}
    assert res.speed(2.0).shape == (4,)           # inf-safe reduction
    return [("smoke_experiment/2cell_2seed_8p", 0.0,
             f"buckets={res.n_buckets};traces={traces};"
             f"final_acc={res.final_acc.mean():.3f}")]


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))
    print("smoke_experiment: OK")

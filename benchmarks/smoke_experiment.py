"""CI smoke: tiny declarative experiments end-to-end on CPU.

Cell 1 — a 2-cell single-bucket experiment through ``MeshExecutor``
(whatever devices exist; 1 on CPU CI): single bucket, single compiled
program, finite series, monotone time ledgers.

Cell 2 — an ``AsyncExecutor`` smoke on a multi-bucket geometry study
(``grid`` over ``cell.radius_m`` × scheme): async dispatch must be
bit-identical to the serial reference, streaming must yield one
cumulative partial per bucket, and wider cells must plan longer
communication latencies.

Run:  PYTHONPATH=src python -m benchmarks.smoke_experiment
"""
from __future__ import annotations

import numpy as np

from repro.api import (AsyncExecutor, Experiment, MeshExecutor,
                       ScenarioSpec, SerialExecutor, grid)
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine


def main(fast: bool = True):
    full = ClassificationData.synthetic(n=600, dim=48, seed=0, spread=6.0)
    data, test = full.split(120)
    fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                  for f in (0.7, 1.4, 2.1))
    specs = [ScenarioSpec(fleet=fleet, name="cpu3", partition=part,
                          policy="proposed", b_max=32, base_lr=0.15,
                          hidden=128, seeds=(0, 1))
             for part in ("iid", "noniid")]

    before = engine.trace_count()
    res = Experiment(data, test, specs).run(periods=8,
                                            executor=MeshExecutor())
    traces = engine.trace_count() - before

    assert res.n_buckets == 1, res.n_buckets
    assert traces == 1, f"2-cell grid must compile once, traced {traces}x"
    assert res.rows == 4 and res.periods == 8
    assert np.all(np.isfinite(res.losses))
    assert np.all(np.isfinite(res.accs))
    assert np.all(np.diff(res.times, axis=1) > 0)
    assert set(res.coords["partition"]) == {"iid", "noniid"}
    assert res.speed(2.0).shape == (4,)           # inf-safe reduction

    # ---- async smoke: multi-bucket geometry study ------------------------
    base = ScenarioSpec(fleet=fleet, name="cpu3", partition="noniid",
                        policy="full", b_max=16, base_lr=0.15, hidden=64,
                        compression=1.0, seeds=(0,))
    study = grid(base, scheme=["feel", "individual"],
                 **{"cell.radius_m": [150.0, 600.0]})
    exp = Experiment(data, test, study)
    assert len(exp.lower()) == 2                  # feel + dev buckets
    serial = exp.run(periods=6, executor=SerialExecutor())
    partials = list(exp.stream(periods=6, executor=AsyncExecutor()))
    assert len(partials) == 2                     # one yield per bucket
    a = partials[-1]
    assert np.array_equal(np.asarray(serial.losses), np.asarray(a.losses))
    assert np.array_equal(np.asarray(serial.accs), np.asarray(a.accs))
    assert np.array_equal(serial.times, a.times)
    near = a.sel(cell_radius_m=150.0, scheme="feel").times[0, -1]
    far = a.sel(cell_radius_m=600.0, scheme="feel").times[0, -1]
    assert far > near, (near, far)                # wider cell: slower rates
    return [("smoke_experiment/2cell_2seed_8p", 0.0,
             f"buckets={res.n_buckets};traces={traces};"
             f"final_acc={res.final_acc.mean():.3f}"),
            ("smoke_experiment/async_geometry_2bucket", 0.0,
             f"serial==async;radius150_t={float(near):.2f}s;"
             f"radius600_t={float(far):.2f}s")]


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))
    print("smoke_experiment: OK")

"""Algorithm 1 scaling: solver wall time vs K (paper claims
O((K log 1/ε)²) for 𝒫₂ and O(1/√ε·(K log 1/ε)²) overall)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import DeviceProfile, gradient_bits, solve_period


def main(fast: bool = True):
    rows = []
    s = gradient_bits(7_000_000)
    for k in ([4, 16, 64] if fast else [4, 16, 64, 256]):
        rng = np.random.default_rng(0)
        devs = [DeviceProfile(kind="cpu", f_cpu=f)
                for f in rng.uniform(0.5e9, 3e9, k)]
        r_up = rng.uniform(20e6, 200e6, k)
        r_down = rng.uniform(20e6, 200e6, k)
        t0 = time.time()
        sol = solve_period(devs, r_up, r_down, s, 0.01, 0.01, xi=0.05,
                           b_max=128)
        us = (time.time() - t0) * 1e6
        rows.append((f"solver_scaling/K{k}", us,
                     f"B={sol.global_batch:.0f};E={sol.efficiency:.4f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

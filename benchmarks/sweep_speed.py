"""Engine wall-clock benchmark (ISSUE 1 acceptance): a 50-period, 8-seed
feel/proposed sweep, device-resident ``vmap(lax.scan)`` engine vs the seed
implementation.

The baseline below reproduces the seed's ``FeelSimulation.run`` faithfully:
one Python iteration per period, scalar Algorithm-1 ``scheduler.plan()``
per period, eager exact-top_k SBC, ``float()`` host syncs each step, seeds
run sequentially.  The engine path is the production configuration:
lockstep-vectorized horizon planning + one compiled ``vmap(lax.scan)``
advancing all seeds.  Acceptance bar: >=5x."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.sbc import compress_dense
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import feel_model
from repro.fed.sweep import run_seed_batch
from repro.fed.trainer import FeelSimulation

PERIODS, SEEDS = 50, range(8)


def _fleet():
    return [DeviceProfile(kind="cpu", f_cpu=f * 1e9)
            for f in [0.7, 0.7, 1.4, 1.4, 2.1, 2.1]]


def _sims(data, test, seeds):
    return [FeelSimulation(_fleet(), data, test, partition="noniid",
                           policy="proposed", b_max=64, base_lr=0.15,
                           seed=s) for s in seeds]


def _seed_style_run(sim: FeelSimulation, periods: int, eval_every: int = 10):
    """The seed's per-period loop, verbatim semantics: plan -> sample ->
    grad -> eager SBC (exact top_k) -> aggregate -> float() syncs."""
    t = 0.0
    for p in range(periods):
        plan = sim.scheduler.plan()
        idx, w = sim.batcher.sample(plan.batch)
        x = jnp.asarray(sim.data.x[idx])
        y = jnp.asarray(sim.data.y[idx])
        wj = jnp.asarray(w)
        loss_before = float(sim._loss_fn(sim.params,
                                         x.reshape(-1, x.shape[-1]),
                                         y.reshape(-1), wj.reshape(-1)))
        grads = sim._grad_fn(sim.params, x, y, wj)
        grads, sim.residuals = compress_dense(
            grads, sim.scheduler.compression, sim.residuals, exact=True)
        bk = jnp.asarray(plan.batch, jnp.float32)
        wk = bk / jnp.sum(bk)
        agg = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(wk, g, axes=1), grads)
        sim.params = jax.tree_util.tree_map(
            lambda pr, g: pr - plan.lr * g, sim.params, agg)
        loss = float(sim._loss_fn(sim.params, x.reshape(-1, x.shape[-1]),
                                  y.reshape(-1), wj.reshape(-1)))
        sim.scheduler.observe(loss_before - loss, plan.global_batch)
        t += plan.predicted_latency
        if p % eval_every == 0 or p == periods - 1:
            float(sim._acc_fn(sim.params, jnp.asarray(sim.test.x),
                              jnp.asarray(sim.test.y)))


def main(fast: bool = True):
    full = ClassificationData.synthetic(n=2200, dim=128, seed=0, spread=6.0)
    data, test = full.split(300)

    # warm both paths (same shapes) so jit compile is excluded
    run_seed_batch(_sims(data, test, SEEDS), PERIODS)
    _seed_style_run(_sims(data, test, [99])[0], 3)

    t0 = time.time()
    run_seed_batch(_sims(data, test, SEEDS), PERIODS)
    t_scan = time.time() - t0

    t0 = time.time()
    for sim in _sims(data, test, SEEDS):
        _seed_style_run(sim, PERIODS)
    t_seed = time.time() - t0

    speedup = t_seed / t_scan
    return [("sweep_speed/engine_8seed_50p", t_scan * 1e6,
             f"wall={t_scan:.2f}s"),
            ("sweep_speed/seed_loop_8seed_50p", t_seed * 1e6,
             f"wall={t_seed:.2f}s;speedup={speedup:.1f}x")]


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

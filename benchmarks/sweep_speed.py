"""Sweep engine wall-clock: 6-rung comparison emitting ``BENCH_sweep.json``.

The main grid is a scenario *family* — 2 CPU fleets × {iid, noniid} × 2
base learning rates, all under the proposed Algorithm-1 policy — i.e. the
workload the declarative API exists for.  Rungs (same grid; schedules are
bit-identical across rungs, so this measures pure implementation
overhead):

  python_loop   — the seed's per-period reference loop: scalar
                  ``scheduler.plan()`` per period, eager exact-top_k SBC,
                  ``float()`` host syncs each step, seeds sequential.
                  Measured on a seed subset and extrapolated (labeled in
                  the JSON) in fast mode; full grid otherwise.
  percell_vmap  — PR 1's ``run_sweep`` grid driver, frozen verbatim below:
                  per cell, simulations constructed and horizons planned
                  sequentially (per-period channel draws, per-scenario
                  Algorithm-1 rows), then one vmap(lax.scan) per cell.
                  Every cell re-plans from scratch — the per-cell driver
                  cannot see that cells share planning work.
  bucket_vmap   — the declarative API: one ``Experiment`` lowering the
                  whole grid to ONE compiled program — batched channel
                  draws, shared-fleet Algorithm-1 rows fused across
                  scenarios, horizons deduplicated across rows that are
                  scheduler-identical modulo partition/base_lr (exact, not
                  approximate), vmapped init, flattened (cell × seed) axis.
  bucket_async  — a *multi-bucket* grid (a ``grid()`` study over model
                  capacity × partition: 4 shape buckets, 16 rows each)
                  run under ``AsyncExecutor`` vs ``SerialExecutor``.  The
                  async runtime dispatches bucket N without blocking and
                  overlaps bucket N+1's host planning (channel MC draws,
                  Algorithm-1 bisections) behind N's device execution;
                  buckets are declared largest-first so the final —
                  unhidden — collection is the cheapest one.  Both
                  executors produce bit-identical Results (test-enforced);
                  best-of-2 walls damp CI scheduling noise.
  chunked_pipeline — rung 6: intra-bucket chunked pipelining on the SAME
                  single-bucket grid as rung 3 (where host planning —
                  channel MC draws + Algorithm-1 bisections — and device
                  execution are both substantial).  Bucket-serial: one
                  monolithic plan → dispatch → collect (the host plans
                  ~5s before the device starts).  Chunked-pipelined:
                  ``AsyncExecutor(chunk_periods=C)`` executes the bucket
                  as C-period chunks carrying the engine state, so the
                  host plans chunk c+1 while the device scans chunk c —
                  results bit-identical (test-enforced), wall-clock
                  bounded below by max(plan, device) instead of their
                  sum.  On 2-core CI the overlap is contended (numpy and
                  XLA share cores; CPU async dispatch depth is shallow),
                  so the recorded ratio undersells accelerator meshes.
  users_padded  — rung 5: the paper's "impact of number of users" sweep,
                  ``grid(base, users=[5, 6, 7, 8])`` × 8 seeds at a short
                  horizon (the interactive-sweep regime, where per-K
                  recompiles dominate wall-clock — exactly the workload
                  the ragged-fleet redesign unblocks).  Padded-bucketed:
                  fleet size is non-structural, so the whole K-sweep
                  lowers to ONE padded compiled program with cross-K
                  fused Algorithm-1 planning.  Per-K serial: the
                  pre-redesign shape-per-K lowering (each spec its own
                  Experiment → its own compile + its own planning pass).
                  Both walls are measured cold (compiles included — the
                  compile tax is the point): the padded side compiles
                  ONE (N=32, K=8) program; the per-K side compiles one
                  (N=8, K_m) program per fleet size.  (On long horizons
                  the CPU pays serially for the padding FLOPs and the
                  ratio shrinks toward the device-work ratio
                  ΣK_m / (n·K_max); on accelerator meshes the batch axis
                  is parallel and padding is ~free.)

Acceptance bars: bucket_vmap >= 2x over PR 1's per-cell loop;
bucket_async >= 1.2x over SerialExecutor on the >= 3-bucket grid;
users_padded >= 1.5x over per-K serial on the 4-size K-sweep;
chunked_pipeline >= 1.1x over the bucket-serial monolithic lowering on
the planning-heavy single-bucket grid (2-core CI floor).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (AsyncExecutor, Experiment, ScenarioSpec,
                       SerialExecutor, grid)
from repro.compression.sbc import compress_dense
from repro.core import DeviceProfile, FeelScheduler
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.fed.trainer import FeelSimulation

PERIODS, SEEDS = 50, tuple(range(8))
BMAX, HIDDEN = 24, 96
CELLS = [(fl, part, lr) for fl in ("cpu6-slow", "cpu6-fast")
         for part in ("iid", "noniid") for lr in (0.1, 0.15)]
# multi-bucket study: model capacity splits shape buckets; declared
# largest-first so AsyncExecutor's final (unhidden) collect is smallest
MB_HIDDEN = [128, 96, 64, 48]
# rung 5 K-sweep: fleet sizes via the users= axis; hidden=80 is unique to
# this rung so both sides compile cold, and the short horizon keeps the
# rung in the compile/plan-dominated interactive regime
US_USERS = [5, 6, 7, 8]
US_HIDDEN = 80
US_PERIODS = 12
# rung 6: chunk size for intra-bucket pipelining (5 chunks over PERIODS)
CHUNK = 10


def _fleet(tag):
    tiers = ([0.7, 0.7, 1.4, 1.4, 2.1, 2.1] if tag == "cpu6-slow"
             else [1.0, 1.0, 1.8, 1.8, 2.6, 2.6])
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9) for f in tiers)


def _sims(data, test, cell, seeds):
    fl, part, lr = cell
    return [FeelSimulation(list(_fleet(fl)), data, test, partition=part,
                           policy="proposed", b_max=BMAX, base_lr=lr,
                           hidden=HIDDEN, seed=s) for s in seeds]


# ---------------------------------------------------------------------------
# rung 1: the seed's python loop (frozen verbatim from PR 1's baseline)
# ---------------------------------------------------------------------------


def _seed_style_run(sim: FeelSimulation, periods: int, eval_every: int = 10):
    """The seed's per-period loop, verbatim semantics: plan -> sample ->
    grad -> eager SBC (exact top_k) -> aggregate -> float() syncs."""
    t = 0.0
    for p in range(periods):
        plan = sim.scheduler.plan()
        idx, w = sim.batcher.sample(plan.batch)
        x = jnp.asarray(sim.data.x[idx])
        y = jnp.asarray(sim.data.y[idx])
        wj = jnp.asarray(w)
        loss_before = float(sim._loss_fn(sim.params,
                                         x.reshape(-1, x.shape[-1]),
                                         y.reshape(-1), wj.reshape(-1)))
        grads = sim._grad_fn(sim.params, x, y, wj)
        grads, sim.residuals = compress_dense(
            grads, sim.scheduler.compression, sim.residuals, exact=True)
        bk = jnp.asarray(plan.batch, jnp.float32)
        wk = bk / jnp.sum(bk)
        agg = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(wk, g, axes=1), grads)
        sim.params = jax.tree_util.tree_map(
            lambda pr, g: pr - plan.lr * g, sim.params, agg)
        loss = float(sim._loss_fn(sim.params, x.reshape(-1, x.shape[-1]),
                                  y.reshape(-1), wj.reshape(-1)))
        sim.scheduler.observe(loss_before - loss, plan.global_batch)
        t += plan.predicted_latency
        if p % eval_every == 0 or p == periods - 1:
            float(sim._acc_fn(sim.params, jnp.asarray(sim.test.x),
                              jnp.asarray(sim.test.y)))


# ---------------------------------------------------------------------------
# rung 2: PR 1's per-cell grid driver, frozen verbatim (per-period channel
# draws, per-scenario planning, one vmap(lax.scan) invocation per cell)
# ---------------------------------------------------------------------------


def _pr1_plan_horizon_proposed(sched: FeelScheduler, periods: int):
    """PR 1's ``_plan_horizon_proposed`` body: per-period Monte-Carlo rate
    draws, per-scenario Algorithm-1 rows."""
    from repro.core.solver import optimize_batch_rows, solve_period_rows
    c = sched.cell.cfg
    K = len(sched.devices)
    rates_up = np.empty((periods, K))
    rates_down = np.empty((periods, K))
    for p in range(periods):
        rates_up[p] = sched.cell.avg_rate(sched._dist_km)
        rates_down[p] = sched.cell.avg_rate(sched._dist_km)
    xi = sched.xi_est.xi
    reopt = np.array([(sched._period + p) % sched.reopt_every == 0
                      or (p == 0 and sched._b_cache is None)
                      for p in range(periods)])
    B = np.empty(periods)
    carry = sched._b_cache
    if reopt.any():
        b_star = optimize_batch_rows(
            sched.devices, rates_up[reopt], rates_down[reopt],
            sched.payload_bits, c.frame_up_s, c.frame_down_s, xi,
            sched.b_max)
        j = 0
        for p in range(periods):
            if reopt[p]:
                carry = float(b_star[j])
                j += 1
            B[p] = carry
    else:
        B[:] = carry
    sol = solve_period_rows(sched.devices, rates_up, rates_down,
                            sched.payload_bits, c.frame_up_s,
                            c.frame_down_s, xi, B, sched.b_max)
    batch = np.maximum(np.round(sol["batch"]).astype(int), 1)
    return batch, sol, B


def _pr1_run_cell(data, test, cell, seeds, periods):
    """PR 1's run_sweep body for one cell: sequential sim construction and
    planning, then one batched trajectory."""
    from repro.core.efficiency import lr_scale
    from repro.core.scheduler import PlanHorizon
    sims = _sims(data, test, cell, seeds)
    schedules = []
    for sim in sims:
        sched = sim.scheduler
        batch, sol, B = _pr1_plan_horizon_proposed(sched, periods)
        gb = batch.sum(1)
        horizon = PlanHorizon(
            batch=batch, tau_up=sol["tau_up"], tau_down=sol["tau_down"],
            lr=np.array([lr_scale(sched.base_lr, g, sched.ref_batch)
                         for g in gb], np.float64),
            latency=sol["latency"], global_batch=gb.astype(np.int64))
        schedules.append(engine.build_schedule(
            sched, sim.batcher, sim.devices, periods, horizon=horizon))
    params0 = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[sim.params for sim in sims])
    residual0 = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[sim.initial_residual() for sim in sims])
    s0 = sims[0]
    _, _, (losses, accs, _) = engine.run_trajectory_batch(
        params0, residual0, schedules, s0.data, s0.test,
        local_steps=s0.local_steps, compress=s0.compress,
        ratio=s0.scheduler.compression)
    return np.asarray(losses), np.asarray(accs)


def _pr1_run_grid(data, test, periods):
    return {cell: _pr1_run_cell(data, test, cell, SEEDS, periods)
            for cell in CELLS}


# ---------------------------------------------------------------------------
# rung 3: the declarative bucket lowering
# ---------------------------------------------------------------------------


def _bucket_specs():
    return [ScenarioSpec(fleet=_fleet(fl), name=fl, partition=part,
                         policy="proposed", b_max=BMAX, base_lr=lr,
                         hidden=HIDDEN, seeds=SEEDS)
            for fl, part, lr in CELLS]


# ---------------------------------------------------------------------------
# rung 4: multi-bucket async dispatch (overlap host planning with device
# execution across shape buckets)
# ---------------------------------------------------------------------------


def _multibucket_study():
    base = ScenarioSpec(fleet=_fleet("cpu6-slow"), name="mb",
                        partition="noniid", policy="proposed", b_max=BMAX,
                        base_lr=0.1, seeds=SEEDS)
    return grid(base, hidden=MB_HIDDEN, partition=["iid", "noniid"])


def _users_study():
    base = ScenarioSpec(fleet=_fleet("cpu6-slow")[:3], name="ks",
                        partition="noniid", policy="proposed", b_max=BMAX,
                        base_lr=0.1, hidden=US_HIDDEN, seeds=SEEDS)
    return grid(base, users=US_USERS)


def _time_executor(exp, executor_cls, reps: int = 2) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        exp.run(PERIODS, executor=executor_cls())
        best = min(best, time.time() - t0)
    return best


def main(fast: bool = True):
    full = ClassificationData.synthetic(n=900, dim=48, seed=0, spread=6.0)
    data, test = full.split(150)
    n_cells = len(CELLS)
    n_runs = n_cells * len(SEEDS)

    # warm all paths (same shapes) so jit compile is excluded
    Experiment(data, test, _bucket_specs()).run(PERIODS)
    _pr1_run_cell(data, test, CELLS[0], SEEDS, PERIODS)
    _seed_style_run(_sims(data, test, CELLS[0], [99])[0], 3)

    t0 = time.time()
    res = Experiment(data, test, _bucket_specs()).run(PERIODS)
    t_bucket = time.time() - t0
    assert res.n_buckets == 1

    t0 = time.time()
    _pr1_run_grid(data, test, PERIODS)
    t_percell = time.time() - t0

    python_runs = 2 if fast else n_runs
    t0 = time.time()
    done = 0
    for cell in CELLS:
        if done == python_runs:
            break
        for sim in _sims(data, test, cell, SEEDS):
            if done == python_runs:
                break
            _seed_style_run(sim, PERIODS)
            done += 1
    t_python = (time.time() - t0) * (n_runs / python_runs)

    # rung 4: serial vs async executors on a 4-bucket study
    mb = _multibucket_study()
    exp_mb = Experiment(data, test, mb)
    n_mb_buckets = len(exp_mb.lower())
    exp_mb.run(PERIODS)                  # warm: compile all 4 programs
    t_mb_serial = _time_executor(exp_mb, SerialExecutor)
    t_mb_async = _time_executor(exp_mb, AsyncExecutor)

    # rung 6: intra-bucket chunked pipelining vs the bucket-serial
    # monolithic lowering, on rung 3's planning-heavy single bucket
    exp_ck = Experiment(data, test, _bucket_specs())
    exp_ck.run(PERIODS, executor=AsyncExecutor(chunk_periods=CHUNK))
    t_ck_serial = _time_executor(exp_ck, SerialExecutor)
    t_ck_chunked = _time_executor(
        exp_ck, lambda: AsyncExecutor(chunk_periods=CHUNK))

    # rung 5: K-sweep — padded bucket (ONE cold compile + fused planning)
    # vs per-K serial lowering (one cold compile + one planning pass per
    # fleet size), both at the short interactive horizon
    us = _users_study()
    t0 = time.time()
    res_us = Experiment(data, test, us).run(US_PERIODS)
    t_us_padded = time.time() - t0
    assert res_us.n_buckets == 1
    t0 = time.time()
    for spec in us:
        Experiment(data, test, [spec]).run(US_PERIODS)
    t_us_perk = time.time() - t0

    report = {
        "grid": {"cells": ["/".join(map(str, c)) for c in CELLS],
                 "n_cells": n_cells, "n_seeds": len(SEEDS),
                 "periods": PERIODS, "b_max": BMAX, "hidden": HIDDEN},
        "python_loop_s": t_python,
        "python_loop_extrapolated_from_runs": python_runs,
        "percell_vmap_s": t_percell,
        "bucket_vmap_s": t_bucket,
        "speedup_bucket_vs_percell": t_percell / t_bucket,
        "speedup_bucket_vs_python": t_python / t_bucket,
        "n_buckets": res.n_buckets,
        "multibucket_grid": {
            "hidden": MB_HIDDEN, "partitions": ["iid", "noniid"],
            "n_specs": len(mb), "n_seeds": len(SEEDS),
            "n_buckets": n_mb_buckets, "periods": PERIODS,
            "walls": "best of 2",
        },
        "bucket_serial_s": t_mb_serial,
        "bucket_async_s": t_mb_async,
        "speedup_async_vs_serial": t_mb_serial / t_mb_async,
        "users_sweep": {
            "users": US_USERS, "n_seeds": len(SEEDS),
            "periods": US_PERIODS,
            "hidden": US_HIDDEN, "n_buckets": res_us.n_buckets,
            "walls": "cold (compiles included: 1 padded program vs one "
                     "per fleet size; short interactive horizon)",
        },
        "users_padded_s": t_us_padded,
        "users_per_k_serial_s": t_us_perk,
        "speedup_users_padded_vs_per_k": t_us_perk / t_us_padded,
        "chunked_pipeline": {
            "chunk_periods": CHUNK, "periods": PERIODS,
            "grid": "rung-3 single bucket", "walls": "best of 2",
        },
        "bucket_serial_monolithic_s": t_ck_serial,
        "bucket_chunked_pipelined_s": t_ck_chunked,
        "speedup_chunked_vs_bucket_serial": t_ck_serial / t_ck_chunked,
    }
    with open("BENCH_sweep.json", "w") as f:
        json.dump(report, f, indent=2)

    tag = f"{n_cells}cell_8seed_50p"
    mb_tag = f"{n_mb_buckets}bucket_{len(mb)}cell_8seed_50p"
    us_tag = f"{len(US_USERS)}sizes_8seed_{US_PERIODS}p"
    return [(f"sweep_speed/bucket_vmap_{tag}", t_bucket * 1e6,
             f"wall={t_bucket:.2f}s;buckets={res.n_buckets}"),
            (f"sweep_speed/percell_vmap_{tag}", t_percell * 1e6,
             f"wall={t_percell:.2f}s;"
             f"speedup_bucket={t_percell / t_bucket:.2f}x"),
            (f"sweep_speed/python_loop_{tag}", t_python * 1e6,
             f"wall={t_python:.2f}s(extrap from {python_runs} runs);"
             f"speedup_bucket={t_python / t_bucket:.2f}x"),
            (f"sweep_speed/bucket_async_{mb_tag}", t_mb_async * 1e6,
             f"wall={t_mb_async:.2f}s;serial={t_mb_serial:.2f}s;"
             f"speedup_async={t_mb_serial / t_mb_async:.2f}x"),
            (f"sweep_speed/users_padded_{us_tag}", t_us_padded * 1e6,
             f"wall={t_us_padded:.2f}s;per_k={t_us_perk:.2f}s;"
             f"speedup_padded={t_us_perk / t_us_padded:.2f}x"),
            (f"sweep_speed/chunked_pipeline_{tag}_c{CHUNK}",
             t_ck_chunked * 1e6,
             f"wall={t_ck_chunked:.2f}s;serial={t_ck_serial:.2f}s;"
             f"speedup_chunked={t_ck_serial / t_ck_chunked:.2f}x")]


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

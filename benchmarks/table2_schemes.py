"""Table II — training performance of the four schemes, K=6 and K=12,
IID and non-IID (synthetic data stand-in; scheme ORDERING is the
reproduction target, DESIGN.md §9)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.trainer import run_scheme


def fleet(k):
    tiers = [0.7e9, 1.4e9, 2.1e9]
    return [DeviceProfile(kind="cpu", f_cpu=tiers[i % 3]) for i in range(k)]


def main(fast: bool = True):
    periods = 60 if fast else 400
    n = 2200 if fast else 12000
    rows = []
    for k in ([6] if fast else [6, 12]):
        for part in ["iid", "noniid"]:
            full = ClassificationData.synthetic(n=n, dim=128, seed=0,
                                                spread=6.0)
            data, test = full.split(max(200, n // 10))
            base = None
            for scheme in ["individual", "model_fl", "gradient_fl", "feel"]:
                t0 = time.time()
                r = run_scheme(scheme, fleet(k), data, test, part, periods,
                               eval_every=max(1, periods // 6))
                # training speedup vs individual = inverse ratio of
                # simulated time to a common accuracy target
                target = 0.6
                t_reach = r.speed(target)
                if scheme == "individual":
                    base = t_reach
                speedup = (base / t_reach) if (base and np.isfinite(t_reach)
                                               and np.isfinite(base)) else 0.0
                rows.append((f"table2/K{k}/{part}/{scheme}",
                             (time.time() - t0) * 1e6,
                             f"acc={r.accs[-1]:.4f};simT={r.times[-1]:.1f}s;"
                             f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

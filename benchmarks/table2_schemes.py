"""Table II — training performance of the four schemes, K=6 and K=12,
IID and non-IID (synthetic data stand-in; scheme ORDERING is the
reproduction target, DESIGN.md §9).

Declarative-API driver: the whole (K × partition × scheme) grid is ONE
``Experiment`` — feel/gradient_fl lower to a bucketed FEEL scan per fleet
size, individual/model_fl to the per-device-parameter scan, all seeds and
cells batched along the flattened (scenario × seed) axis — run under
``AsyncExecutor``: the grid spans several shape buckets (FEEL + the two
dev schemes per fleet size), so each bucket's host planning overlaps the
previous bucket's device execution."""
from __future__ import annotations

import time

import numpy as np

from repro.api import AsyncExecutor, Experiment, ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData

SCHEMES = ["individual", "model_fl", "gradient_fl", "feel"]


def fleet(k):
    tiers = [0.7e9, 1.4e9, 2.1e9]
    return tuple(DeviceProfile(kind="cpu", f_cpu=tiers[i % 3])
                 for i in range(k))


def main(fast: bool = True):
    periods = 60 if fast else 400
    n = 2200 if fast else 12000
    seeds = tuple(range(2)) if fast else tuple(range(8))
    target = 0.6
    full = ClassificationData.synthetic(n=n, dim=128, seed=0, spread=6.0)
    data, test = full.split(max(200, n // 10))

    specs = [
        ScenarioSpec(fleet=fleet(k), name=f"K{k}", scheme=scheme,
                     partition=part, policy="proposed", b_max=128,
                     base_lr=0.05, seeds=seeds)
        for k in ([6] if fast else [6, 12])
        for part in ["iid", "noniid"]
        for scheme in SCHEMES]

    t0 = time.time()
    res = Experiment(data, test, specs).run(periods,
                                            executor=AsyncExecutor())
    wall = time.time() - t0

    rows = [("table2/_experiment", wall * 1e6,
             f"rows={res.rows};buckets={res.n_buckets}")]
    for k in ([6] if fast else [6, 12]):
        for part in ["iid", "noniid"]:
            base = None
            for scheme in SCHEMES:
                cell = res.sel(fleet=f"K{k}", partition=part, scheme=scheme)
                t_reach = float(np.median(cell.speed(target)))
                acc = float(cell.final_acc.mean())
                sim_t = float(cell.times[:, -1].mean())
                # training speedup vs individual = inverse ratio of
                # simulated time to a common accuracy target
                if scheme == "individual":
                    base = t_reach
                speedup = (base / t_reach) if (base and np.isfinite(t_reach)
                                               and np.isfinite(base)) else 0.0
                rows.append((f"table2/K{k}/{part}/{scheme}", 0.0,
                             f"acc={acc:.4f};simT={sim_t:.1f}s;"
                             f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

"""Table II — training performance of the four schemes, K=6 and K=12,
IID and non-IID (synthetic data stand-in; scheme ORDERING is the
reproduction target, DESIGN.md §9).

feel/gradient_fl run on the device-resident scan engine via the seed-batched
sweep path; individual/model_fl use the scan-compiled per-device-parameter
trajectory (``run_scheme``)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.sweep import run_seed_batch
from repro.fed.trainer import FeelSimulation, run_scheme


def fleet(k):
    tiers = [0.7e9, 1.4e9, 2.1e9]
    return [DeviceProfile(kind="cpu", f_cpu=tiers[i % 3]) for i in range(k)]


def _feel_speed(devices, data, test, part, policy, periods, seeds,
                target=0.6):
    """Median time-to-target + final acc over a vmapped seed batch."""
    sims = [FeelSimulation(devices, data, test, partition=part,
                           policy=policy, b_max=128, base_lr=0.05, seed=s)
            for s in seeds]
    losses, accs, times, _ = run_seed_batch(sims, periods)
    reach = np.where(accs >= target, times, np.inf).min(axis=1)
    return float(np.median(reach)), float(accs[:, -1].mean()), \
        float(times[:, -1].mean())


def main(fast: bool = True):
    periods = 60 if fast else 400
    n = 2200 if fast else 12000
    seeds = range(2) if fast else range(8)
    target = 0.6
    rows = []
    for k in ([6] if fast else [6, 12]):
        for part in ["iid", "noniid"]:
            full = ClassificationData.synthetic(n=n, dim=128, seed=0,
                                                spread=6.0)
            data, test = full.split(max(200, n // 10))
            base = None
            for scheme in ["individual", "model_fl", "gradient_fl", "feel"]:
                t0 = time.time()
                if scheme in ("feel", "gradient_fl"):
                    policy = "proposed" if scheme == "feel" else "full"
                    t_reach, acc, sim_t = _feel_speed(
                        fleet(k), data, test, part, policy, periods, seeds,
                        target)
                else:
                    # same seed set as the feel schemes so the speedup
                    # ratio compares matched medians
                    runs = [run_scheme(scheme, fleet(k), data, test, part,
                                       periods, seed=s,
                                       eval_every=max(1, periods // 6))
                            for s in seeds]
                    t_reach = float(np.median([r.speed(target)
                                               for r in runs]))
                    acc = float(np.mean([r.accs[-1] for r in runs]))
                    sim_t = float(np.mean([r.times[-1] for r in runs]))
                # training speedup vs individual = inverse ratio of
                # simulated time to a common accuracy target
                if scheme == "individual":
                    base = t_reach
                speedup = (base / t_reach) if (base and np.isfinite(t_reach)
                                               and np.isfinite(base)) else 0.0
                rows.append((f"table2/K{k}/{part}/{scheme}",
                             (time.time() - t0) * 1e6,
                             f"acc={acc:.4f};simT={sim_t:.1f}s;"
                             f"speedup={speedup:.2f}x"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(map(str, r)))

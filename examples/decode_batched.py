"""Batched LLM token-decode demo: prefill a prompt batch then decode
continuations with the KV/SSM cache — the laptop-scale version of the
decode_32k / long_500k dry-run shapes.  Tries one arch per cache family.

This is the model-zoo *decode* demo (``repro.launch.serve`` driver), not
the FEEL experiment service — for streaming scenario requests through a
long-running service see ``repro.serve`` and ``examples/quickstart.py``.

Run:  PYTHONPATH=src python examples/decode_batched.py
"""
from repro.launch import serve as serve_cli

for arch in ["qwen1.5-4b",        # dense GQA: ring-buffer KV cache
             "minicpm3-4b",       # MLA: compressed latent cache
             "mamba2-2.7b",       # SSM: O(1) recurrent state
             "zamba2-7b"]:        # hybrid: SSM state + shared-attn KV
    serve_cli.main(["--arch", arch, "--batch", "2", "--prompt-len", "8",
                    "--gen", "16", "--ctx", "64"])

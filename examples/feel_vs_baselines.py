"""Train a classifier with the full FEEL loop (5 steps per period) under
the proposed scheduler and the paper's baseline schemes, on pathological
non-IID data — a laptop-scale Table II on the declarative API.

One ``Experiment`` declares all four Table-II schemes (× seeds); the
lowering batches every shape-compatible (scheme, seed) row into the same
compiled ``vmap(lax.scan)`` program, and ``AsyncExecutor`` pipelines the
three shape buckets (FEEL family, individual, model_fl) so host planning
overlaps device execution.

Run:  PYTHONPATH=src python examples/feel_vs_baselines.py [--periods N]
"""
import argparse

import numpy as np

from repro.api import AsyncExecutor, Experiment, ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData

ap = argparse.ArgumentParser()
ap.add_argument("--periods", type=int, default=80)
ap.add_argument("--k", type=int, default=6)
ap.add_argument("--seeds", type=int, default=1,
                help="seeds per scheme (vmapped on device)")
args = ap.parse_args()

tiers = [0.7e9, 1.4e9, 2.1e9]
devices = tuple(DeviceProfile(kind="cpu", f_cpu=tiers[i % 3])
                for i in range(args.k))
full = ClassificationData.synthetic(n=2600, dim=128, seed=0, spread=6.0)
data, test = full.split(400)

seeds = tuple(range(args.seeds))
specs = [ScenarioSpec(fleet=devices, name=f"K{args.k}", scheme=scheme,
                      partition="noniid", b_max=128, base_lr=0.05,
                      seeds=seeds)
         for scheme in ["individual", "model_fl", "gradient_fl", "feel"]]
res = Experiment(data, test, specs).run(args.periods,
                                        executor=AsyncExecutor())
print(f"{len(specs)} schemes x {len(seeds)} seeds -> "
      f"{res.n_buckets} compiled programs (async cross-bucket dispatch)\n")

print(f"{'scheme':<14}{'final acc':>10}{'sim time':>10}{'t@60%':>9}")
t60 = {}
for labels, cell in res.cells():
    scheme = labels["scheme"]
    t60[scheme] = float(np.median(cell.speed(0.60)))
    print(f"{scheme:<14}{cell.final_acc.mean():>10.4f}"
          f"{cell.times[:, -1].mean():>9.1f}s"
          f"{t60[scheme] if np.isfinite(t60[scheme]) else float('nan'):>9.1f}")

if np.isfinite(t60["individual"]) and np.isfinite(t60["feel"]):
    print(f"\nproposed scheme speedup vs individual learning: "
          f"{t60['individual'] / t60['feel']:.2f}x "
          f"(paper Table II reports 1.03-1.26x)")

if args.seeds > 1:
    cell = res.sel(scheme="feel")
    print(f"proposed over {args.seeds} vmapped seeds: "
          f"acc={cell.final_acc.mean():.4f}±{cell.final_acc.std():.4f}, "
          f"median t@60%={np.median(cell.speed(0.60)):.1f}s")

"""Train a classifier with the full FEEL loop (5 steps per period) under
the proposed scheduler and the paper's baseline schemes, on pathological
non-IID data — a laptop-scale Table II, on the device-resident engine.

Every scheme's trajectory is one compiled ``lax.scan``; with ``--seeds``
the feel row additionally reports a vmapped multi-seed spread via the
sweep API.

Run:  PYTHONPATH=src python examples/feel_vs_baselines.py [--periods N]
"""
import argparse

import numpy as np

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed.sweep import run_sweep
from repro.fed.trainer import run_scheme

ap = argparse.ArgumentParser()
ap.add_argument("--periods", type=int, default=80)
ap.add_argument("--k", type=int, default=6)
ap.add_argument("--seeds", type=int, default=1,
                help="extra seeds for the proposed-scheme sweep row")
args = ap.parse_args()

tiers = [0.7e9, 1.4e9, 2.1e9]
devices = [DeviceProfile(kind="cpu", f_cpu=tiers[i % 3])
           for i in range(args.k)]
full = ClassificationData.synthetic(n=2600, dim=128, seed=0, spread=6.0)
data, test = full.split(400)

print(f"{'scheme':<14}{'final acc':>10}{'sim time':>10}{'t@60%':>9}")
rows = {}
for scheme in ["individual", "model_fl", "gradient_fl", "feel"]:
    r = run_scheme(scheme, devices, data, test, "noniid", args.periods,
                   eval_every=max(1, args.periods // 8))
    rows[scheme] = r
    t60 = r.speed(0.60)
    print(f"{scheme:<14}{r.accs[-1]:>10.4f}{r.times[-1]:>9.1f}s"
          f"{t60 if np.isfinite(t60) else float('nan'):>9.1f}")

base = rows["individual"].speed(0.60)
feel = rows["feel"].speed(0.60)
if np.isfinite(base) and np.isfinite(feel):
    print(f"\nproposed scheme speedup vs individual learning: "
          f"{base/feel:.2f}x (paper Table II reports 1.03-1.26x)")

if args.seeds > 1:
    cell = run_sweep({"fleet": devices}, data, test,
                     policies=("proposed",), partitions=("noniid",),
                     seeds=range(args.seeds), periods=args.periods
                     )["fleet/noniid/proposed"]
    t60 = cell.speed(0.60)
    print(f"proposed over {args.seeds} vmapped seeds: "
          f"acc={cell.final_acc.mean():.4f}±{cell.final_acc.std():.4f}, "
          f"median t@60%={np.median(t60):.1f}s")

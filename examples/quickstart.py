"""Quickstart: one FEEL training period solved end-to-end, then a tiny
declarative geometry study, then the streaming experiment service.

Part 1 drops K heterogeneous edge devices into a cell, samples the
wireless channel (eq. 5-6), solves 𝒫₁ (Theorems 1+2 / Algorithm 1) and
prints the optimal batchsizes, TDMA slots, and the learning-efficiency
comparison against the paper's baseline policies.  Part 2 declares a
``grid`` study sweeping the wireless cell radius × data partition and
runs it as one compiled program via ``repro.api.Experiment`` — the swept
radius comes back as a named ``Results`` coordinate.  Part 3 sweeps
fleet size.  Part 4 runs the same specs through ``repro.serve``: submit
scenario requests to a long-running service and stream chunked results
back, with warm-cache admissions and preemptive scheduling.  Part 5
leaves the paper's static world: ``repro.dynamics`` drifts the channel
under the planner's feet and shows closed-loop replanning beating the
stale open-loop plan on the realized latency ledger.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.channels.model import Cell
from repro.core import (DeviceProfile, POLICIES, gradient_bits, solve_period)

K = 8
devices = [DeviceProfile(kind="cpu", f_cpu=f * 1e9)
           for f in [0.7, 0.7, 1.0, 1.4, 1.4, 1.8, 2.1, 2.1]]

cell = Cell.make(seed=0)
dist, r_up, r_down = cell.sample_rates(K)
s_bits = gradient_bits(7_000_000)          # DenseNet121-class payload
print(f"payload s = r*d*p = {s_bits/8/1e3:.0f} kB   "
      f"uplink rates = {np.round(r_up/1e6, 1)} Mbps")

sol = solve_period(devices, r_up, r_down, s_bits, 0.010, 0.010,
                   xi=0.05, b_max=128)
print(f"\noptimal global batch B* = {sol.global_batch:.0f}")
print(f"per-device batchsizes B_k* = {np.round(sol.batch, 1)}")
print(f"uplink slots tau_k (ms)    = {np.round(sol.tau_up*1e3, 3)}")
print(f"downlink slots tau_k (ms)  = {np.round(sol.tau_down*1e3, 3)}")
print(f"period latency T = {sol.latency:.3f}s   "
      f"learning efficiency E = {sol.efficiency:.4f}\n")

print(f"{'policy':<10}{'B':>7}{'T (s)':>10}{'E = dL/T':>12}")
for name, pol in POLICIES.items():
    kw = {"rng": np.random.default_rng(0)}
    if name == "proposed":
        kw["xi"] = 0.05
    res = pol(devices, r_up, r_down, s_bits, 0.010, 0.010, 128, **kw)
    eff = 0.05 * np.sqrt(res.global_batch) / res.latency
    print(f"{name:<10}{res.global_batch:>7.0f}{res.latency:>10.3f}"
          f"{eff:>12.4f}")

# ---- part 2: a declarative geometry study ----------------------------------
from repro.api import Experiment, ScenarioSpec, grid      # noqa: E402
from repro.data.pipeline import ClassificationData        # noqa: E402

full = ClassificationData.synthetic(n=900, dim=64, seed=0, spread=6.0)
data, test = full.split(150)
base = ScenarioSpec(fleet=tuple(devices), name="cpu8", policy="proposed",
                    b_max=64, base_lr=0.15, hidden=128, seeds=(0, 1),
                    compression=0.1)   # heavier payload: geometry shows up
                                       # in the latency ledger
study = grid(base, partition=["iid", "noniid"],
             **{"cell.radius_m": [150.0, 400.0]})
results = Experiment(data, test, study).run(periods=20)
print(f"\n{len(study)} cells x 2 seeds lowered to "
      f"{results.n_buckets} compiled program")
for radius in (150.0, 400.0):
    for part in ("iid", "noniid"):
        cell = results.sel(cell_radius_m=radius, partition=part)
        print(f"  r={radius:>5.0f}m {part:<7} final acc "
              f"{cell.final_acc.mean():.3f}±{cell.final_acc.std():.3f}  "
              f"sim time {cell.times[:, -1].mean():.1f}s")

# ---- part 3: fleet size as a sweep axis ------------------------------------
# fleet is non-structural: every K pads into ONE compiled program, and
# the swept size comes back as the num_users coordinate
kstudy = grid(base, users=[2, 4, 8])
kres = Experiment(data, test, kstudy).run(periods=20)
print(f"\nK-sweep {list(kres.unique('num_users'))} lowered to "
      f"{kres.n_buckets} compiled program")
for k in kres.unique("num_users"):
    cell = kres.sel(num_users=k)
    print(f"  K={k}  final acc {cell.final_acc.mean():.3f}"
          f"±{cell.final_acc.std():.3f}  "
          f"sim time {cell.times[:, -1].mean():.1f}s")

# ---- part 4: the streaming experiment service ------------------------------
# instead of a grid known up front, submit ScenarioSpecs to a running
# service over time: arrivals micro-batch into compiled-program groups
# (same bucket_key rule as the static lowering), repeat shapes admit
# warm from the persistent compile cache, and hot requests preempt long
# background horizons at chunk boundaries — the resumable chunked scans
# of PR 5 make a suspended run just parked state, so the preempted run
# finishes bit-identical to an uninterrupted one (test-enforced).
from repro.serve import ExperimentService                 # noqa: E402

svc = ExperimentService(data, test, chunk_periods=5)
background = svc.submit(base, periods=20, priority=5)     # long horizon
svc.step()                        # admitted; first chunk runs
hot = svc.submit(ScenarioSpec(fleet=tuple(devices), name="hot",
                              policy="proposed", b_max=64, base_lr=0.1,
                              hidden=128, seeds=(2, 3), compression=0.1),
                 periods=10, priority=0)   # same program shape: admits
                                           # warm, and preempts
while not (background.done and hot.done):
    svc.step()                        # admit due arrivals + run one chunk
    if not hot.done:
        part = hot.partial()          # complete=False mid-stream view
        print(f"  hot request: {part.losses.shape[1]}/10 periods "
              f"streamed (complete={part.complete})")
print(f"\nservice: {svc.stats.admissions} admissions, "
      f"{svc.stats.preemptions} preemption(s), cache hit rate "
      f"{svc.stats.cache_hit_rate:.0%}, warm-admission traces "
      f"{svc.stats.warm_admission_traces}")
print(f"background final acc {background.result().final_acc.mean():.3f} "
      f"— bit-identical to the uninterrupted Experiment run")

# ---- part 5: dynamic worlds ------------------------------------------------
# the paper plans once against frozen channel statistics; repro.dynamics
# drifts them mid-horizon (a seeded Markov gain ladder multiplying the
# average rates) and replan=R re-prices Algorithm 1 at fresh gains every
# chunk boundary — same spec, one extra field, and the closed loop wins
# on the realized latency ledger while the open loop pays for its stale
# first-period belief
from repro.dynamics import Fading                         # noqa: E402

drift = ScenarioSpec(fleet=tuple(devices), name="drift", policy="proposed",
                     b_max=64, base_lr=0.1, hidden=128, seeds=(3,),
                     fading=Fading(states=3, spread=1.2, stickiness=0.95))
open_run = Experiment(data, test, [drift]).run(periods=8)
closed_run = Experiment(data, test, [drift]).run(periods=8, replan=2)
print(f"\ndrifting channel, 8 periods: open-loop "
      f"{open_run.times[0, -1]:.2f}s vs closed-loop (replan=2) "
      f"{closed_run.times[0, -1]:.2f}s simulated "
      f"({open_run.times[0, -1] / closed_run.times[0, -1]:.2f}x faster "
      f"with fresh-gain replanning)")

"""Deprecated name — this demo moved to ``examples/decode_batched.py``.

It was never the experiment *service* (that is ``repro.serve``); it is
the batched LLM token-decode demo, and the new name says so.  This shim
keeps old invocations working.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import runpy
import sys
import warnings

warnings.warn(
    "examples/serve_batched.py is deprecated: the batched decode demo "
    "is now examples/decode_batched.py (repro.serve is the FEEL "
    "experiment service, a different thing).",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    sys.argv[0] = sys.argv[0].replace("serve_batched.py",
                                      "decode_batched.py")
    runpy.run_module("examples.decode_batched"
                     if __package__ else "decode_batched",
                     run_name="__main__")

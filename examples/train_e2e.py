"""End-to-end training driver: a transformer from the assigned-architecture
zoo trained with the FEEL scheduler in the loop (channel sampling ->
joint batchsize/slot optimization -> weighted eq.(1) aggregation).

Default is laptop-scale (a reduced qwen variant, ~8M params, 150 steps on
synthetic Markov text).  ``--model-100m`` selects a ~100M-param variant
(a few hundred steps is a multi-hour CPU run; on TPU it is minutes).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 150]
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # reparse below

from repro.launch import train as train_cli  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--model-100m", action="store_true")
    ap.add_argument("--policy", default="proposed")
    ap.add_argument("--compress-uplink", action="store_true")
    args, _ = ap.parse_known_args()

    argv = ["--arch", "qwen1.5-4b", "--steps", str(args.steps),
            "--devices", "4", "--slot", "8", "--seq", "64",
            "--policy", args.policy]
    if args.compress_uplink:
        argv.append("--compress-uplink")
    if args.model_100m:
        # a genuine ~100M-param qwen-family variant (12 x d768); a few
        # hundred steps is a multi-hour CPU run, minutes on TPU
        argv += ["--layers", "12", "--d-model", "768", "--seq", "128"]
    loss = train_cli.main(argv)
    print(f"[example] final loss {loss:.4f} — see launch/train.py for the "
          f"production entry point (--full + production mesh on TPU).")


if __name__ == "__main__":
    main()

"""Static analysis over lowered bucket programs.

Three passes walk the closed jaxpr of every bucket program (FEEL and dev
schemes, monolithic and chunked) and turn the repo's example-tested
invariants into all-inputs guarantees:

* :mod:`repro.analysis.taint` — abstract interpretation proving padded
  user lanes are mask-dominated before any cross-user reduction;
* :mod:`repro.analysis.determinism` — lint for non-bit-stable idioms
  (pairwise-unrolled reductions, unseeded cumsum ledgers, PRNG key
  collisions across streams);
* :mod:`repro.analysis.compile_audit` — trace-ledger audit (one trace
  per bucket, zero retraces across chunks/replan rounds), 64-bit leak
  and folded-constant detection on the jaxpr itself.

:mod:`repro.analysis.report` defines the shared finding/report
datamodel; ``python -m repro.analysis.audit`` sweeps the benchmark
grids and writes ``AUDIT_report.json``.
"""
from repro.analysis.report import (AuditError, AuditReport, Finding,
                                   Severity)

__all__ = ["AuditError", "AuditReport", "Finding", "Severity"]

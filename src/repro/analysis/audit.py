"""Benchmark-grid audit CLI: ``python -m repro.analysis.audit``.

Sweeps the repo's benchmark program families through every static pass
and writes a machine-readable ``AUDIT_report.json``:

* **taint + hygiene** over the lowered bucket program of every grid
  cell: the four Table-II schemes (feel/gradient_fl at both compression
  settings, individual, model_fl), the ragged padded-fleet program
  (``--users``), the ``local_steps > 1`` delta-upload variant, the
  per-round-sampled (time-varying participation mask) programs on both
  engines, the hierarchical cell→edge→cloud family (alone and composed
  with sampling), the K-banded sub-bucketed sweep, the PR-9 dynamics
  families (drifting block-fading channels, straggler/dropout faults,
  energy-budget shedding — alone and composed with sampling), and the
  PR-10 big-model families (transformer / Mamba-2 train-step scans,
  SBC-compressed and dense uploads);
* **trace ledger** over a real chunked closed-loop run
  (``Experiment.run(replan=R, audit=True)``) — proving one trace per
  (bucket, chunk-length) program and zero retraces across replan
  rounds, while also exercising the ``audit=True`` hook end to end;
* **determinism lint** over the library sources.

Exit status 1 iff any error-severity finding survives.  Shapes are
deliberately tiny (the passes certify *programs*, which are shape-
polymorphic in everything but rank), so the sweep is CI-cheap.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.analysis import compile_audit, determinism, taint
from repro.analysis.report import AuditReport
from repro.api import ScenarioSpec, SerialExecutor
from repro.api.experiment import Experiment
from repro.api.lowering import group_rows, plan_bucket, trace_bucket
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.dynamics import EnergyBudget, Fading, Faults
from repro.fed import engine
from repro.topology import Sampling, Topology


def _fleet(k: int):
    return tuple(DeviceProfile(kind="cpu", f_cpu=(0.7 + 0.35 * (i % 3)) * 1e9)
                 for i in range(k))


def _spec(k: int, **kw) -> ScenarioSpec:
    kw.setdefault("name", f"K{k}")
    kw.setdefault("b_max", 12)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", 16)
    kw.setdefault("seeds", (0,))
    return ScenarioSpec(fleet=_fleet(k), **kw)


def _grid_specs(users):
    """The audited program families (one spec list per labeled grid)."""
    k = users[0]
    return {
        # Table II: feel == gradient_fl+SBC; gradient_fl (uncompressed
        # upload) is the compress=False program family
        "schemes": [
            _spec(k, scheme="feel"),
            _spec(k, scheme="feel", compress=False),
            _spec(k, scheme="individual"),
            _spec(k, scheme="model_fl"),
        ],
        # the ragged padded-fleet program: one bucket, k_pad = max(users)
        "ragged": [_spec(u, scheme="feel") for u in users],
        # tau > 1 local SGD (delta uploads must cancel on padded lanes)
        "local-steps": [_spec(k, scheme="feel", local_steps=2)],
        # per-round S-of-K participation: the time-varying (n, P, K)
        # active mask must dominate every cross-user reduction exactly
        # like the static padding mask it generalizes — on BOTH engines
        "sampled": [_spec(u, scheme="feel", sampling=Sampling(size=2))
                    for u in users]
                   + [_spec(k, scheme="individual",
                            sampling=Sampling(size=2)),
                      _spec(k, scheme="model_fl",
                            sampling=Sampling(size=2))],
        # cell→edge→cloud hierarchy: the "hier" program family (member
        # routing one-hots, cloud-cadence merges), plus its composition
        # with per-round sampling
        "hier": [_spec(k, scheme="feel",
                       topology=Topology(cells=2, edges=2, agg_every=2)),
                 _spec(k, scheme="feel", sampling=Sampling(size=2),
                       topology=Topology(cells=2, edges=2, agg_every=2))],
        # K-banded sub-bucketing: the ragged sweep again, one program
        # per power-of-two band (group_rows(..., bands=True) below)
        "banded": [_spec(u, scheme="feel", sampling=Sampling(fraction=0.5))
                   for u in users],
        # dynamics (PR 9): drifting block-fading channels — structural
        # via the Markov state count — alone and composed with sampling
        "fading": [_spec(k, scheme="feel",
                         fading=Fading(states=3, spread=0.8)),
                   _spec(k, scheme="feel", sampling=Sampling(size=2),
                         fading=Fading(states=3, spread=0.8))],
        # straggler slowdowns + mid-horizon dropout: the config-static
        # time-varying mask must dominate reductions like sampling's
        "faults": [_spec(u, scheme="feel",
                         faults=Faults(slow_prob=0.3, drop_prob=0.2))
                   for u in users],
        # per-user energy budgets: post-solve shedding is one more
        # participation mask through the same active machinery
        "energy": [_spec(k, scheme="feel",
                         energy=EnergyBudget(budget_j=0.5)),
                   _spec(k, scheme="feel", sampling=Sampling(size=2),
                         energy=EnergyBudget(budget_j=0.5),
                         faults=Faults(slow_prob=0.2, drop_prob=0.2))],
        # big-model train steps (PR 10): the transformer / mamba2 program
        # families — SBC-compressed and dense uploads, plus composition
        # with per-round sampling — certify like the MLP scan they mirror
        "models": [_spec(k, scheme="feel", model_family="transformer"),
                   _spec(k, scheme="feel", model_family="mamba2"),
                   _spec(k, scheme="feel", model_family="transformer",
                         compress=False),
                   _spec(k, scheme="feel", model_family="mamba2",
                         sampling=Sampling(size=2))],
    }


def _audit_static(report: AuditReport, data, test, users, periods: int):
    """Taint + jaxpr hygiene over every grid cell's bucket program."""
    for grid, specs in _grid_specs(users).items():
        for bucket in group_rows(specs, bands=(grid == "banded")):
            plan = plan_bucket(bucket, data, periods)
            traced = trace_bucket(plan, data, test)
            program = f"{grid}:{traced.program}"
            taint.analyze_jaxpr(traced.closed, traced.in_labels,
                                traced.out_contracts, program=program,
                                report=report)
            compile_audit.audit_jaxpr_hygiene(traced.closed,
                                              program=program,
                                              report=report)


def _audit_chunked_run(report: AuditReport, data, test, periods: int,
                       replan: int):
    """A real chunked closed-loop run, trace-audited end to end."""
    specs = [_spec(3, scheme="feel", seeds=(0, 1)),
             _spec(3, scheme="individual")]
    mark = len(engine.trace_events())
    res = Experiment(data, test, specs).run(
        periods=periods, executor=SerialExecutor(), replan=replan,
        audit=True)
    run_report = res.audit
    # fold the hook's findings in under distinct labels
    for f in run_report.findings:
        report.findings.append(f)
    for k, v in run_report.programs.items():
        report.programs[f"replan-run:{k}"] = v
    events = engine.trace_events()[mark:]
    compile_audit.audit_traces(
        events, label=f"chunked-replan={replan}", report=report)
    return len(events)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="static padding-taint / determinism / compile-hygiene "
                    "audit over the benchmark bucket programs")
    ap.add_argument("--out", default="AUDIT_report.json",
                    help="report path (default: %(default)s)")
    ap.add_argument("--users", default="4,8,16",
                    help="ragged fleet sizes, comma-separated "
                         "(default: %(default)s)")
    ap.add_argument("--periods", type=int, default=3,
                    help="horizon length for probed programs "
                         "(default: %(default)s)")
    ap.add_argument("--replan", type=int, default=2,
                    help="closed-loop chunk length for the trace-audited "
                         "run (default: %(default)s)")
    ap.add_argument("--skip-run", action="store_true",
                    help="skip the executed chunked-run trace audit "
                         "(static passes only)")
    args = ap.parse_args(argv)
    users = sorted(int(u) for u in args.users.split(","))

    full = ClassificationData.synthetic(n=220, dim=12, seed=0, spread=6.0)
    data, test = full.split(60)

    report = AuditReport()
    _audit_static(report, data, test, users, args.periods)
    if not args.skip_run:
        try:
            _audit_chunked_run(report, data, test, args.periods,
                               args.replan)
        except Exception as exc:  # an AuditError already carries findings
            from repro.analysis.report import AuditError, Severity
            if not isinstance(exc, AuditError):
                report.add("compile.run-failed", Severity.ERROR,
                           "chunked-replan-run", repr(exc))
    determinism.lint_sources(report=report)

    report.write(args.out)
    print(report.summary())
    for name, prog in sorted(report.programs.items()):
        certified = prog.get("n_certified_reductions")
        extra = f", certified={certified}" if certified is not None else ""
        print(f"  [{'ok' if prog.get('ok') else 'FAIL'}] {name}"
              f" ({prog['pass']}{extra})")
    for f in report.errors():
        print(f"  ERROR {f.check} @ {f.where}: {f.detail}")
    print(f"wrote {args.out}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

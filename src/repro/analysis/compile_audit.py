"""Compile-hygiene audit: trace discipline + lowering hygiene.

Two surfaces:

* **Trace ledger** (:func:`audit_traces`) — consumes the engine's
  structured :func:`repro.fed.engine.trace_events` ledger and proves the
  one-trace-per-bucket contract: no (kind, cache-key, arg-signature)
  triple ever traces twice.  Chunked horizons legitimately trace once
  per distinct chunk *length* (different shapes → different programs);
  a duplicate triple is a retrace the jit cache should have absorbed —
  e.g. an argument donated/committed differently per call, or a
  non-hashable static arg defeating ``lru_cache``.
* **Jaxpr hygiene** (:func:`audit_jaxpr_hygiene`) — walks a lowered
  program (recursing into scan/pjit/custom-call sub-jaxprs) and flags
  (a) 64-bit dtypes anywhere in the program — host planners work in
  float64 and must cross ``engine.host_to_device`` before dispatch —
  and (b) large constants folded into the jaxpr (captured arrays compile
  into the executable and defeat donation/caching; datasets must be
  passed as arguments).
"""
from __future__ import annotations

from collections import Counter
from typing import Optional

import numpy as np
from jax.core import ClosedJaxpr

from repro.analysis.report import AuditReport, Severity

__all__ = ["audit_traces", "audit_jaxpr_hygiene", "iter_subjaxprs"]

# one 64-bit scalar is harmless; a folded dataset is not
_CONST_ELEMENT_LIMIT = 4096


def audit_traces(events=None, *, label: str = "trace-ledger",
                 expect_total: Optional[int] = None,
                 report: Optional[AuditReport] = None) -> AuditReport:
    """Audit a trace-event ledger for retraces.

    ``events`` defaults to the engine's full process ledger; pass a
    slice (``engine.trace_events()[mark:]``) to audit one run.
    ``expect_total`` additionally pins the exact number of traces (the
    per-Experiment contract: one per (bucket, chunk-length) program).
    """
    if report is None:
        report = AuditReport()
    if events is None:
        from repro.fed import engine
        events = engine.trace_events()
    counts = Counter(events)
    n_dup = 0
    for ev, n in counts.items():
        if n > 1:
            n_dup += n - 1
            report.add(
                "compile.retrace", Severity.ERROR, f"{label}:{ev.kind}",
                f"program {ev.kind}{ev.key} traced {n}x for identical "
                f"argument signature — the jit cache should have "
                f"absorbed {n - 1} of these; signature={ev.signature}")
    if expect_total is not None and len(events) != expect_total:
        report.add(
            "compile.trace-count", Severity.ERROR, label,
            f"expected exactly {expect_total} trace(s), ledger has "
            f"{len(events)}: {[(e.kind, e.key) for e in events]}")
    report.programs[label] = {
        "pass": "compile",
        "n_traces": len(events),
        "n_unique_programs": len(counts),
        "n_retraces": n_dup,
        "ok": n_dup == 0 and (expect_total is None
                              or len(events) == expect_total),
    }
    return report


def iter_subjaxprs(jaxpr, path: str = ""):
    """Yield (path, jaxpr) for a jaxpr and every nested sub-jaxpr."""
    yield path, jaxpr
    for i, eqn in enumerate(jaxpr.eqns):
        for key, val in eqn.params.items():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for j, v in enumerate(vals):
                inner = None
                if isinstance(v, ClosedJaxpr):
                    inner = v.jaxpr
                elif hasattr(v, "eqns") and hasattr(v, "invars"):
                    inner = v
                if inner is not None:
                    sub = f"{path}/{i}:{eqn.primitive.name}.{key}"
                    if len(vals) > 1:
                        sub += f"[{j}]"
                    yield from iter_subjaxprs(inner, sub)


def _closed_consts(jaxpr):
    """(path, const) pairs for every ClosedJaxpr constant in the tree."""
    stack = [("", jaxpr)]
    while stack:
        path, cj = stack.pop()
        if isinstance(cj, ClosedJaxpr):
            for i, c in enumerate(cj.consts):
                yield f"{path}.consts[{i}]", c
            inner = cj.jaxpr
        else:
            inner = cj
        for j, eqn in enumerate(inner.eqns):
            for key, val in eqn.params.items():
                vals = val if isinstance(val, (tuple, list)) else (val,)
                for v in vals:
                    if isinstance(v, ClosedJaxpr):
                        stack.append(
                            (f"{path}/{j}:{eqn.primitive.name}.{key}", v))


def audit_jaxpr_hygiene(closed: ClosedJaxpr, *, program: str = "program",
                        report: Optional[AuditReport] = None) -> AuditReport:
    """64-bit-leak and folded-constant audit over one lowered program."""
    if report is None:
        report = AuditReport()
    n_wide = 0
    n_vals = 0
    for path, jaxpr in iter_subjaxprs(closed.jaxpr):
        for var in (*jaxpr.invars, *jaxpr.constvars,
                    *(v for eqn in jaxpr.eqns for v in eqn.outvars)):
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is None:
                continue
            n_vals += 1
            dt = np.dtype(dtype)
            if dt.itemsize == 8 and dt.kind in "fiuc":
                n_wide += 1
                report.add(
                    "compile.x64-leak", Severity.ERROR,
                    f"{program}:{path or '/'}",
                    f"{dt} value inside the device program "
                    f"(shape {tuple(aval.shape)}) — host float64 planning "
                    "leaked past engine.host_to_device")
    n_large = 0
    for path, const in _closed_consts(closed):
        size = int(np.size(const))
        if size > _CONST_ELEMENT_LIMIT:
            n_large += 1
            nbytes = getattr(const, "nbytes", size * 8)
            report.add(
                "compile.folded-constant", Severity.WARN,
                f"{program}:{path}",
                f"constant of {size} elements ({nbytes} bytes) folded "
                "into the jaxpr — pass large arrays as arguments so "
                "they are donated/shared, not baked into the executable")
    report.programs[f"{program}/hygiene"] = {
        "pass": "compile",
        "n_values_checked": n_vals,
        "n_x64_leaks": n_wide,
        "n_large_constants": n_large,
        "ok": n_wide == 0,
    }
    return report

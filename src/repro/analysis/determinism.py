"""Determinism lint: non-bit-stable idioms the repo has been burned by.

Three AST-level rules over the library sources (device programs are
covered by the taint/compile passes; this pass guards the HOST planning
code, whose numerics are part of the bit-exactness contract):

* ``det.pairwise-sum`` — in modules that define the strictly-sequential
  ``_ssum`` row reduction (PR 4: ``np.sum`` pairwise-splits long axes,
  so a padded row's sum need not bit-match the unpadded row's),
  any other ``np.sum`` call is suspect.
* ``det.unseeded-cumsum`` — ``np.cumsum(x) + offset`` is not
  bit-identical to the seeded ``np.cumsum(concatenate([[offset], x]))``
  form (PR 5: float addition is non-associative); chunked ledgers must
  use the seeded form.
* ``det.prng-stream-collision`` — distinct rng *streams* (channel
  fading, batch sampling, scheduler jitter) constructed from the same
  seed expression are correlated.  Advisory (WARN): the repo's existing
  collisions are frozen into bit-exact expectations, so the lint
  documents rather than breaks them; new streams should derive distinct
  seeds (e.g. ``seed + 1`` as ``FeelScheduler`` does).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Optional

from repro.analysis.report import AuditReport, Severity

__all__ = ["lint_sources"]

# modules whose rng streams must be mutually independent (they interleave
# in one simulation): channel draws, batch sampling, scheduler jitter
_PRNG_COUPLED = ("channels/model.py", "core/scheduler.py",
                 "data/pipeline.py", "fed/engine.py")


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _is_np_sum(node) -> bool:
    return (isinstance(node, ast.Call) and _call_name(node) == "sum"
            and isinstance(node.func, ast.Attribute)
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id in ("np", "numpy"))


def _is_cumsum(node) -> bool:
    return isinstance(node, ast.Call) and _call_name(node) == "cumsum"


def _norm_seed_expr(expr: ast.AST) -> str:
    """Normalize a seed expression: ``self.seed`` / ``args.seed`` and the
    bare ``seed`` are the same stream source."""
    text = ast.unparse(expr)
    for prefix in ("self.", "args.", "cfg.", "spec."):
        text = text.replace(prefix, "")
    return text


class _Walker(ast.NodeVisitor):
    """AST walk tracking the enclosing class/function qualname."""

    def __init__(self):
        self.stack = []
        self.sites = []  # (qualname, node)

    def visit_scoped(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = visit_ClassDef = \
        visit_scoped

    def generic_visit(self, node):
        self.sites.append((".".join(self.stack), node))
        super().generic_visit(node)


def _scoped_nodes(tree):
    w = _Walker()
    w.visit(tree)
    return w.sites


def _lint_file(path: Path, rel: str, report: AuditReport, prng_sites: dict):
    tree = ast.parse(path.read_text(), filename=str(path))
    sites = _scoped_nodes(tree)
    defines_ssum = any(isinstance(n, ast.FunctionDef) and n.name == "_ssum"
                       for _, n in sites)
    for qual, node in sites:
        # rule 1: np.sum in an _ssum-disciplined module
        if defines_ssum and _is_np_sum(node) and "_ssum" not in qual:
            report.add(
                "det.pairwise-sum", Severity.WARN,
                f"{rel}:{node.lineno}",
                f"np.sum in {qual or '<module>'}: this module sums over "
                "padded fleet axes and must use the strictly-sequential "
                "_ssum (np.sum pairwise-splits long axes; padded rows "
                "would stop bit-matching solo rows)")
        # rule 2: cumsum + offset instead of seeded cumsum
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add) \
                and (_is_cumsum(node.left) or _is_cumsum(node.right)):
            report.add(
                "det.unseeded-cumsum", Severity.ERROR,
                f"{rel}:{node.lineno}",
                f"cumsum(x) + offset in {qual or '<module>'}: float "
                "addition is non-associative — chunked ledgers must seed "
                "the cumsum (np.cumsum(concatenate([[offset], x]))[1:]) "
                "to stay bit-identical to the monolithic ledger")
        # rule 3 collection: default_rng seed expressions in coupled files
        if any(rel.endswith(m) for m in _PRNG_COUPLED) \
                and isinstance(node, ast.Call) \
                and _call_name(node) == "default_rng" and node.args:
            seed = _norm_seed_expr(node.args[0])
            prng_sites.setdefault(seed, []).append(
                (rel, node.lineno, qual or "<module>"))


def lint_sources(root=None,
                 report: Optional[AuditReport] = None) -> AuditReport:
    """Run the determinism lint over the library sources.

    ``root`` defaults to the installed ``repro`` package directory.
    Findings accumulate into ``report`` (a fresh one when None); a
    summary lands in ``report.programs["determinism-lint"]``.
    """
    if report is None:
        report = AuditReport()
    if root is None:
        import repro
        root = Path(list(repro.__path__)[0])
    root = Path(root)
    prng_sites: dict = {}
    n_files = 0
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root.parent).as_posix()
        if "/analysis/" in rel or "/testing/" in rel:
            continue  # the analyzers themselves are out of scope
        n_files += 1
        _lint_file(path, rel, report, prng_sites)
    n_collisions = 0
    for seed, sites in sorted(prng_sites.items()):
        scopes = {(rel, qual) for rel, _, qual in sites}
        if len(scopes) < 2:
            continue
        n_collisions += 1
        listing = ", ".join(f"{rel}:{line} ({qual})"
                            for rel, line, qual in sites)
        report.add(
            "det.prng-stream-collision", Severity.WARN,
            sites[0][0] + f":{sites[0][1]}",
            f"{len(sites)} rng streams derive from the same seed "
            f"expression {seed!r}: {listing} — streams are correlated; "
            "new streams should derive a distinct seed (cf. "
            "FeelScheduler's seed + 1)")
    report.programs["determinism-lint"] = {
        "pass": "determinism",
        "n_files": n_files,
        "n_prng_collision_groups": n_collisions,
        "ok": not any(f.severity is Severity.ERROR
                      for f in report.findings
                      if f.check.startswith("det.")),
    }
    return report

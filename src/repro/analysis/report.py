"""Shared finding/report datamodel for the static-analysis passes.

Every pass emits :class:`Finding` rows into an :class:`AuditReport`;
severities split machine-enforceable errors (taint escapes, retraces,
64-bit leaks) from advisory warnings (PRNG stream collisions) and
informational notes (assumptions the proofs rest on).  The report
serializes to the ``AUDIT_report.json`` schema the CI job uploads.
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass, field


class Severity(str, enum.Enum):
    """How bad a finding is.

    ``ERROR`` findings fail the audit (CLI exit 1, ``AuditError`` under
    ``Experiment.run(audit=True)``); ``WARN`` is advisory; ``INFO``
    records proof assumptions and certificate statistics.
    """
    ERROR = "error"
    WARN = "warn"
    INFO = "info"


@dataclass(frozen=True)
class Finding:
    """One analysis result row.

    ``check``   — machine name of the rule (e.g. ``taint.unmasked-reduction``)
    ``severity``— :class:`Severity`
    ``where``   — program path to the site (eqn trail, file:line, ...)
    ``detail``  — human-readable explanation
    """
    check: str
    severity: Severity
    where: str
    detail: str

    def to_json(self) -> dict:
        return {"check": self.check, "severity": self.severity.value,
                "where": self.where, "detail": self.detail}


class AuditError(RuntimeError):
    """Raised when an audit surfaces error-severity findings."""

    def __init__(self, report: "AuditReport"):
        self.report = report
        lines = [f"  [{f.severity.value}] {f.check} @ {f.where}: {f.detail}"
                 for f in report.errors()]
        super().__init__(
            f"audit failed with {len(report.errors())} error finding(s):\n"
            + "\n".join(lines))


@dataclass
class AuditReport:
    """Findings from one or more passes over one or more programs.

    ``programs`` maps a program label (e.g. the bucket key) to its
    per-program summary dict (certified reduction counts, trace totals,
    ...); ``findings`` is the flat finding list across all programs.
    """
    findings: list = field(default_factory=list)
    programs: dict = field(default_factory=dict)

    def add(self, check: str, severity: Severity, where: str,
            detail: str) -> None:
        self.findings.append(Finding(check, severity, where, detail))

    def extend(self, other: "AuditReport") -> None:
        self.findings.extend(other.findings)
        self.programs.update(other.programs)

    def errors(self) -> list:
        return [f for f in self.findings if f.severity is Severity.ERROR]

    def warnings(self) -> list:
        return [f for f in self.findings if f.severity is Severity.WARN]

    @property
    def ok(self) -> bool:
        """True iff no error-severity findings."""
        return not self.errors()

    def raise_on_error(self) -> "AuditReport":
        if not self.ok:
            raise AuditError(self)
        return self

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "n_errors": len(self.errors()),
            "n_warnings": len(self.warnings()),
            "programs": self.programs,
            "findings": [f.to_json() for f in self.findings],
        }

    def write(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")

    def summary(self) -> str:
        """One-line human summary for CLI output."""
        status = "OK" if self.ok else "FAIL"
        return (f"audit {status}: {len(self.programs)} program(s), "
                f"{len(self.errors())} error(s), "
                f"{len(self.warnings())} warning(s), "
                f"{len(self.findings)} finding(s) total")

"""Padding-taint abstract interpretation over closed jaxprs.

The ragged-fleet contract (PR 4) pads every bucket's user axis to a
common ``k_pad`` and promises padded lanes never influence active rows.
The test suite checks this for specific grids; this pass proves it for
*all inputs* by abstract interpretation of the lowered program.

Abstract domain
---------------
Each value gets an :class:`AbsVal`:

* ``digits`` — which output axes are user-lane structured.  A
  :class:`Digit` ``(axis, sub_stride, extent)`` survives reshapes that
  merge the user axis with others (e.g. ``(K, slot) -> (K*slot,)``): the
  lane of flat coordinate ``c`` is ``(c // sub_stride) % extent``.
* ``lanes`` — what padded-lane elements hold: :class:`Known` (a concrete
  scalar, evaluated through every primitive), :class:`Same` (elementwise
  equal to another value's elements — how parameter deltas cancel to
  zero in the ``local_steps > 1`` path), or :data:`VARIANT` (arbitrary
  finite values).
* ``const`` — whole-array constant scalar, for concrete folding.
* ``poison`` — violation tags that have influenced this value.

The theorem per reduction site: a cross-user reduction is mask-dominated
iff the abstract padded-lane value is the **identity of its monoid**
(``sum``↔0, ``max``↔-inf, ``and``↔True, ...); a ``dot_general``
contraction over the user axis is safe iff either side's padded lanes
are ``Known(0)``.  Everything else that would let a padded lane reach an
active output (gathers indexing along the user axis, scatters writing
across lanes) is flagged at the site.

Stated assumptions (recorded as INFO findings on every certificate):
padded-lane inputs are finite (``0 * x == 0`` needs ``x`` finite — the
engine's schedules guarantee this) and index-typed padded lanes are
in-bounds (``pad_schedule`` writes index 0).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np
from jax.core import ClosedJaxpr, Jaxpr, Literal

from repro.analysis.report import AuditReport, Severity

__all__ = ["LaneLabel", "OutContract", "AbsVal", "Digit", "Known", "Same",
           "VARIANT", "analyze_jaxpr"]


# ---------------------------------------------------------------------------
# abstract domain
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Digit:
    """One user-lane-structured axis of a value.

    ``lane(coord) = (coord // sub_stride) % extent`` — ``sub_stride`` and
    ``extent`` keep lane identity through axis merges; a plain user axis
    is ``Digit(axis, 1, K)``.
    """
    axis: int
    sub_stride: int
    extent: int


@dataclass(frozen=True)
class Known:
    """Padded lanes hold exactly this scalar (tracked concretely)."""
    value: object

    def __repr__(self):
        return f"Known({self.value})"


@dataclass(frozen=True)
class Same:
    """Padded lanes equal the corresponding elements of value ``ref``."""
    ref: object  # a jaxpr Var (identity compared)

    def __hash__(self):
        return hash(id(self.ref))

    def __eq__(self, other):
        return isinstance(other, Same) and self.ref is other.ref


class _Variant:
    def __repr__(self):
        return "VARIANT"


VARIANT = _Variant()


@dataclass(frozen=True)
class AbsVal:
    """Abstract value: lane structure + padded-lane contents + constness."""
    digits: tuple = ()          # tuple[Digit], sorted by axis
    lanes: object = None        # Known | Same | VARIANT; None iff no digits
    const: object = None        # scalar if the whole array is constant
    poison: frozenset = frozenset()

    @property
    def marked(self) -> bool:
        return bool(self.digits)

    def digit_axes(self):
        return {d.axis for d in self.digits}


CLEAN = AbsVal()


def _known_zero(lanes) -> bool:
    return isinstance(lanes, Known) and not np.any(np.asarray(lanes.value))


def _join_lanes(a, b):
    if a == b:
        return a
    return VARIANT


def _join(a: AbsVal, b: AbsVal) -> AbsVal:
    """Least upper bound (used for scan/while carries and cond joins)."""
    poison = a.poison | b.poison
    if not a.marked and not b.marked:
        const = a.const if (a.const is not None and a.const == b.const) \
            else None
        return AbsVal(const=const, poison=poison)
    digits = {}
    for d in a.digits + b.digits:
        prev = digits.get(d.axis)
        if prev is None or prev == d:
            digits[d.axis] = d
        else:  # geometry disagreement: widen to full coverage
            digits[d.axis] = Digit(d.axis, 1, 0)
    la = a.lanes if a.marked else (Known(a.const) if a.const is not None
                                   else VARIANT)
    lb = b.lanes if b.marked else (Known(b.const) if b.const is not None
                                   else VARIANT)
    return AbsVal(digits=tuple(sorted(digits.values(),
                                      key=lambda d: d.axis)),
                  lanes=_join_lanes(la, lb), poison=poison)


# ---------------------------------------------------------------------------
# concrete evaluation of Known lanes through primitives
# ---------------------------------------------------------------------------

_UNARY_NP = {
    "neg": np.negative, "abs": np.abs, "sign": np.sign, "floor": np.floor,
    "ceil": np.ceil, "round": np.rint, "exp": np.exp, "exp2": np.exp2,
    "expm1": np.expm1, "log": np.log, "log1p": np.log1p, "sqrt": np.sqrt,
    "rsqrt": lambda x: 1.0 / np.sqrt(x), "cbrt": np.cbrt, "tanh": np.tanh,
    "sin": np.sin, "cos": np.cos, "tan": np.tan,
    "logistic": lambda x: 1.0 / (1.0 + np.exp(-x)),
    "is_finite": np.isfinite, "not": np.logical_not,
    "erf": lambda x: np.vectorize(__import__("math").erf)(x),
    "square": np.square, "real": np.real, "imag": np.imag,
}

_BINARY_NP = {
    "add": np.add, "add_any": np.add, "sub": np.subtract,
    "mul": np.multiply, "div": np.divide, "pow": np.power,
    "max": np.maximum, "min": np.minimum, "rem": np.fmod,
    "atan2": np.arctan2, "nextafter": np.nextafter,
    "and": np.logical_and, "or": np.logical_or, "xor": np.logical_xor,
    "lt": np.less, "le": np.less_equal, "gt": np.greater,
    "ge": np.greater_equal, "eq": np.equal, "ne": np.not_equal,
    "shift_left": np.left_shift, "shift_right_logical": np.right_shift,
    "shift_right_arithmetic": np.right_shift,
}

# monoid identities: reduce primitive -> identity check on scalar c
_REDUCE_IDENTITY = {
    "reduce_sum": lambda c, dt: float(c) == 0.0,
    "reduce_prod": lambda c, dt: float(c) == 1.0,
    "reduce_max": lambda c, dt: (bool(c) is False if dt.kind == "b" else
                                 (np.isneginf(c) if dt.kind == "f" else
                                  c == np.iinfo(dt).min)),
    "reduce_min": lambda c, dt: (bool(c) is True if dt.kind == "b" else
                                 (np.isposinf(c) if dt.kind == "f" else
                                  c == np.iinfo(dt).max)),
    "reduce_and": lambda c, dt: bool(c) is True,
    "reduce_or": lambda c, dt: bool(c) is False,
    "argmax": lambda c, dt: False,   # order-sensitive: never identity
    "argmin": lambda c, dt: False,
}

_REDUCE_FOLD = {
    # padded-lane value after reducing n elements each holding c over a
    # NON-user axis
    "reduce_sum": lambda c, n: c * n,
    "reduce_prod": lambda c, n: c ** n,
    "reduce_max": lambda c, n: c,
    "reduce_min": lambda c, n: c,
    "reduce_and": lambda c, n: c,
    "reduce_or": lambda c, n: c,
}

_IDENTITY_PRIMS = {"stop_gradient", "copy", "reduce_precision",
                   "device_put", "sharding_constraint", "optimization_barrier"}


def _np_scalar(x, dtype=None):
    a = np.asarray(x)
    if dtype is not None:
        a = a.astype(dtype)
    return a[()] if a.ndim == 0 else a


# ---------------------------------------------------------------------------
# labels / contracts (the analysis API surface)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LaneLabel:
    """Input label: ``axis`` is the user axis of this (flattened) input.

    ``lanes`` is what padded lanes hold: a scalar (``Known``) or the
    string ``"variant"`` (arbitrary — e.g. schedule indices, whose
    masking the program must therefore re-establish itself).
    ``axis=None`` marks an unlabeled input.
    """
    axis: Optional[int] = None
    lanes: object = "variant"


NO_LABEL = LaneLabel(axis=None)


@dataclass(frozen=True)
class OutContract:
    """Output contract: padded lanes of ``axis`` must be Known(``value``).

    Used for carry outputs that feed the next chunk (the SBC residual):
    proving the contract at the output IS the inductive step that makes
    the certificate hold across chunked/replanned horizons.
    """
    axis: int
    value: object = 0.0


# ---------------------------------------------------------------------------
# the interpreter
# ---------------------------------------------------------------------------


class _Interp:
    def __init__(self, report: AuditReport, program: str):
        self.report = report
        self.program = program
        self.assumptions = set()
        self.n_eqns = 0
        self.n_certified = 0   # mask-dominated cross-user reductions proven
        self.recording = True  # off during scan/while fixpoint warm-up
        self.alias = {}        # var -> canonical var (element-equal values)

    # -- bookkeeping --------------------------------------------------------

    def _finding(self, check, where, detail):
        if self.recording:
            self.report.add(check, Severity.ERROR,
                            f"{self.program}:{where}", detail)
        return frozenset([f"{check}@{where}"])

    def _assume(self, text):
        self.assumptions.add(text)

    def canon(self, v):
        while v in self.alias:
            v = self.alias[v]
        return v

    # -- env helpers --------------------------------------------------------

    def read(self, env, v) -> AbsVal:
        if isinstance(v, Literal):
            val = np.asarray(v.val)
            return AbsVal(const=_np_scalar(val) if val.ndim == 0 else None)
        return env.get(v, CLEAN)

    def lane_of(self, v, a: AbsVal):
        """This operand's contribution to padded-lane elements."""
        if a.marked:
            return a.lanes
        if a.const is not None:
            return Known(a.const)
        if isinstance(v, Literal):
            return VARIANT  # array literal: arbitrary data at lane coords
        return Same(self.canon(v))

    # -- main walk ----------------------------------------------------------

    def run_jaxpr(self, jaxpr: Jaxpr, in_vals, path: str):
        env = {}
        assert len(jaxpr.invars) == len(in_vals), \
            f"{path}: invar arity {len(jaxpr.invars)} != {len(in_vals)}"
        for var, val in zip(jaxpr.invars, in_vals):
            env[var] = val
        for cv in jaxpr.constvars:
            env[cv] = CLEAN
        for i, eqn in enumerate(jaxpr.eqns):
            self.n_eqns += 1
            outs = self.eval_eqn(eqn, env, f"{path}/{i}:{eqn.primitive.name}")
            for ov, o in zip(eqn.outvars, outs):
                env[ov] = o
        return [self.read(env, v) for v in jaxpr.outvars]

    # -- per-equation dispatch ----------------------------------------------

    def eval_eqn(self, eqn, env, where):
        prim = eqn.primitive.name
        ins = [(v, self.read(env, v)) for v in eqn.invars]
        poison = frozenset().union(*(a.poison for _, a in ins)) \
            if ins else frozenset()
        handler = getattr(self, "_p_" + prim.replace("-", "_"), None)
        if handler is not None:
            outs = handler(eqn, ins, where)
        elif prim in _IDENTITY_PRIMS:
            outs = [self._identity(eqn, ins)]
        elif prim in _UNARY_NP:
            outs = [self._elementwise(eqn, ins, _UNARY_NP[prim])]
        elif prim in _BINARY_NP:
            outs = [self._elementwise(eqn, ins, _BINARY_NP[prim])]
        elif prim in _REDUCE_IDENTITY:
            outs = self._reduce(eqn, ins, where)
        else:
            outs = self._unknown(eqn, ins, where)
        return [replace(o, poison=o.poison | poison) for o in outs]

    # elementwise family -----------------------------------------------------

    def _merge_digits(self, eqn, ins):
        """Union the operands' digits onto the (rank-aligned) output."""
        out_shape = eqn.outvars[0].aval.shape
        digits = {}
        agree = True
        for v, a in ins:
            rank = len(getattr(v.aval, "shape", ())) \
                if not isinstance(v, Literal) else np.asarray(v.val).ndim
            for d in a.digits:
                # lax elementwise ops are rank-aligned; scalar operands
                # broadcast and carry no digits
                ax = d.axis + (len(out_shape) - rank)
                nd = Digit(ax, d.sub_stride, d.extent)
                prev = digits.get(ax)
                if prev is None:
                    digits[ax] = nd
                elif prev != nd:
                    digits[ax] = Digit(ax, 1, out_shape[ax])
                    agree = False
        return (tuple(sorted(digits.values(), key=lambda d: d.axis)), agree)

    def _elementwise(self, eqn, ins, np_fn):
        prim = eqn.primitive.name
        out_aval = eqn.outvars[0].aval
        digits, agree = self._merge_digits(eqn, ins)
        consts = [a.const for _, a in ins]
        const = None
        if all(c is not None for c in consts) and not digits:
            with np.errstate(all="ignore"):
                const = _np_scalar(np_fn(*consts), out_aval.dtype)
        if not digits:
            return AbsVal(const=const)
        lanes = [self.lane_of(v, a) for v, a in ins]
        out_lanes = self._combine_lanes(prim, lanes, np_fn, out_aval.dtype) \
            if agree else VARIANT
        return AbsVal(digits=digits, lanes=out_lanes)

    def _combine_lanes(self, prim, lanes, np_fn, dtype):
        if all(isinstance(x, Known) for x in lanes):
            with np.errstate(all="ignore"):
                return Known(_np_scalar(np_fn(*(x.value for x in lanes)),
                                        dtype))
        if prim == "mul" and any(_known_zero(x) for x in lanes):
            self._assume("padded-lane operands are finite (0 * x == 0)")
            return Known(_np_scalar(0, dtype))
        if prim in ("and",) and any(isinstance(x, Known) and not x.value
                                    for x in lanes):
            return Known(False)
        if prim in ("or",) and any(isinstance(x, Known) and bool(x.value)
                                   for x in lanes):
            return Known(True)
        if prim == "div" and _known_zero(lanes[0]):
            self._assume("padded-lane denominators are nonzero "
                         "(0 / d == 0)")
            return Known(_np_scalar(0, dtype))
        if prim == "sub" and isinstance(lanes[0], Same) \
                and lanes[0] == lanes[1]:
            return Known(_np_scalar(0, dtype))
        if prim in ("add", "add_any", "sub") and isinstance(lanes[0], Same) \
                and _known_zero(lanes[1]):
            return lanes[0]
        if prim in ("add", "add_any") and isinstance(lanes[1], Same) \
                and _known_zero(lanes[0]):
            return lanes[1]
        return VARIANT

    def _identity(self, eqn, ins):
        v, a = ins[0]
        if not isinstance(v, Literal):
            self.alias[eqn.outvars[0]] = self.canon(v)
        return a

    # reductions -------------------------------------------------------------

    def _reduce(self, eqn, ins, where):
        prim = eqn.primitive.name
        (v, a), = ins
        axes = eqn.params["axes"]
        in_aval = v.aval
        out_aval = eqn.outvars[0].aval
        hit = [d for d in a.digits if d.axis in axes]
        remaining = [d for d in a.digits if d.axis not in axes]
        # renumber the surviving axes
        new_digits = tuple(
            Digit(d.axis - sum(1 for ax in axes if ax < d.axis),
                  d.sub_stride, d.extent) for d in remaining)
        poison = frozenset()
        if hit:
            ident = _REDUCE_IDENTITY.get(prim)
            ok = (isinstance(a.lanes, Known) and ident is not None
                  and ident(a.lanes.value, np.dtype(in_aval.dtype)))
            if ok:
                self.n_certified += 1
                lanes = a.lanes if prim != "reduce_sum" \
                    else Known(_np_scalar(0, out_aval.dtype))
            else:
                poison = self._finding(
                    "taint.unmasked-reduction", where,
                    f"{prim} over user axis/axes "
                    f"{[d.axis for d in hit]} with padded lanes {a.lanes} "
                    "— not the monoid identity, padded users leak into "
                    "active outputs")
                lanes = VARIANT
        else:
            n = int(np.prod([in_aval.shape[ax] for ax in axes], dtype=int)) \
                if axes else 1
            if isinstance(a.lanes, Known) and prim in _REDUCE_FOLD:
                with np.errstate(all="ignore"):
                    lanes = Known(_np_scalar(
                        _REDUCE_FOLD[prim](a.lanes.value, n),
                        out_aval.dtype))
            elif prim in ("argmax", "argmin"):
                lanes = VARIANT
            else:
                lanes = VARIANT if not isinstance(a.lanes, Known) else VARIANT
        if not new_digits:
            if hit and not poison:
                # fully reduced, certified: result carries no lane structure
                return [AbsVal(poison=poison)]
            return [AbsVal(poison=poison)] if hit else [
                AbsVal(const=None, poison=poison)]
        return [AbsVal(digits=new_digits, lanes=lanes, poison=poison)]

    def _p_argmax(self, eqn, ins, where):
        return self._reduce(eqn, ins, where)

    def _p_argmin(self, eqn, ins, where):
        return self._reduce(eqn, ins, where)

    def _p_cumsum(self, eqn, ins, where):
        return self._cumulative(eqn, ins, where)

    _p_cumprod = _p_cummax = _p_cummin = _p_cumlogsumexp = _p_cumsum

    def _cumulative(self, eqn, ins, where):
        (v, a), = ins
        axis = eqn.params.get("axis")
        if any(d.axis == axis for d in a.digits):
            poison = self._finding(
                "taint.cumulative-over-user-axis", where,
                f"{eqn.primitive.name} along user axis {axis}: prefix "
                "results mix padded and active lanes")
            return [AbsVal(digits=a.digits, lanes=VARIANT, poison=poison)]
        lanes = a.lanes if isinstance(a.lanes, Known) and \
            eqn.primitive.name in ("cummax", "cummin") else (
                a.lanes if _known_zero(a.lanes)
                and eqn.primitive.name == "cumsum" else
                (VARIANT if a.marked else None))
        return [AbsVal(digits=a.digits, lanes=lanes)]

    # select ----------------------------------------------------------------

    def _p_select_n(self, eqn, ins, where):
        out_aval = eqn.outvars[0].aval
        digits, agree = self._merge_digits(eqn, ins)
        if not digits:
            consts = [a.const for _, a in ins]
            if all(c is not None for c in consts):
                which = int(np.asarray(consts[0]).item())
                return [AbsVal(const=consts[1 + which])]
            return [AbsVal()]
        pred_lane = self.lane_of(*ins[0])
        case_lanes = [self.lane_of(v, a) for v, a in ins[1:]]
        if isinstance(pred_lane, Known):
            lanes = case_lanes[int(np.asarray(pred_lane.value).item())]
        else:
            lanes = case_lanes[0]
            for cl in case_lanes[1:]:
                lanes = _join_lanes(lanes, cl)
        return [AbsVal(digits=digits, lanes=lanes if agree else VARIANT)]

    def _p_clamp(self, eqn, ins, where):
        def np_clamp(lo, x, hi):
            return np.minimum(np.maximum(x, lo), hi)
        return [self._elementwise(eqn, ins, np_clamp)]

    def _p_integer_pow(self, eqn, ins, where):
        y = eqn.params["y"]
        return [self._elementwise(
            eqn, ins, lambda x: np.power(x, y))]

    def _p_convert_element_type(self, eqn, ins, where):
        (v, a), = ins
        out_dtype = np.dtype(eqn.outvars[0].aval.dtype)
        in_kind = np.dtype(v.aval.dtype).kind if not isinstance(v, Literal) \
            else np.asarray(v.val).dtype.kind
        const = _np_scalar(a.const, out_dtype) if a.const is not None \
            else None
        lanes = a.lanes
        if isinstance(lanes, Known):
            lanes = Known(_np_scalar(lanes.value, out_dtype))
        elif isinstance(lanes, Same) and in_kind != out_dtype.kind:
            lanes = VARIANT
        if in_kind == out_dtype.kind and not isinstance(v, Literal):
            self.alias[eqn.outvars[0]] = self.canon(v)
        return [AbsVal(digits=a.digits, lanes=lanes, const=const)]

    # shape ops -------------------------------------------------------------

    def _p_broadcast_in_dim(self, eqn, ins, where):
        (v, a), = ins
        bdims = eqn.params["broadcast_dimensions"]
        out_shape = eqn.params["shape"]
        digits = tuple(Digit(bdims[d.axis], d.sub_stride, d.extent)
                       for d in a.digits)
        if not isinstance(v, Literal) and a.const is None:
            # broadcasting preserves element correspondence along kept axes
            self.alias[eqn.outvars[0]] = self.canon(v)
        const = a.const
        if isinstance(v, Literal) and np.asarray(v.val).ndim == 0:
            const = _np_scalar(v.val)
        return [AbsVal(digits=digits, lanes=a.lanes, const=const)]

    def _p_reshape(self, eqn, ins, where):
        (v, a), = ins
        in_shape = v.aval.shape
        out_shape = eqn.outvars[0].aval.shape
        if eqn.params.get("dimensions") is not None:
            return self._unknown(eqn, ins, where)  # fused transpose: rare
        if not a.marked:
            return [AbsVal(const=a.const)]
        in_strides = _row_major_strides(in_shape)
        out_strides = _row_major_strides(out_shape)
        digits = []
        degraded = False
        for d in a.digits:
            g = in_strides[d.axis] * d.sub_stride  # global flat stride
            placed = False
            for j, (so, sz) in enumerate(zip(out_strides, out_shape)):
                if (g % so == 0 and so <= g and g * d.extent <= so * sz):
                    digits.append(Digit(j, g // so, d.extent))
                    placed = True
                    break
            if not placed:
                # lane structure split across axes: widen every axis the
                # digit's span overlaps
                degraded = True
                span_lo, span_hi = g, g * d.extent
                for j, (so, sz) in enumerate(zip(out_strides, out_shape)):
                    if so < span_hi and so * sz > span_lo // max(1, sz):
                        digits.append(Digit(j, 1, sz))
        dd = {}
        for d in digits:
            dd[d.axis] = d if d.axis not in dd else Digit(
                d.axis, 1, out_shape[d.axis])
        return [AbsVal(digits=tuple(sorted(dd.values(),
                                           key=lambda d: d.axis)),
                       lanes=a.lanes if not degraded else VARIANT)]

    def _p_transpose(self, eqn, ins, where):
        (v, a), = ins
        perm = eqn.params["permutation"]
        inv = {old: new for new, old in enumerate(perm)}
        digits = tuple(sorted(
            (Digit(inv[d.axis], d.sub_stride, d.extent) for d in a.digits),
            key=lambda d: d.axis))
        return [AbsVal(digits=digits, lanes=a.lanes, const=a.const)]

    def _p_squeeze(self, eqn, ins, where):
        (v, a), = ins
        dims = eqn.params["dimensions"]
        digits = tuple(
            Digit(d.axis - sum(1 for ax in dims if ax < d.axis),
                  d.sub_stride, d.extent)
            for d in a.digits if d.axis not in dims)
        return [AbsVal(digits=digits,
                       lanes=a.lanes if digits else None, const=a.const)]

    def _p_expand_dims(self, eqn, ins, where):
        (v, a), = ins
        dims = eqn.params["dimensions"]
        digits = tuple(
            Digit(d.axis + sum(1 for ax in dims if ax <= d.axis),
                  d.sub_stride, d.extent) for d in a.digits)
        return [AbsVal(digits=digits, lanes=a.lanes, const=a.const)]

    def _p_rev(self, eqn, ins, where):
        (v, a), = ins
        # reversal permutes lanes but keeps the axis lane-partitioned
        return [AbsVal(digits=a.digits,
                       lanes=a.lanes if isinstance(a.lanes, Known)
                       else (VARIANT if a.marked else None),
                       const=a.const)]

    def _p_pad(self, eqn, ins, where):
        (v, a), (pv, pa) = ins
        cfg = eqn.params["padding_config"]
        out_shape = eqn.outvars[0].aval.shape
        pad_lane = Known(pa.const) if pa.const is not None \
            else self.lane_of(pv, pa)
        digits = []
        lanes = a.lanes
        for d in a.digits:
            lo, hi, interior = cfg[d.axis]
            if lo == 0 and hi == 0 and interior == 0:
                digits.append(d)
            else:
                digits.append(Digit(d.axis, 1, out_shape[d.axis]))
                lanes = _join_lanes(lanes, pad_lane) if lanes is not None \
                    else pad_lane
        const = a.const if (a.const is not None and pa.const is not None
                            and a.const == pa.const) else None
        return [AbsVal(digits=tuple(digits), lanes=lanes, const=const)]

    def _p_slice(self, eqn, ins, where):
        (v, a), = ins
        start = eqn.params["start_indices"]
        limit = eqn.params["limit_indices"]
        strides = eqn.params["strides"] or (1,) * len(start)
        in_shape = v.aval.shape
        out_shape = eqn.outvars[0].aval.shape
        digits = []
        lanes = a.lanes
        for d in a.digits:
            ax = d.axis
            if start[ax] == 0 and limit[ax] == in_shape[ax] \
                    and strides[ax] == 1:
                digits.append(d)
            else:
                digits.append(Digit(ax, 1, out_shape[ax]))
                if not isinstance(lanes, Known):
                    lanes = VARIANT
        return [AbsVal(digits=tuple(digits),
                       lanes=lanes if digits else None, const=a.const)]

    def _p_concatenate(self, eqn, ins, where):
        dim = eqn.params["dimension"]
        out_shape = eqn.outvars[0].aval.shape
        digits, agree = self._merge_digits(eqn, ins)
        if not digits:
            return [AbsVal()]
        on_dim = any(d.axis == dim for d in digits)
        lanes = None
        for v, a in ins:
            contrib = self.lane_of(v, a)
            lanes = contrib if lanes is None else _join_lanes(lanes, contrib)
        if on_dim:
            digits = tuple(d if d.axis != dim else Digit(dim, 1,
                                                         out_shape[dim])
                           for d in digits)
        return [AbsVal(digits=digits,
                       lanes=lanes if agree else VARIANT)]

    def _p_iota(self, eqn, ins, where):
        return [AbsVal()]

    def _p_dynamic_slice(self, eqn, ins, where):
        (v, a) = ins[0]
        out_shape = eqn.outvars[0].aval.shape
        in_shape = v.aval.shape
        digits = []
        lanes = a.lanes
        for d in a.digits:
            if out_shape[d.axis] == in_shape[d.axis]:
                digits.append(d)
            else:
                digits.append(Digit(d.axis, 1, out_shape[d.axis]))
                if not isinstance(lanes, Known):
                    lanes = VARIANT
        return [AbsVal(digits=tuple(digits),
                       lanes=lanes if digits else None)]

    def _p_dynamic_update_slice(self, eqn, ins, where):
        digits, agree = self._merge_digits(eqn, ins[:2])
        if not digits:
            return [AbsVal()]
        lo = self.lane_of(*ins[0])
        lu = self.lane_of(*ins[1])
        return [AbsVal(digits=digits,
                       lanes=_join_lanes(lo, lu) if agree else VARIANT)]

    # contraction / indexing -------------------------------------------------

    def _p_dot_general(self, eqn, ins, where):
        (lv, la), (rv, ra) = ins[:2]
        ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
        l_rank = len(lv.aval.shape)
        r_rank = len(rv.aval.shape)
        l_free = [ax for ax in range(l_rank) if ax not in lc and ax not in lb]
        r_free = [ax for ax in range(r_rank) if ax not in rc and ax not in rb]
        l_lane = self.lane_of(lv, la)
        r_lane = self.lane_of(rv, ra)
        poison = frozenset()
        # contracted user axes: the cross-user reduction case
        contracted_hit = [d for d in la.digits if d.axis in lc] + \
                         [d for d in ra.digits if d.axis in rc]
        if contracted_hit:
            if _known_zero(l_lane) or _known_zero(r_lane):
                self.n_certified += 1
                self._assume("padded-lane operands are finite (0 * x == 0)")
            else:
                poison = self._finding(
                    "taint.unmasked-contraction", where,
                    f"dot_general contracts user axis with padded lanes "
                    f"lhs={l_lane} rhs={r_lane} — neither side is "
                    "Known(0), padded users leak into the product")
        # batch/free user axes survive into the output
        out_digits = []

        def out_pos_l(ax):
            if ax in lb:
                return lb.index(ax)
            return len(lb) + l_free.index(ax)

        def out_pos_r(ax):
            if ax in rb:
                return rb.index(ax)
            return len(lb) + len(l_free) + r_free.index(ax)

        for d in la.digits:
            if d.axis in lc:
                continue
            out_digits.append(Digit(out_pos_l(d.axis), d.sub_stride,
                                    d.extent))
        for d in ra.digits:
            if d.axis in rc:
                continue
            pos = out_pos_r(d.axis)
            if not any(x.axis == pos for x in out_digits):
                out_digits.append(Digit(pos, d.sub_stride, d.extent))
        out_digits = tuple(sorted(out_digits, key=lambda d: d.axis))
        if not out_digits:
            return [AbsVal(poison=poison)]
        lanes = Known(_np_scalar(0, eqn.outvars[0].aval.dtype)) \
            if (_known_zero(l_lane) or _known_zero(r_lane)) else VARIANT
        if _known_zero(l_lane) or _known_zero(r_lane):
            self._assume("padded-lane operands are finite (0 * x == 0)")
        return [AbsVal(digits=out_digits, lanes=lanes, poison=poison)]

    def _p_gather(self, eqn, ins, where):
        (ov, oa), (iv, ia) = ins
        dn = eqn.params["dimension_numbers"]
        slice_sizes = eqn.params["slice_sizes"]
        op_shape = ov.aval.shape
        out_shape = eqn.outvars[0].aval.shape
        out_rank = len(out_shape)
        idx_rank = len(iv.aval.shape)
        batch_out = [d for d in range(out_rank) if d not in dn.offset_dims]
        ob = tuple(getattr(dn, "operand_batching_dims", ()))
        ib = tuple(getattr(dn, "start_indices_batching_dims", ()))
        digits = []
        lanes = None
        poison = frozenset()

        def add_lane(contrib):
            nonlocal lanes
            lanes = contrib if lanes is None else _join_lanes(lanes, contrib)

        # indices-side digits -> output batch positions
        for d in ia.digits:
            if d.axis >= idx_rank - 1:
                continue  # the index-vector dim is never lane data
            out_ax = batch_out[d.axis] if d.axis < len(batch_out) else None
            if out_ax is None:
                continue
            digits.append(Digit(out_ax, d.sub_stride, d.extent))
            if d.axis in ib:
                pair = ob[ib.index(d.axis)]
                op_digit = next((x for x in oa.digits if x.axis == pair),
                                None)
                if op_digit is not None and isinstance(oa.lanes, Known):
                    # within-lane gather of a uniform lane: still uniform
                    self._assume("index-typed padded lanes are in-bounds "
                                 "(pad_schedule writes index 0)")
                    add_lane(oa.lanes)
                else:
                    add_lane(VARIANT)
            else:
                add_lane(VARIANT)
        # operand-side digits
        op_offset_src = [ax for ax in range(len(op_shape))
                         if ax not in dn.collapsed_slice_dims
                         and ax not in ob]
        for d in oa.digits:
            if d.axis in ob:
                pair_idx_dim = ib[ob.index(d.axis)]
                out_ax = batch_out[pair_idx_dim] \
                    if pair_idx_dim < len(batch_out) else None
                if out_ax is not None \
                        and not any(x.axis == out_ax for x in digits):
                    digits.append(Digit(out_ax, 1, out_shape[out_ax]))
                    add_lane(oa.lanes if isinstance(oa.lanes, Known)
                             else VARIANT)
            elif d.axis in dn.collapsed_slice_dims \
                    or (d.axis in dn.start_index_map
                        and slice_sizes[d.axis] != op_shape[d.axis]):
                poison |= self._finding(
                    "taint.gather-over-user-axis", where,
                    f"gather indexes along user axis {d.axis}: padded-lane "
                    "data can surface at arbitrary output positions")
            elif d.axis in dn.start_index_map:
                # dynamic-slice-style gather whose slice spans the whole
                # user axis: the only in-bounds start is 0 (and gather
                # clamps), so the axis passes through untouched — lane
                # structure is preserved exactly like a full offset dim
                # (e.g. ``x[:, :, -1, :]`` batched over leading user axes)
                self._assume("full-length gather slices start at 0 "
                             "(out-of-range starts clamp to 0)")
                j = op_offset_src.index(d.axis)
                out_ax = dn.offset_dims[j]
                digits.append(Digit(out_ax, d.sub_stride, d.extent))
                add_lane(oa.lanes if isinstance(oa.lanes, Known)
                         else VARIANT)
            else:
                j = op_offset_src.index(d.axis)
                out_ax = dn.offset_dims[j]
                if slice_sizes[d.axis] == op_shape[d.axis]:
                    digits.append(Digit(out_ax, d.sub_stride, d.extent))
                else:
                    digits.append(Digit(out_ax, 1, out_shape[out_ax]))
                add_lane(oa.lanes if isinstance(oa.lanes, Known)
                         else VARIANT)
        digits = tuple(sorted(digits, key=lambda d: d.axis))
        if not digits:
            return [AbsVal(poison=poison)]
        return [AbsVal(digits=digits,
                       lanes=lanes if lanes is not None else VARIANT,
                       poison=poison)]

    def _p_scatter_add(self, eqn, ins, where):
        (ov, oa), (iv, ia), (uv, ua) = ins
        dn = eqn.params["dimension_numbers"]
        op_shape = ov.aval.shape
        upd_shape = uv.aval.shape
        idx_rank = len(iv.aval.shape)
        ob = tuple(getattr(dn, "operand_batching_dims", ()))
        ib = tuple(getattr(dn, "scatter_indices_batching_dims", ()))
        uw = tuple(dn.update_window_dims)
        # updates dims that are NOT window dims map in order to scatter
        # indices dims (excluding the trailing index-vector dim)
        upd_batch = [ax for ax in range(len(upd_shape)) if ax not in uw]
        op_window = [ax for ax in range(len(op_shape))
                     if ax not in dn.inserted_window_dims and ax not in ob]
        u_lane = self.lane_of(uv, ua)
        o_lane = self.lane_of(ov, oa)
        poison = frozenset()
        cross_lane_zero = True
        for d in ua.digits:
            if d.axis in uw:
                continue  # window dims: within-slice, handled via operand
            j = upd_batch.index(d.axis)
            idx_dim = j  # indices dim order
            if idx_dim in ib:
                continue  # batched (within-lane) scatter: confined
            # lane-structured updates scattered across lanes by index value
            if not _known_zero(u_lane):
                cross_lane_zero = False
                poison |= self._finding(
                    "taint.scatter-across-user-axis", where,
                    f"scatter-add writes user-lane updates (lanes={u_lane}) "
                    "at index-selected positions: padded-lane data can "
                    "land in active rows")
        # output keeps the operand's layout
        digits = dict((d.axis, d) for d in oa.digits)
        for d in ua.digits:
            if d.axis in uw:
                op_ax = op_window[uw.index(d.axis)]
                nd = Digit(op_ax, d.sub_stride, d.extent)
                if op_ax not in digits:
                    digits[op_ax] = nd
            else:
                j = upd_batch.index(d.axis)
                if j in ib:
                    op_ax = ob[ib.index(j)]
                    if op_ax not in digits:
                        digits[op_ax] = Digit(op_ax, 1, op_shape[op_ax])
        digits = tuple(sorted(digits.values(), key=lambda d: d.axis))
        if not digits:
            return [AbsVal(poison=poison)]
        if _known_zero(u_lane):
            lanes = o_lane  # adding exact zeros changes nothing
        elif isinstance(o_lane, Known) and isinstance(u_lane, Known):
            lanes = VARIANT  # added at some positions within the lane only
        else:
            lanes = VARIANT
        return [AbsVal(digits=digits, lanes=lanes, poison=poison)]

    _p_scatter = _p_scatter_add  # conservative: same confinement rules

    def _p_sort(self, eqn, ins, where):
        dim = eqn.params["dimension"]
        outs = []
        poison = frozenset()
        for v, a in ins:
            if any(d.axis == dim for d in a.digits):
                poison |= self._finding(
                    "taint.sort-over-user-axis", where,
                    f"sort along user axis {dim} interleaves padded and "
                    "active lanes")
            outs.append(AbsVal(digits=a.digits,
                               lanes=VARIANT if a.marked else None,
                               poison=poison))
        return outs

    def _p_top_k(self, eqn, ins, where):
        (v, a), = ins
        last = len(v.aval.shape) - 1
        poison = frozenset()
        if any(d.axis == last for d in a.digits):
            poison = self._finding(
                "taint.topk-over-user-axis", where,
                "top_k along user axis selects across padded lanes")
        digits = tuple(d for d in a.digits if d.axis != last)
        vals = AbsVal(digits=digits,
                      lanes=a.lanes if isinstance(a.lanes, Known) and digits
                      else (VARIANT if digits else None), poison=poison)
        idxs = AbsVal(digits=digits, lanes=VARIANT if digits else None,
                      poison=poison)
        return [vals, idxs]

    # higher-order -----------------------------------------------------------

    def _p_pjit(self, eqn, ins, where):
        closed = eqn.params["jaxpr"]
        return self.run_jaxpr(closed.jaxpr, [a for _, a in ins],
                              where + "/pjit")

    def _p_closed_call(self, eqn, ins, where):
        closed = eqn.params["call_jaxpr"]
        return self.run_jaxpr(closed.jaxpr, [a for _, a in ins],
                              where + "/call")

    def _p_custom_jvp_call(self, eqn, ins, where):
        closed = eqn.params["call_jaxpr"]
        return self.run_jaxpr(closed.jaxpr, [a for _, a in ins],
                              where + "/jvp")

    def _p_custom_vjp_call(self, eqn, ins, where):
        closed = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
        return self.run_jaxpr(closed.jaxpr, [a for _, a in ins],
                              where + "/vjp")

    _p_custom_vjp_call_jaxpr = _p_custom_vjp_call

    def _p_remat(self, eqn, ins, where):
        inner = eqn.params["jaxpr"]
        jaxpr = inner.jaxpr if isinstance(inner, ClosedJaxpr) else inner
        return self.run_jaxpr(jaxpr, [a for _, a in ins], where + "/remat")

    _p_remat2 = _p_checkpoint = _p_remat

    def _p_cond(self, eqn, ins, where):
        branches = eqn.params["branches"]
        op_vals = [a for _, a in ins[1:]]
        outs = None
        for bi, br in enumerate(branches):
            bouts = self.run_jaxpr(br.jaxpr, op_vals,
                                   f"{where}/branch{bi}")
            outs = bouts if outs is None else [
                _join(x, y) for x, y in zip(outs, bouts)]
        return outs

    def _p_while(self, eqn, ins, where):
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        cond_consts = [a for _, a in ins[:cn]]
        body_consts = [a for _, a in ins[cn:cn + bn]]
        carry = [a for _, a in ins[cn + bn:]]
        carry = self._fixpoint(
            lambda c, rec: self._run_quiet(
                eqn.params["body_jaxpr"].jaxpr, body_consts + c,
                f"{where}/body", rec),
            carry, where)
        self._run_quiet(eqn.params["cond_jaxpr"].jaxpr,
                        cond_consts + carry, f"{where}/cond", True)
        return carry

    def _p_scan(self, eqn, ins, where):
        num_consts = eqn.params["num_consts"]
        num_carry = eqn.params["num_carry"]
        body = eqn.params["jaxpr"].jaxpr
        consts = [a for _, a in ins[:num_consts]]
        carry0 = [a for _, a in ins[num_consts:num_consts + num_carry]]
        xs = [(v, a) for v, a in ins[num_consts + num_carry:]]
        # xs lose their leading scan axis entering the body
        xs_body = []
        poison = frozenset()
        for v, a in xs:
            if any(d.axis == 0 for d in a.digits):
                poison |= self._finding(
                    "taint.scan-over-user-axis", where,
                    "lax.scan consumes the user axis as its scan axis")
            digits = tuple(Digit(d.axis - 1, d.sub_stride, d.extent)
                           for d in a.digits if d.axis > 0)
            xs_body.append(AbsVal(digits=digits,
                                  lanes=a.lanes if digits else None,
                                  const=a.const, poison=a.poison))

        def step(c, rec):
            outs = self._run_quiet(body, consts + c + xs_body,
                                   f"{where}/body", rec)
            return outs[:num_carry], outs[num_carry:]

        carry = self._fixpoint(lambda c, rec: step(c, rec)[0], carry0, where)
        _, ys = step(carry, True)
        # ys gain a leading period axis
        ys_out = [AbsVal(digits=tuple(Digit(d.axis + 1, d.sub_stride,
                                            d.extent) for d in y.digits),
                         lanes=y.lanes, const=y.const,
                         poison=y.poison | poison) for y in ys]
        carry_out = [replace(c, poison=c.poison | poison) for c in carry]
        return carry_out + ys_out

    def _run_quiet(self, jaxpr, vals, path, record):
        prev, self.recording = self.recording, record and self.recording
        prev_n = (self.n_eqns, self.n_certified)
        try:
            outs = self.run_jaxpr(jaxpr, vals, path)
        finally:
            self.recording = prev
            if not record:
                self.n_eqns, self.n_certified = prev_n
        return outs

    def _fixpoint(self, step, carry, where, max_iter=24):
        for _ in range(max_iter):
            nxt = [_join(c, n) for c, n in zip(carry, step(carry, False))]
            if nxt == carry:
                return carry
            carry = nxt
        # no convergence: widen everything
        return [AbsVal(digits=c.digits, lanes=VARIANT if c.marked else None,
                       poison=c.poison) for c in carry]

    # fallback ---------------------------------------------------------------

    def _unknown(self, eqn, ins, where):
        marked = any(a.marked for _, a in ins)
        poison = frozenset()
        if marked:
            poison = self._finding(
                "taint.unhandled-primitive", where,
                f"primitive '{eqn.primitive.name}' has no transfer rule "
                "but consumes a user-lane-structured value")
        outs = []
        for ov in eqn.outvars:
            shape = getattr(ov.aval, "shape", ())
            if marked:
                digits = tuple(Digit(ax, 1, s)
                               for ax, s in enumerate(shape) if s > 1)
                outs.append(AbsVal(digits=digits,
                                   lanes=VARIANT if digits else None,
                                   poison=poison))
            else:
                outs.append(AbsVal())
        return outs


def _row_major_strides(shape):
    strides = [1] * len(shape)
    for i in range(len(shape) - 2, -1, -1):
        strides[i] = strides[i + 1] * shape[i + 1]
    return strides


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------


def analyze_jaxpr(closed: ClosedJaxpr, in_labels, out_contracts=None, *,
                  program: str = "program",
                  report: Optional[AuditReport] = None) -> AuditReport:
    """Run the padding-taint pass over one closed jaxpr.

    ``in_labels``: one :class:`LaneLabel` (or :data:`NO_LABEL`) per
    flattened jaxpr input.  ``out_contracts``: optional dict mapping
    flattened output index → :class:`OutContract` (padded lanes of that
    output must provably hold the contract value — the chunk-resumption
    induction).  Findings land in ``report`` (new one if None) and a
    per-program summary in ``report.programs[program]``.
    """
    if report is None:
        report = AuditReport()
    interp = _Interp(report, program)
    in_vals = []
    for i, (var, label) in enumerate(zip(closed.jaxpr.invars, in_labels)):
        if label is None or label.axis is None:
            in_vals.append(CLEAN)
            continue
        shape = var.aval.shape
        assert 0 <= label.axis < len(shape), \
            f"label axis {label.axis} out of range for invar {i} {shape}"
        lanes = VARIANT if label.lanes == "variant" \
            else Known(_np_scalar(label.lanes, var.aval.dtype))
        in_vals.append(AbsVal(
            digits=(Digit(label.axis, 1, shape[label.axis]),), lanes=lanes))
    outs = interp.run_jaxpr(closed.jaxpr, in_vals, "")
    n_poisoned = 0
    for i, o in enumerate(outs):
        if o.poison:
            n_poisoned += 1
            report.add("taint.poisoned-output", Severity.ERROR,
                       f"{program}:out[{i}]",
                       f"output {i} is influenced by taint violations: "
                       f"{sorted(o.poison)}")
    for i, contract in (out_contracts or {}).items():
        o = outs[i]
        ok = any(d.axis == contract.axis for d in o.digits) and \
            isinstance(o.lanes, Known) and \
            float(np.asarray(o.lanes.value)) == float(contract.value)
        # an unmarked constant output equal to the contract also satisfies
        ok = ok or (not o.marked and o.const is not None
                    and float(o.const) == float(contract.value))
        if not ok:
            report.add("taint.output-contract", Severity.ERROR,
                       f"{program}:out[{i}]",
                       f"output {i} must hold Known({contract.value}) on "
                       f"padded lanes of axis {contract.axis}; analysis "
                       f"derived digits={o.digits} lanes={o.lanes}")
    for text in sorted(interp.assumptions):
        report.add("taint.assumption", Severity.INFO, program, text)
    report.programs[program] = {
        "pass": "taint",
        "n_eqns": interp.n_eqns,
        "n_certified_reductions": interp.n_certified,
        "n_outputs": len(outs),
        "n_poisoned_outputs": n_poisoned,
        "assumptions": sorted(interp.assumptions),
        "ok": not any(f.severity is Severity.ERROR
                      for f in report.findings
                      if f.where.startswith(program)),
    }
    return report

"""Declarative experiment API: Study grids → bucketed lowering →
pluggable Executor runtimes → streaming Results.

The paper's contribution is a *family* of scenarios — CPU vs GPU fleets,
IID vs non-IID partitions, the four Table-II schemes, batchsize policies,
wireless operating points — and this package is the experiment surface
that serves that family at hardware speed:

* :class:`ScenarioSpec` (``spec.py``) — one frozen, hashable cell of the
  scenario grid: fleet, wireless ``CellConfig``, partition, policy,
  scheme, compression, ``b_max``, ``base_lr``, ``local_steps``, seeds.
* :func:`grid` / :class:`Study` (``study.py``) — product-expansion
  sweeps over *any* spec field, including ``CellConfig`` geometry via
  dotted axes (``cell.radius_m``, ``cell.bandwidth_hz``,
  ``cell.tx_power_dbm``) and fleet size/composition via the ``users``
  axis (``users=[4, 8, 16]`` → ``res.sel(num_users=8)``; fleet is a
  padded, non-structural axis so a whole K-sweep shares one compiled
  program), expanding to deduplicated specs with auto-derived labels and
  per-axis ``Results`` coordinates.
* :class:`Experiment` (``experiment.py``) — dedupes and groups rows into
  shape-compatible buckets (``ScenarioSpec.bucket_key`` — see
  ``spec.py``), lowers each bucket to ONE jitted ``vmap(lax.scan)``
  program through the plan/dispatch/collect phases of ``lowering.py``,
  and assembles ``Results`` incrementally (``run`` / ``stream``).
* Executors (``executor.py``) — :class:`SerialExecutor` (reference),
  :class:`AsyncExecutor` (cross-bucket pipelining: bucket *N+1*'s host
  planning overlaps bucket *N*'s device execution), and
  :class:`MeshExecutor` (batch axis sharded over
  ``launch.mesh.make_batch_mesh``).  Bit-identical by construction and
  by test.  All executors take ``chunk_periods=``: horizons execute as
  period-chunks through resumable scans
  (``lowering.BucketRun`` / ``fed.engine.EngineState``), pipelining
  chunk *c+1*'s host planning behind chunk *c*'s device execution —
  bit-identical to the monolithic scan at any chunk size.  Specs with
  ``replan=R`` (or ``Experiment.run(replan=R)``) close the Algorithm-1
  loop: each chunk's realized loss decays update the per-row ξ
  estimator before the next chunk is planned.
* :class:`Results` / :class:`ResultsBuilder` (``results.py``) — named
  (fleet, partition, policy, scheme, seed, period, …axis) coordinates
  with ``sel``/``speed``/``final_acc`` reductions, explicit NaN handling,
  and incremental per-bucket collection.

The legacy entry points ``fed.sweep.run_sweep`` and
``fed.trainer.run_scheme`` remain as thin deprecation shims on top of
this package.  The ``Experiment(mesh=...)`` shim is gone — meshes belong
to executors (``MeshExecutor(mesh)`` / ``AsyncExecutor(mesh=...)``).
"""
from repro.api.executor import (AsyncExecutor, Executor, MeshExecutor,
                                SerialExecutor)
from repro.api.experiment import Experiment
from repro.api.results import Results, ResultsBuilder, time_to_target
from repro.api.spec import ScenarioSpec
from repro.api.study import Study, grid

__all__ = ["AsyncExecutor", "Executor", "Experiment", "MeshExecutor",
           "Results", "ResultsBuilder", "ScenarioSpec", "SerialExecutor",
           "Study", "grid", "time_to_target"]

"""Declarative experiment API: ScenarioSpec → bucketed lowering → Results.

The paper's contribution is a *family* of scenarios — CPU vs GPU fleets,
IID vs non-IID partitions, the four Table-II schemes, batchsize policies —
and this package is the experiment surface that serves that family at
hardware speed:

* :class:`ScenarioSpec` (``spec.py``) — one frozen, hashable cell of the
  scenario grid: fleet, wireless ``CellConfig``, partition, policy,
  scheme, compression, ``b_max``, ``base_lr``, ``local_steps``, seeds.
* :class:`Experiment` (``experiment.py``) — groups specs into
  shape-compatible buckets (the rule lives on
  ``ScenarioSpec.bucket_key`` — see ``spec.py``'s docstring) and lowers
  each bucket to ONE jitted ``vmap(lax.scan)`` program whose leading axis
  flattens the (scenario × seed) grid, optionally sharded across a device
  mesh (``launch.mesh.make_batch_mesh``).
* :class:`Results` (``results.py``) — named (fleet, partition, policy,
  scheme, seed, period) axes with ``sel``/``speed``/``final_acc``
  reductions and explicit NaN handling for not-evaluated periods.

The legacy entry points ``fed.sweep.run_sweep`` and
``fed.trainer.run_scheme`` remain as thin deprecation shims on top of
this package.
"""
from repro.api.experiment import Experiment
from repro.api.results import Results, time_to_target
from repro.api.spec import ScenarioSpec

__all__ = ["Experiment", "Results", "ScenarioSpec", "time_to_target"]

"""Pluggable Experiment runtimes: how buckets are scheduled on hardware.

The lowering (``api.lowering``) splits every bucket into three pure
phases — host-side *plan*, non-blocking device *dispatch*, blocking
*collect* — and an :class:`Executor` is nothing but a composition policy
over those phases.  All executors are bit-identical in results (the
phases are pure functions of the bucket; test-enforced); they differ only
in wall-clock and device layout:

* :class:`SerialExecutor` — plan → dispatch → collect one bucket at a
  time, blocking between buckets.  The reference runtime (today's
  behaviour) and the default.
* :class:`AsyncExecutor` — dispatch bucket *N* without blocking and
  overlap bucket *N+1*'s host planning (channel Monte-Carlo draws and
  Algorithm-1 bisections are pure host NumPy) behind its device
  execution; only block at collection.  On a multi-bucket grid the host
  plans the next program while the device retires the previous one.
* :class:`MeshExecutor` — shard every bucket's flattened
  (scenario × seed) batch axis across a 1-D device mesh
  (``launch.mesh.make_batch_mesh``), created lazily over all available
  devices when none is given.  Subsumes the deprecated
  ``Experiment(mesh=...)`` kwarg.

Executors yield ``(bucket, (losses, accs, times, global_batch))`` in
bucket order as results become available, which is what lets
``Experiment.stream`` hand back incrementally collected ``Results``.
"""
from __future__ import annotations

from typing import Iterator, Optional, Sequence, Tuple

from repro.api.lowering import (Bucket, collect_bucket, dispatch_bucket,
                                plan_bucket)
from repro.launch.mesh import ensure_batch_mesh, make_batch_mesh

BucketSeries = Tuple[Bucket, tuple]


class Executor:
    """Composition policy over the plan/dispatch/collect bucket phases."""

    def __init__(self, mesh=None):
        self.mesh = mesh

    def _resolve_mesh(self):
        return None if self.mesh is None else ensure_batch_mesh(self.mesh)

    def execute(self, buckets: Sequence[Bucket], data, test,
                periods: int) -> Iterator[BucketSeries]:
        """Yield ``(bucket, (losses, accs, times, global_batch))`` per
        bucket, in bucket order."""
        raise NotImplementedError


class SerialExecutor(Executor):
    """One bucket at a time, blocking at each collection (reference)."""

    def execute(self, buckets, data, test, periods):
        mesh = self._resolve_mesh()
        for bucket in buckets:
            handle = dispatch_bucket(plan_bucket(bucket, data, periods),
                                     data, test, mesh=mesh)
            yield bucket, collect_bucket(handle)


class AsyncExecutor(Executor):
    """Cross-bucket pipelining: plan+dispatch every bucket back-to-back,
    collect afterwards.

    Because jax dispatch is asynchronous, dispatching bucket *N* returns
    as soon as the program is enqueued — bucket *N+1*'s host planning
    (pure NumPy) then runs concurrently with *N*'s device execution, and
    the only blocking happens at collection.  Results are bit-identical
    to :class:`SerialExecutor` (test-enforced): every phase is a pure
    function of its bucket, so scheduling order cannot change values.
    """

    def execute(self, buckets, data, test, periods):
        mesh = self._resolve_mesh()
        handles = [dispatch_bucket(plan_bucket(bucket, data, periods),
                                   data, test, mesh=mesh)
                   for bucket in buckets]
        for handle in handles:
            yield handle.bucket, collect_bucket(handle)


class MeshExecutor(SerialExecutor):
    """Serial schedule with every bucket's batch axis sharded over a 1-D
    device mesh; builds ``make_batch_mesh(max_devices)`` lazily when no
    mesh is given.  For sharding *and* cross-bucket overlap, pass a mesh
    to :class:`AsyncExecutor` instead."""

    def __init__(self, mesh=None, max_devices: Optional[int] = None):
        super().__init__(mesh=mesh)
        self.max_devices = max_devices

    def _resolve_mesh(self):
        if self.mesh is None:
            self.mesh = make_batch_mesh(self.max_devices)
        return ensure_batch_mesh(self.mesh)

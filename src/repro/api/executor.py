"""Pluggable Experiment runtimes: how buckets are scheduled on hardware.

The lowering (``api.lowering``) splits every bucket into three pure
phases — host-side *plan*, non-blocking device *dispatch*, blocking
*collect* — and an :class:`Executor` is nothing but a composition policy
over those phases.  All executors are bit-identical in results (the
phases are pure functions of the bucket; test-enforced); they differ only
in wall-clock and device layout:

* :class:`SerialExecutor` — plan → dispatch → collect one bucket at a
  time, blocking between buckets.  The reference runtime (today's
  behaviour) and the default.
* :class:`AsyncExecutor` — dispatch bucket *N* without blocking and
  overlap bucket *N+1*'s host planning (channel Monte-Carlo draws and
  Algorithm-1 bisections are pure host NumPy) behind its device
  execution; only block at collection.  On a multi-bucket grid the host
  plans the next program while the device retires the previous one.
  ``max_in_flight=N`` caps the dispatch backlog (device residency) at N
  buckets without changing a single result bit.
* :class:`MeshExecutor` — shard every bucket's flattened
  (scenario × seed) batch axis across a 1-D device mesh
  (``launch.mesh.make_batch_mesh``), created lazily over all available
  devices when none is given.

Chunked horizons (``chunk_periods=``)
-------------------------------------
Every executor also pipelines *within* a bucket: ``chunk_periods=C``
executes each bucket as C-period chunks through
:class:`~repro.api.lowering.BucketRun`, carrying the engine scan state
between chunks.  Under :class:`AsyncExecutor` the host plans chunk *c+1*
(bisections, channel MC) while the device executes chunk *c* — so even a
single-bucket experiment overlaps host and device work.  Chunking with ξ
frozen is a pure scheduling policy: results are bit-identical to the
monolithic scan at any chunk size (test-enforced).  Buckets whose specs
set ``replan=`` are *closed-loop*: they chunk at the replan interval
regardless of ``chunk_periods`` and must collect chunk *c* (feeding its
realized decays to the ξ estimators) before planning chunk *c+1* — under
:class:`AsyncExecutor`, other buckets' device work still hides behind
that feedback stall.

Executors yield ``(bucket, (losses, accs, times, global_batch))`` in
bucket order as results become available, which is what lets
``Experiment.stream`` hand back incrementally collected ``Results``.
"""
from __future__ import annotations

from collections import deque
from typing import Iterator, Optional, Sequence, Tuple, Union

from repro.api.lowering import (Bucket, BucketRun, collect_bucket,
                                dispatch_bucket, plan_bucket)
from repro.launch.mesh import ensure_batch_mesh, make_batch_mesh

BucketSeries = Tuple[Bucket, tuple]


class Executor:
    """Composition policy over the plan/dispatch/collect bucket phases."""

    def __init__(self, mesh=None, chunk_periods: Optional[int] = None):
        if chunk_periods is not None and chunk_periods < 1:
            raise ValueError(
                f"chunk_periods must be >= 1, got {chunk_periods}")
        self.mesh = mesh
        self.chunk_periods = chunk_periods

    def _resolve_mesh(self):
        return None if self.mesh is None else ensure_batch_mesh(self.mesh)

    def _chunk_for(self, bucket: Bucket) -> Optional[int]:
        """The bucket's chunk size, or ``None`` for one monolithic scan.
        A closed-loop bucket chunks at its replan interval (the feedback
        boundary is semantic, not a tuning knob); otherwise the
        executor's ``chunk_periods`` applies."""
        if bucket.replan is not None:
            return bucket.replan
        return self.chunk_periods

    def execute(self, buckets: Sequence[Bucket], data, test,
                periods: int) -> Iterator[BucketSeries]:
        """Yield ``(bucket, (losses, accs, times, global_batch))`` per
        bucket, in bucket order."""
        raise NotImplementedError


class SerialExecutor(Executor):
    """One bucket at a time, blocking at each collection (reference).

    With ``chunk_periods`` (or closed-loop buckets) the reference
    schedule is strictly sequential per chunk too: plan chunk *c*,
    dispatch it, collect it, then plan chunk *c+1* — no overlap anywhere,
    which is exactly what makes it the semantics oracle the pipelined
    runtimes are tested against.
    """

    def execute(self, buckets, data, test, periods):
        mesh = self._resolve_mesh()
        for bucket in buckets:
            chunk = self._chunk_for(bucket)
            if chunk is None:
                handle = dispatch_bucket(plan_bucket(bucket, data, periods),
                                         data, test, mesh=mesh)
                yield bucket, collect_bucket(handle)
            else:
                run = BucketRun(bucket, data, test, periods, chunk,
                                mesh=mesh)
                yield bucket, run.run_serial()


class AsyncExecutor(Executor):
    """Cross-bucket (and, with ``chunk_periods``, intra-bucket)
    pipelining: plan+dispatch back-to-back, collect afterwards.

    Because jax dispatch is asynchronous, dispatching bucket *N* returns
    as soon as the program is enqueued — bucket *N+1*'s host planning
    (pure NumPy) then runs concurrently with *N*'s device execution, and
    the only blocking happens at collection.  Chunked buckets extend the
    same overlap inside a bucket: every open-loop chunk is planned and
    dispatched as soon as the previous one is enqueued, so the host
    plans chunk *c+1* while the device executes chunk *c* — a
    single-bucket experiment no longer serializes planning before
    execution.  Closed-loop buckets collect each chunk before planning
    the next (the ξ feedback is the point); the already-enqueued chunks
    of *other* buckets keep the device busy through that stall.  Results
    are bit-identical to :class:`SerialExecutor` (test-enforced): every
    phase is a pure function of its bucket and the carried state, so
    scheduling order cannot change values.

    ``max_in_flight`` bounds how many dispatched buckets' device values
    stay resident at once: once the window is full, the oldest bucket is
    collected (blocking) before the next one is planned and dispatched.
    A chunked bucket counts as one in-flight unit (its chunks replace —
    not multiply — the monolithic residency).  The default (``None``)
    keeps every bucket in flight — fine at current scales;
    thousand-bucket studies should cap the backlog.  ``max_in_flight=1``
    degenerates to the serial schedule across buckets while keeping
    intra-bucket chunk pipelining.  The cap is a scheduling policy only:
    capped and uncapped runs are bit-identical (test-enforced).
    """

    def __init__(self, mesh=None, max_in_flight: Optional[int] = None,
                 chunk_periods: Optional[int] = None):
        super().__init__(mesh=mesh, chunk_periods=chunk_periods)
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.max_in_flight = max_in_flight

    def _start(self, bucket, data, test, periods, mesh):
        chunk = self._chunk_for(bucket)
        if chunk is None:
            return dispatch_bucket(plan_bucket(bucket, data, periods),
                                   data, test, mesh=mesh)
        run = BucketRun(bucket, data, test, periods, chunk, mesh=mesh)
        run.advance()                     # chunk 0 in flight immediately
        return run

    @staticmethod
    def _plan_ahead(pending) -> None:
        """Push every in-flight chunked bucket as far as its guard
        allows (open-loop chunks dispatch immediately; closed-loop
        buckets wait for their collect)."""
        for item in pending:
            if isinstance(item, BucketRun):
                while item.can_advance:
                    item.advance()

    @staticmethod
    def _finish(item: Union[BucketRun, object]) -> BucketSeries:
        if isinstance(item, BucketRun):
            return item.bucket, item.drain()
        return item.bucket, collect_bucket(item)

    def execute(self, buckets, data, test, periods):
        mesh = self._resolve_mesh()
        cap = self.max_in_flight or len(buckets)
        pending: deque = deque()
        for bucket in buckets:
            if len(pending) >= cap:
                yield self._finish(pending.popleft())
            pending.append(self._start(bucket, data, test, periods, mesh))
            self._plan_ahead(pending)
        while pending:
            yield self._finish(pending.popleft())


class MeshExecutor(SerialExecutor):
    """Serial schedule with every bucket's batch axis sharded over a 1-D
    device mesh; builds ``make_batch_mesh(max_devices)`` lazily when no
    mesh is given.  For sharding *and* cross-bucket overlap, pass a mesh
    to :class:`AsyncExecutor` instead."""

    def __init__(self, mesh=None, max_devices: Optional[int] = None,
                 chunk_periods: Optional[int] = None):
        super().__init__(mesh=mesh, chunk_periods=chunk_periods)
        self.max_devices = max_devices

    def _resolve_mesh(self):
        if self.mesh is None:
            self.mesh = make_batch_mesh(self.max_devices)
        return ensure_batch_mesh(self.mesh)

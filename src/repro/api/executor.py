"""Pluggable Experiment runtimes: how buckets are scheduled on hardware.

The lowering (``api.lowering``) splits every bucket into three pure
phases — host-side *plan*, non-blocking device *dispatch*, blocking
*collect* — and an :class:`Executor` is nothing but a composition policy
over those phases.  All executors are bit-identical in results (the
phases are pure functions of the bucket; test-enforced); they differ only
in wall-clock and device layout:

* :class:`SerialExecutor` — plan → dispatch → collect one bucket at a
  time, blocking between buckets.  The reference runtime (today's
  behaviour) and the default.
* :class:`AsyncExecutor` — dispatch bucket *N* without blocking and
  overlap bucket *N+1*'s host planning (channel Monte-Carlo draws and
  Algorithm-1 bisections are pure host NumPy) behind its device
  execution; only block at collection.  On a multi-bucket grid the host
  plans the next program while the device retires the previous one.
  ``max_in_flight=N`` caps the dispatch backlog (device residency) at N
  buckets without changing a single result bit.
* :class:`MeshExecutor` — shard every bucket's flattened
  (scenario × seed) batch axis across a 1-D device mesh
  (``launch.mesh.make_batch_mesh``), created lazily over all available
  devices when none is given.

Executors yield ``(bucket, (losses, accs, times, global_batch))`` in
bucket order as results become available, which is what lets
``Experiment.stream`` hand back incrementally collected ``Results``.
"""
from __future__ import annotations

from collections import deque
from typing import Iterator, Optional, Sequence, Tuple

from repro.api.lowering import (Bucket, collect_bucket, dispatch_bucket,
                                plan_bucket)
from repro.launch.mesh import ensure_batch_mesh, make_batch_mesh

BucketSeries = Tuple[Bucket, tuple]


class Executor:
    """Composition policy over the plan/dispatch/collect bucket phases."""

    def __init__(self, mesh=None):
        self.mesh = mesh

    def _resolve_mesh(self):
        return None if self.mesh is None else ensure_batch_mesh(self.mesh)

    def execute(self, buckets: Sequence[Bucket], data, test,
                periods: int) -> Iterator[BucketSeries]:
        """Yield ``(bucket, (losses, accs, times, global_batch))`` per
        bucket, in bucket order."""
        raise NotImplementedError


class SerialExecutor(Executor):
    """One bucket at a time, blocking at each collection (reference)."""

    def execute(self, buckets, data, test, periods):
        mesh = self._resolve_mesh()
        for bucket in buckets:
            handle = dispatch_bucket(plan_bucket(bucket, data, periods),
                                     data, test, mesh=mesh)
            yield bucket, collect_bucket(handle)


class AsyncExecutor(Executor):
    """Cross-bucket pipelining: plan+dispatch buckets back-to-back,
    collect afterwards.

    Because jax dispatch is asynchronous, dispatching bucket *N* returns
    as soon as the program is enqueued — bucket *N+1*'s host planning
    (pure NumPy) then runs concurrently with *N*'s device execution, and
    the only blocking happens at collection.  Results are bit-identical
    to :class:`SerialExecutor` (test-enforced): every phase is a pure
    function of its bucket, so scheduling order cannot change values.

    ``max_in_flight`` bounds how many dispatched buckets' device values
    stay resident at once: once the window is full, the oldest bucket is
    collected (blocking) before the next one is planned and dispatched.
    The default (``None``) keeps every bucket in flight — today's
    behaviour, fine at current scales; thousand-bucket studies should
    cap the backlog.  ``max_in_flight=1`` degenerates to the serial
    schedule.  The cap is a scheduling policy only: capped and uncapped
    runs are bit-identical (test-enforced).
    """

    def __init__(self, mesh=None, max_in_flight: Optional[int] = None):
        super().__init__(mesh=mesh)
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1, got {max_in_flight}")
        self.max_in_flight = max_in_flight

    def execute(self, buckets, data, test, periods):
        mesh = self._resolve_mesh()
        cap = self.max_in_flight or len(buckets)
        pending: deque = deque()
        for bucket in buckets:
            if len(pending) >= cap:
                handle = pending.popleft()
                yield handle.bucket, collect_bucket(handle)
            pending.append(
                dispatch_bucket(plan_bucket(bucket, data, periods),
                                data, test, mesh=mesh))
        while pending:
            handle = pending.popleft()
            yield handle.bucket, collect_bucket(handle)


class MeshExecutor(SerialExecutor):
    """Serial schedule with every bucket's batch axis sharded over a 1-D
    device mesh; builds ``make_batch_mesh(max_devices)`` lazily when no
    mesh is given.  For sharding *and* cross-bucket overlap, pass a mesh
    to :class:`AsyncExecutor` instead."""

    def __init__(self, mesh=None, max_devices: Optional[int] = None):
        super().__init__(mesh=mesh)
        self.max_devices = max_devices

    def _resolve_mesh(self):
        if self.mesh is None:
            self.mesh = make_batch_mesh(self.max_devices)
        return ensure_batch_mesh(self.mesh)

"""The declarative experiment driver: specs in, named Results out.

    from repro.api import AsyncExecutor, Experiment, ScenarioSpec, grid

    study = grid(ScenarioSpec(fleet=fleet, name="cpu6", seeds=range(8)),
                 policy=("proposed", "online", "full"),
                 **{"cell.radius_m": [100.0, 200.0, 400.0]})
    res = Experiment(data, test, study).run(periods=100,
                                            executor=AsyncExecutor())
    res.sel(policy="proposed", cell_radius_m=200.0).speed(0.6)

``run`` lowers the whole grid through ``api.lowering``: rows (spec × seed)
are deduplicated (a spec declared twice is computed once and fanned back
out) and grouped into shape-compatible buckets, each bucket executing as
ONE jitted ``vmap(lax.scan)`` over the flattened (scenario × seed) axis.
*How* buckets are scheduled is the executor's policy (``api.executor``):
serial reference, async cross-bucket pipelining, or mesh-sharded — all
bit-identical in results.  ``stream`` yields cumulative partial
``Results`` as each bucket collects, for long grids where early buckets
are worth looking at before the last one retires.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.api.executor import Executor, SerialExecutor
from repro.api.lowering import Bucket, group_rows
from repro.api.results import (COORD_NAMES, Results, ResultsBuilder,
                               assign_row_coords, empty_coords)
from repro.api.spec import ScenarioSpec
from repro.data.pipeline import ClassificationData


@dataclass
class Experiment:
    """A family of scenarios over one dataset, lowered bucket-by-bucket.

    ``specs`` may be any spec sequence, including a
    :class:`repro.api.study.Study` — swept study axes then surface as
    extra ``Results`` coordinates.  Device placement is the executor's
    job: ``run(executor=MeshExecutor(...))`` (the former
    ``Experiment(mesh=...)`` shim is gone).
    """
    data: ClassificationData
    test: ClassificationData
    specs: Sequence[ScenarioSpec]

    def lower(self, replan: Optional[int] = None,
              bands: bool = False) -> List[Bucket]:
        """The bucketed row plan (introspection / tests): which rows share
        a compiled program, in execution order.  Duplicate (spec, seed)
        rows collapse onto one computed row (``Row.indices`` fans out).
        ``replan`` applies the run-level closed-loop override and
        ``bands`` the power-of-two K-band sub-bucketing (see
        :meth:`run`)."""
        return group_rows(self.specs, replan=replan, bands=bands)

    def run(self, periods: int, executor: Optional[Executor] = None,
            replan: Optional[int] = None, audit: bool = False,
            bands: bool = False) -> Results:
        """Run the whole grid and return the complete ``Results``.

        ``replan=R`` turns every FEEL-family bucket closed-loop for this
        run: horizons execute as R-period chunks and each chunk's
        realized loss decays update the ξ estimator before the next chunk
        is planned (Algorithm 1 with live feedback — overriding any
        per-spec ``ScenarioSpec.replan``).  Dev-family buckets have no ξ
        loop and ignore the override.

        ``audit=True`` runs the static-analysis passes alongside the
        computation (see :mod:`repro.analysis`): the padding-taint
        certificate and compile-hygiene checks over every bucket's
        lowered program (probed under ``engine.suspend_trace_count`` —
        no device work, but host planning runs once more per bucket),
        the determinism lint, and a trace-ledger audit scoped to this
        run proving zero retraces across chunks and replan rounds.  The
        report attaches as ``Results.audit``; error-severity findings
        raise :class:`repro.analysis.AuditError`.  Audit composes with
        any executor — the passes inspect programs and ledgers, not the
        execution schedule.

        ``bands=True`` splits each bucket by power-of-two K band
        (``repro.topology.band_width``) so a mixed-K grid pads each row
        to its band instead of the grid max — one compiled program per
        band, bit-identical results (the band is invisible to
        ``Results``), order-of-magnitude less padded compute when fleet
        sizes span decades.
        """
        if audit:
            from repro.fed import engine as _engine
            mark = len(_engine.trace_events())
        builder = None
        for builder in self._collected(periods, executor, replan,
                                       bands=bands):
            pass
        res = builder.build()
        if audit:
            report = self._audit(periods, replan, mark, bands=bands)
            res = _dc_replace(res, audit=report)
            report.raise_on_error()
        return res

    def _audit(self, periods: int, replan: Optional[int], mark: int,
               bands: bool = False):
        """The ``run(audit=True)`` pass bundle (see :mod:`repro.analysis`)."""
        from repro.analysis import compile_audit, determinism, taint
        from repro.analysis.report import AuditReport
        from repro.api import lowering
        from repro.fed import engine as _engine

        report = AuditReport()
        compile_audit.audit_traces(_engine.trace_events()[mark:],
                                   label="trace-ledger", report=report)
        for bucket in self.lower(replan=replan, bands=bands):
            plan = lowering.plan_bucket(bucket, self.data, periods)
            traced = lowering.trace_bucket(plan, self.data, self.test)
            taint.analyze_jaxpr(traced.closed, traced.in_labels,
                                traced.out_contracts,
                                program=traced.program, report=report)
            compile_audit.audit_jaxpr_hygiene(
                traced.closed, program=traced.program, report=report)
        determinism.lint_sources(report=report)
        return report

    def stream(self, periods: int, executor: Optional[Executor] = None,
               replan: Optional[int] = None,
               bands: bool = False) -> Iterator[Results]:
        """Yield a cumulative partial ``Results`` after each bucket
        collection (the final yield is the complete result).

        With an :class:`~repro.api.executor.AsyncExecutor` every bucket
        is already dispatched before the first yield, so consuming the
        stream slowly does not serialize the device work.
        """
        for builder in self._collected(periods, executor, replan,
                                       bands=bands):
            yield builder.partial()

    def _collected(self, periods: int, executor: Optional[Executor],
                   replan: Optional[int] = None, bands: bool = False
                   ) -> Iterator[ResultsBuilder]:
        """Drive the executor, yielding the builder after each bucket
        lands (``run`` assembles once at the end; ``stream`` snapshots a
        partial per yield)."""
        buckets = self.lower(replan=replan, bands=bands)
        if not buckets:
            raise ValueError("Experiment has no specs")
        if executor is None:
            executor = SerialExecutor()
        builder = ResultsBuilder(coords=self._coords(buckets),
                                 n_rows=self._n_rows(buckets),
                                 n_buckets=len(buckets))
        for bucket, (bl, ba, bt, bg) in executor.execute(
                buckets, self.data, self.test, periods):
            idx = np.array([i for row in bucket.rows
                            for i in row.indices], np.int64)
            take = np.array([j for j, row in enumerate(bucket.rows)
                             for _ in row.indices], np.int64)
            builder.add_rows(idx, bl[take], ba[take], bt[take], bg[take])
            yield builder

    @staticmethod
    def _n_rows(buckets: Sequence[Bucket]) -> int:
        return sum(len(r.indices) for b in buckets for r in b.rows)

    def _coords(self, buckets: Sequence[Bucket]):
        """Per-output-row coordinate columns: the standard labels plus, for
        Study specs, one column per swept axis (``axis_coords``)."""
        n_rows = self._n_rows(buckets)
        axis_coords = getattr(self.specs, "axis_coords", None)
        extra = [n for n in getattr(self.specs, "coord_names", ())
                 if n not in COORD_NAMES] if axis_coords else []
        coords = empty_coords(n_rows, extra=extra)
        for bucket in buckets:
            for row in bucket.rows:
                axes = axis_coords(row.spec) if axis_coords else {}
                for i in row.indices:
                    assign_row_coords(coords, i, row.spec, row.seed)
                    for name in extra:
                        if name in axes:
                            coords[name][i] = axes[name]
        return coords

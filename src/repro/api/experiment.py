"""The declarative experiment driver: specs in, named Results out.

    from repro.api import Experiment, ScenarioSpec

    specs = [ScenarioSpec(fleet=fleet, name="cpu6", partition=part,
                          policy=pol, seeds=range(8), b_max=64)
             for part in ("iid", "noniid")
             for pol in ("proposed", "online", "full")]
    res = Experiment(data, test, specs).run(periods=100)
    res.sel(policy="proposed").speed(0.6)

``run`` lowers the whole grid through ``api.lowering``: rows (spec × seed)
are grouped into shape-compatible buckets, each bucket executes as ONE
jitted ``vmap(lax.scan)`` program over the flattened (scenario × seed)
batch axis, and that axis is sharded across the devices of ``mesh`` when
one is given (``launch.mesh.make_batch_mesh()``; a 1-device mesh is the
CPU fallback and changes nothing but layout).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.api.lowering import (Bucket, group_rows, run_dev_bucket,
                                run_feel_bucket)
from repro.api.results import COORD_NAMES, Results
from repro.api.spec import ScenarioSpec
from repro.data.pipeline import ClassificationData


@dataclass
class Experiment:
    """A family of scenarios over one dataset, lowered bucket-by-bucket."""
    data: ClassificationData
    test: ClassificationData
    specs: Sequence[ScenarioSpec]
    mesh: Optional[object] = None        # launch.mesh.make_batch_mesh()

    def lower(self) -> List[Bucket]:
        """The bucketed row plan (introspection / tests): which rows share
        a compiled program, in execution order."""
        return group_rows(self.specs)

    def run(self, periods: int) -> Results:
        buckets = self.lower()
        if not buckets:
            raise ValueError("Experiment has no specs")
        n_rows = sum(len(b.rows) for b in buckets)
        losses = np.empty((n_rows, periods))
        accs = np.empty((n_rows, periods))
        times = np.empty((n_rows, periods))
        gb = np.empty((n_rows, periods), np.int64)
        coords = {name: np.empty(n_rows, object) for name in COORD_NAMES}
        coords["seed"] = np.empty(n_rows, np.int64)

        for bucket in buckets:
            runner = run_feel_bucket if bucket.kind == "feel" \
                else run_dev_bucket
            bl, ba, bt, bg = runner(bucket, self.data, self.test, periods,
                                    mesh=self.mesh)
            for j, row in enumerate(bucket.rows):
                i = row.index
                losses[i], accs[i], times[i], gb[i] = bl[j], ba[j], bt[j], \
                    bg[j]
                coords["fleet"][i] = row.spec.name or f"K{row.spec.k}"
                coords["partition"][i] = row.spec.partition
                coords["policy"][i] = row.spec.effective_policy
                coords["scheme"][i] = row.spec.scheme
                coords["seed"][i] = row.seed
                coords["spec"][i] = row.spec
        return Results(coords=coords, losses=losses, accs=accs, times=times,
                       global_batch=gb, n_buckets=len(buckets))

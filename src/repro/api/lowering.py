"""ScenarioSpec → compiled-program lowering, in three phases.

``group_rows(...)`` flattens the (spec × seed) grid of an experiment into
shape-compatible buckets (``ScenarioSpec.bucket_key``); duplicate
(spec, seed) occurrences collapse onto one computed row whose
``Row.indices`` fan the result back out to every output position.  Each
bucket then executes as ONE jitted program via three composable phases —
the split is what lets ``api.executor`` runtimes schedule buckets
differently without re-implementing the lowering:

* :func:`plan_bucket` — **host only** (pure NumPy): vectorized channel
  Monte-Carlo draws, Algorithm-1 bisections
  (``core.scheduler.plan_horizons_batch`` — shared-fleet rows fused into
  one lockstep solve), horizon dedup across rows that are
  scheduler-identical modulo partition/base_lr (``_plan_key``), batcher
  sampling, the cumulative latency ledger.  No device work, so an async
  runtime can overlap this with another bucket's device execution.
* :func:`dispatch_bucket` — enqueue the bucket's device program and
  return immediately (jax dispatch is asynchronous): one ``vmap(init)``
  over stacked per-row PRNG keys (bit-identical to per-row init —
  counter-based PRNG), then ``engine.run_trajectory_batch`` /
  ``run_dev_trajectory_batch``, a ``vmap(lax.scan)`` over the flattened
  (scenario × seed) axis, optionally sharded across a 1-D device mesh
  (``launch.mesh.make_batch_mesh``; rows padded cyclically, sliced back
  at collection).
* :func:`collect_bucket` — block on the device values and return host
  ``(losses, accs, times, global_batch)`` series, one row per *computed*
  row (callers fan out via ``Row.indices``).

Per-row rng streams (partitioner, batcher, scheduler channel draws) are
consumed in exactly the order the per-simulation path uses, so lowering a
grid produces bit-identical schedules to running each cell alone — and
the phases are pure functions of the bucket, so every executor schedule
(serial, async, meshed) produces bit-identical results.

Fleet size is NOT structural (``spec.bucket_key``): a bucket's rows may
carry different fleets.  Planning always runs at each row's true K (same
rng streams and ledgers as a solo run; Algorithm-1 rows fuse across
fleets via the masked ``core.solver.FleetRows`` path), then schedules /
index blocks are zero-padded to the bucket's ``k_pad`` and a per-row
``active`` mask ({0,1} per user row) rides into the device program,
where padded users contribute zero weight, zero batch and are excluded
from every parameter average — padded rows are bit-identical to solo
unpadded runs (test-enforced).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import ScenarioSpec
from repro.channels.model import Cell
from repro.core.scheduler import (DevScheduler, FeelScheduler,
                                  plan_horizons_batch)
from repro.data.pipeline import (FederatedBatcher, partition_iid,
                                 partition_noniid)
from repro.fed import engine, feel_model
from repro.launch.mesh import pad_batch

tree_map = jax.tree_util.tree_map


@dataclass(frozen=True)
class Row:
    """One *computed* (spec, seed) pair of a bucket's batch axis.

    ``indices`` are the experiment-output row positions this computation
    feeds: more than one when the same ``ScenarioSpec`` was declared
    twice — the duplicate is computed once and fanned back out.
    """
    spec: ScenarioSpec
    seed: int
    indices: Tuple[int, ...]

    @property
    def index(self) -> int:
        return self.indices[0]


@dataclass
class Bucket:
    """All rows sharing one ``bucket_key`` → one compiled program.

    Rows may carry fleets of different sizes (fleet is not structural —
    see ``spec.bucket_key``): the plan/dispatch phases pad every row's
    user axis to :attr:`k_pad` and thread a per-row active mask, so the
    compiled shape is one (padded) family for the whole bucket.
    """
    key: tuple
    rows: List[Row]

    @property
    def kind(self) -> str:
        return self.key[0]      # "feel" | "dev"

    @property
    def k_pad(self) -> int:
        """The padded user-axis width: max K over the bucket's rows."""
        return max(r.spec.k for r in self.rows)

    def active_mask(self) -> np.ndarray:
        """(n, k_pad) f32 {0,1}: row r's first ``spec.k`` users active."""
        mask = np.zeros((len(self.rows), self.k_pad), np.float32)
        for i, r in enumerate(self.rows):
            mask[i, :r.spec.k] = 1.0
        return mask


def group_rows(specs: Sequence[ScenarioSpec]) -> List[Bucket]:
    """Flatten specs × seeds into rows, grouped into first-seen-order
    buckets by shape compatibility.

    Duplicate (spec, seed) pairs — the same spec declared twice —
    deduplicate onto one row carrying every output index, so an
    experiment never runs one trajectory twice.
    """
    entries: Dict[tuple, List[list]] = {}
    seen: Dict[tuple, list] = {}
    index = 0
    for spec in specs:
        key = spec.bucket_key()
        for seed in spec.seeds:
            row_key = (spec, seed)
            if row_key in seen:
                seen[row_key].append(index)
            else:
                entry = [spec, seed, [index]]
                seen[row_key] = entry[2]
                entries.setdefault(key, []).append(entry)
            index += 1
    return [Bucket(key=key,
                   rows=[Row(spec=s, seed=sd, indices=tuple(ix))
                         for s, sd, ix in rows])
            for key, rows in entries.items()]


def _partition(spec: ScenarioSpec, data, seed: int):
    if spec.partition == "iid":
        return partition_iid(len(data.y), spec.k, seed)
    return partition_noniid(data.y, spec.k, seed=seed)


def _n_params(spec: ScenarioSpec, input_dim: int, classes: int = 10) -> int:
    dims = [input_dim] + [spec.hidden] * (spec.depth - 1) + [classes]
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


def _init_params_batch(rows: Sequence[Row], input_dim: int):
    """One vmapped init over the stacked per-row keys (bit-identical to
    per-row ``feel_model.init`` — threefry is counter-based)."""
    spec = rows[0].spec
    keys = jnp.stack([jax.random.key(r.seed) for r in rows])
    return jax.vmap(lambda k: feel_model.init(
        k, spec.hidden, depth=spec.depth, input_dim=input_dim))(keys)


def _pad_rows(trees, n: int, pad: int):
    """Cyclically repeat rows along every leading axis so a bucket divides
    the mesh — valid even when the mesh is larger than the bucket
    (pad > n); callers slice outputs back to ``n``."""
    if pad == 0:
        return trees
    wrap = np.arange(n + pad) % n
    return tree_map(
        lambda a: a[wrap] if hasattr(a, "ndim") else a, trees)


def _plan_key(r: Row) -> tuple:
    """Scheduler identity modulo ``base_lr``: two rows with equal keys
    consume identical rng streams and produce identical horizons (the
    partition only affects the *batcher*, and base_lr only rescales the
    lr row — rebuilt per row below), so the whole-grid lowering plans each
    unique key ONCE.  The full frozen ``CellConfig`` is part of the key:
    distinct wireless geometries (radius, bandwidth, tx power, frames)
    never share a planned horizon.  This is a structural win a per-cell
    driver cannot have: it never sees that its cells share planning
    work."""
    s = r.spec
    return (s.fleet, s.effective_policy, s.b_max, s.compression, s.cell,
            s.hidden, s.depth, r.seed)


def _rescale_lr(horizon, base_lr: float, ref_batch: float):
    """Per-row lr row for a shared horizon: η = η₀·√(B/B_ref), identical
    to what a scheduler constructed with this base_lr would emit."""
    return replace(horizon, lr=base_lr * np.sqrt(
        horizon.global_batch / ref_batch))


# ---------------------------------------------------------------------------
# phase containers
# ---------------------------------------------------------------------------


@dataclass
class BucketPlan:
    """Phase-1 output: everything host planning produced for one bucket.

    ``times``/``global_batch`` are final host-side results (one row per
    computed row); ``payload`` holds the kind-specific arrays the dispatch
    phase feeds the device program.
    """
    bucket: Bucket
    input_dim: int
    times: np.ndarray            # (n, P) cumulative simulated seconds
    global_batch: np.ndarray     # (n, P) int64
    payload: dict


@dataclass
class BucketHandle:
    """Phase-2 output: in-flight device values + finished host ledgers.

    ``losses``/``accs`` are (possibly padded) device arrays whose
    computation has been *dispatched* but not necessarily finished —
    :func:`collect_bucket` blocks and slices.
    """
    bucket: Bucket
    losses: object               # (n+pad, P) device array
    accs: object                 # (n+pad, P) device array
    times: np.ndarray
    global_batch: np.ndarray


# ---------------------------------------------------------------------------
# phase 1: plan (pure host NumPy)
# ---------------------------------------------------------------------------


def _plan_feel(bucket: Bucket, data, periods: int) -> BucketPlan:
    rows = bucket.rows
    spec0 = rows[0].spec
    input_dim = data.x.shape[1]
    n_params = _n_params(spec0, input_dim)

    # one scheduler (and one planned horizon) per unique plan key
    plan_keys = [_plan_key(r) for r in rows]
    unique: Dict[tuple, int] = {}
    schedulers = []
    for r, key in zip(rows, plan_keys):
        if key in unique:
            continue
        unique[key] = len(schedulers)
        schedulers.append(FeelScheduler(
            devices=r.spec.fleet, n_params=n_params,
            policy=r.spec.effective_policy, b_max=r.spec.b_max,
            base_lr=r.spec.base_lr, compression=r.spec.compression,
            cell_cfg=r.spec.cell, seed=r.seed))
    planned = plan_horizons_batch(schedulers, periods)

    # per-row planning runs at the row's TRUE fleet size (identical rng
    # streams and ledgers to a solo run); only the finished schedules are
    # zero-padded to the bucket's K so one program fits every row
    k_pad = bucket.k_pad
    schedules = []
    for r, key in zip(rows, plan_keys):
        parts = _partition(r.spec, data, r.seed)
        batcher = FederatedBatcher(parts, r.spec.b_max, r.seed)
        sched = schedulers[unique[key]]
        horizon = planned[unique[key]]
        if r.spec.base_lr != sched.base_lr:
            horizon = _rescale_lr(horizon, r.spec.base_lr, sched.ref_batch)
        schedules.append(engine.pad_schedule(engine.build_schedule(
            sched, batcher, r.spec.fleet, periods, r.spec.local_steps,
            horizon=horizon), k_pad))
    return BucketPlan(
        bucket=bucket, input_dim=input_dim,
        times=np.stack([s.times for s in schedules]),
        global_batch=np.stack([s.global_batch for s in schedules]),
        payload={"schedules": schedules, "active": bucket.active_mask()})


def _plan_dev(bucket: Bucket, data, periods: int) -> BucketPlan:
    rows = bucket.rows
    spec0 = rows[0].spec
    input_dim = data.x.shape[1]
    n_params = _n_params(spec0, input_dim)
    batch = spec0.dev_epoch_batch
    k_pad = bucket.k_pad

    horizons = []
    for r in rows:
        parts = _partition(r.spec, data, r.seed)
        sched = DevScheduler(
            devices=r.spec.fleet, parts=parts, batch=batch,
            # model-based FL uploads the raw parameters: d·p bits
            payload_bits=32.0 * n_params,
            upload=(r.spec.scheme == "model_fl"),
            seed=r.seed, cell=Cell.make(r.seed, r.spec.cell))
        horizons.append(sched.plan_horizon(periods))
    n = len(rows)
    # rows plan at their true K; pad idx user rows with index 0 (the
    # active mask keeps those devices out of every parameter average)
    idx = np.zeros((n, periods, k_pad, batch), np.int64)
    for i, (r, h) in enumerate(zip(rows, horizons)):
        idx[i, :, :r.spec.k] = h.idx
    return BucketPlan(
        bucket=bucket, input_dim=input_dim,
        times=np.stack([h.times for h in horizons]),
        global_batch=np.stack([
            np.full(periods, batch * r.spec.k, np.int64) for r in rows]),
        payload={"idx": idx,
                 "lr": np.array([r.spec.base_lr for r in rows],
                                np.float32),
                 "active": bucket.active_mask()})


def plan_bucket(bucket: Bucket, data, periods: int) -> BucketPlan:
    """Host-side planning for one bucket (no device work dispatched)."""
    planner = _plan_feel if bucket.kind == "feel" else _plan_dev
    return planner(bucket, data, periods)


# ---------------------------------------------------------------------------
# phase 2: dispatch (enqueue the device program, return without blocking)
# ---------------------------------------------------------------------------


def _dispatch_feel(plan: BucketPlan, data, test, mesh) -> BucketHandle:
    rows = plan.bucket.rows
    spec0 = rows[0].spec
    schedules = plan.payload["schedules"]
    active = plan.payload["active"]
    k_pad = plan.bucket.k_pad

    params0 = _init_params_batch(rows, plan.input_dim)
    residual0 = tree_map(
        lambda p: jnp.zeros((p.shape[0], k_pad) + p.shape[1:], p.dtype),
        params0)

    n = len(rows)
    pad = 0 if mesh is None else pad_batch(n, mesh)
    if pad:
        params0, residual0, active = _pad_rows(
            (params0, residual0, active), n, pad)
        schedules = [schedules[i % n] for i in range(n + pad)]
    _, _, (losses, accs, _) = engine.run_trajectory_batch(
        params0, residual0, schedules, data, test,
        local_steps=spec0.local_steps, compress=spec0.compress,
        ratio=spec0.compression, mesh=mesh, active=active)
    return BucketHandle(bucket=plan.bucket, losses=losses, accs=accs,
                        times=plan.times, global_batch=plan.global_batch)


def _dispatch_dev(plan: BucketPlan, data, test, mesh) -> BucketHandle:
    rows = plan.bucket.rows
    spec0 = rows[0].spec
    k_pad = plan.bucket.k_pad

    p0 = _init_params_batch(rows, plan.input_dim)
    dev_params0 = tree_map(
        lambda a: jnp.broadcast_to(
            a[:, None], (a.shape[0], k_pad) + a.shape[1:]), p0)
    idx, lr = plan.payload["idx"], plan.payload["lr"]
    active = plan.payload["active"]

    n = len(rows)
    pad = 0 if mesh is None else pad_batch(n, mesh)
    if pad:
        dev_params0, idx, lr, active = _pad_rows(
            (dev_params0, idx, lr, active), n, pad)
    _, (losses, accs) = engine.run_dev_trajectory_batch(
        dev_params0, idx, lr, data, test,
        average=(spec0.scheme == "model_fl"), mesh=mesh, active=active)
    return BucketHandle(bucket=plan.bucket, losses=losses, accs=accs,
                        times=plan.times, global_batch=plan.global_batch)


def dispatch_bucket(plan: BucketPlan, data, test, mesh=None) -> BucketHandle:
    """Enqueue one planned bucket's device program; returns immediately
    with in-flight device values (jax dispatch is asynchronous)."""
    dispatcher = (_dispatch_feel if plan.bucket.kind == "feel"
                  else _dispatch_dev)
    return dispatcher(plan, data, test, mesh)


# ---------------------------------------------------------------------------
# phase 3: collect (block, slice padding, hand back host arrays)
# ---------------------------------------------------------------------------


def collect_bucket(handle: BucketHandle):
    """Block until the bucket's device values are ready; returns
    ``(losses, accs, times, global_batch)`` — (n, P) host arrays, one row
    per computed row (fan out duplicates via ``Row.indices``)."""
    n = len(handle.bucket.rows)
    losses = np.asarray(handle.losses)[:n]
    accs = np.asarray(handle.accs)[:n]
    return losses, accs, handle.times, handle.global_batch

"""ScenarioSpec → compiled-program lowering.

``lower(...)`` groups the flattened (spec × seed) rows of an experiment
into shape-compatible buckets (``ScenarioSpec.bucket_key``) and executes
each bucket as ONE jitted program:

* host side, vectorized across the whole bucket: initial parameters come
  from a single ``vmap(init)`` over the stacked per-row PRNG keys
  (bit-identical to per-row init — counter-based PRNG), FEEL horizons from
  ``core.scheduler.plan_horizons_batch`` (shared-fleet Algorithm-1 rows
  fused into one lockstep solve), dev-scheme ledgers from
  ``core.scheduler.DevScheduler``;
* device side: ``engine.run_trajectory_batch`` /
  ``engine.run_dev_trajectory_batch`` — a ``vmap(lax.scan)`` over the
  flattened (scenario × seed) batch axis, optionally sharded across a
  1-D device mesh (``launch.mesh.make_batch_mesh``), padded to the mesh
  size by wrapping the leading rows and sliced back afterwards.

Per-row rng streams (partitioner, batcher, scheduler channel draws) are
consumed in exactly the order the per-simulation path uses, so lowering a
grid produces bit-identical schedules to running each cell alone.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import ScenarioSpec
from repro.channels.model import Cell
from repro.core.scheduler import (DevScheduler, FeelScheduler,
                                  plan_horizons_batch)
from repro.data.pipeline import (FederatedBatcher, partition_iid,
                                 partition_noniid)
from repro.fed import engine, feel_model
from repro.launch.mesh import pad_batch

tree_map = jax.tree_util.tree_map


@dataclass(frozen=True)
class Row:
    """One realized (spec, seed) pair — one entry of a bucket's batch axis."""
    spec: ScenarioSpec
    seed: int
    index: int                  # row position in the experiment's output


@dataclass
class Bucket:
    """All rows sharing one ``bucket_key`` → one compiled program."""
    key: tuple
    rows: List[Row]

    @property
    def kind(self) -> str:
        return self.key[0]      # "feel" | "dev"


def group_rows(specs: Sequence[ScenarioSpec]) -> List[Bucket]:
    """Flatten specs × seeds into rows, grouped into first-seen-order
    buckets by shape compatibility."""
    buckets: Dict[tuple, Bucket] = {}
    index = 0
    for spec in specs:
        key = spec.bucket_key()
        for seed in spec.seeds:
            buckets.setdefault(key, Bucket(key=key, rows=[])) \
                .rows.append(Row(spec=spec, seed=seed, index=index))
            index += 1
    return list(buckets.values())


def _partition(spec: ScenarioSpec, data, seed: int):
    if spec.partition == "iid":
        return partition_iid(len(data.y), spec.k, seed)
    return partition_noniid(data.y, spec.k, seed=seed)


def _n_params(spec: ScenarioSpec, input_dim: int, classes: int = 10) -> int:
    dims = [input_dim] + [spec.hidden] * (spec.depth - 1) + [classes]
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


def _init_params_batch(rows: Sequence[Row], input_dim: int):
    """One vmapped init over the stacked per-row keys (bit-identical to
    per-row ``feel_model.init`` — threefry is counter-based)."""
    spec = rows[0].spec
    keys = jnp.stack([jax.random.key(r.seed) for r in rows])
    return jax.vmap(lambda k: feel_model.init(
        k, spec.hidden, depth=spec.depth, input_dim=input_dim))(keys)


def _pad_rows(trees, n: int, pad: int):
    """Cyclically repeat rows along every leading axis so a bucket divides
    the mesh — valid even when the mesh is larger than the bucket
    (pad > n); callers slice outputs back to ``n``."""
    if pad == 0:
        return trees
    wrap = np.arange(n + pad) % n
    return tree_map(
        lambda a: a[wrap] if hasattr(a, "ndim") else a, trees)


def _plan_key(r: Row) -> tuple:
    """Scheduler identity modulo ``base_lr``: two rows with equal keys
    consume identical rng streams and produce identical horizons (the
    partition only affects the *batcher*, and base_lr only rescales the
    lr row — rebuilt per row below), so the whole-grid lowering plans each
    unique key ONCE.  This is a structural win a per-cell driver cannot
    have: it never sees that its cells share planning work."""
    s = r.spec
    return (s.fleet, s.effective_policy, s.b_max, s.compression, s.cell,
            s.hidden, s.depth, r.seed)


def _rescale_lr(horizon, base_lr: float, ref_batch: float):
    """Per-row lr row for a shared horizon: η = η₀·√(B/B_ref), identical
    to what a scheduler constructed with this base_lr would emit."""
    return replace(horizon, lr=base_lr * np.sqrt(
        horizon.global_batch / ref_batch))


def run_feel_bucket(bucket: Bucket, data, test, periods: int, mesh=None):
    """Lower + execute one FEEL-family bucket; returns (N, P) series."""
    rows = bucket.rows
    spec0 = rows[0].spec
    input_dim = data.x.shape[1]
    n_params = _n_params(spec0, input_dim)

    # one scheduler (and one planned horizon) per unique plan key
    plan_keys = [_plan_key(r) for r in rows]
    unique: Dict[tuple, int] = {}
    schedulers = []
    for r, key in zip(rows, plan_keys):
        if key in unique:
            continue
        unique[key] = len(schedulers)
        schedulers.append(FeelScheduler(
            devices=r.spec.fleet, n_params=n_params,
            policy=r.spec.effective_policy, b_max=r.spec.b_max,
            base_lr=r.spec.base_lr, compression=r.spec.compression,
            cell_cfg=r.spec.cell, seed=r.seed))
    planned = plan_horizons_batch(schedulers, periods)

    schedules = []
    for r, key in zip(rows, plan_keys):
        parts = _partition(r.spec, data, r.seed)
        batcher = FederatedBatcher(parts, r.spec.b_max, r.seed)
        sched = schedulers[unique[key]]
        horizon = planned[unique[key]]
        if r.spec.base_lr != sched.base_lr:
            horizon = _rescale_lr(horizon, r.spec.base_lr, sched.ref_batch)
        schedules.append(engine.build_schedule(
            sched, batcher, r.spec.fleet, periods, r.spec.local_steps,
            horizon=horizon))

    params0 = _init_params_batch(rows, input_dim)
    residual0 = tree_map(
        lambda p: jnp.zeros((p.shape[0], spec0.k) + p.shape[1:], p.dtype),
        params0)

    n = len(rows)
    pad = 0 if mesh is None else pad_batch(n, mesh)
    if pad:
        params0, residual0 = _pad_rows((params0, residual0), n, pad)
        schedules = [schedules[i % n] for i in range(n + pad)]
    _, _, (losses, accs, _) = engine.run_trajectory_batch(
        params0, residual0, schedules, data, test,
        local_steps=spec0.local_steps, compress=spec0.compress,
        ratio=spec0.compression, mesh=mesh)
    losses = np.asarray(losses)[:n]
    accs = np.asarray(accs)[:n]
    times = np.stack([s.times for s in schedules[:n]])
    gb = np.stack([s.global_batch for s in schedules[:n]])
    return losses, accs, times, gb


def run_dev_bucket(bucket: Bucket, data, test, periods: int, mesh=None):
    """Lower + execute one individual/model_fl bucket (N, P) series."""
    rows = bucket.rows
    spec0 = rows[0].spec
    input_dim = data.x.shape[1]
    n_params = _n_params(spec0, input_dim)
    batch = spec0.dev_epoch_batch

    horizons = []
    for r in rows:
        parts = _partition(r.spec, data, r.seed)
        sched = DevScheduler(
            devices=r.spec.fleet, parts=parts, batch=batch,
            # model-based FL uploads the raw parameters: d·p bits
            payload_bits=32.0 * n_params,
            upload=(r.spec.scheme == "model_fl"),
            seed=r.seed, cell=Cell.make(r.seed, r.spec.cell))
        horizons.append(sched.plan_horizon(periods))

    p0 = _init_params_batch(rows, input_dim)
    dev_params0 = tree_map(
        lambda a: jnp.broadcast_to(
            a[:, None], (a.shape[0], spec0.k) + a.shape[1:]), p0)
    idx = np.stack([h.idx for h in horizons])
    lr = np.array([r.spec.base_lr for r in rows], np.float32)

    n = len(rows)
    pad = 0 if mesh is None else pad_batch(n, mesh)
    if pad:
        dev_params0, idx, lr = _pad_rows((dev_params0, idx, lr), n, pad)
    _, (losses, accs) = engine.run_dev_trajectory_batch(
        dev_params0, idx, lr, data, test,
        average=(spec0.scheme == "model_fl"), mesh=mesh)
    losses = np.asarray(losses)[:n]
    accs = np.asarray(accs)[:n]
    times = np.stack([h.times for h in horizons])
    gb = np.broadcast_to(batch * spec0.k,
                         (n, periods)).astype(np.int64).copy()
    return losses, accs, times, gb

"""ScenarioSpec → compiled-program lowering, in three phases.

``group_rows(...)`` flattens the (spec × seed) grid of an experiment into
shape-compatible buckets (``ScenarioSpec.bucket_key``); duplicate
(spec, seed) occurrences collapse onto one computed row whose
``Row.indices`` fan the result back out to every output position.  Each
bucket then executes as ONE jitted program via three composable phases —
the split is what lets ``api.executor`` runtimes schedule buckets
differently without re-implementing the lowering:

* :func:`plan_bucket` — **host only** (pure NumPy): vectorized channel
  Monte-Carlo draws, Algorithm-1 bisections
  (``core.scheduler.plan_horizons_batch`` — shared-fleet rows fused into
  one lockstep solve), horizon dedup across rows that are
  scheduler-identical modulo partition/base_lr (``_plan_key``), batcher
  sampling, the cumulative latency ledger.  No device work, so an async
  runtime can overlap this with another bucket's device execution.
* :func:`dispatch_bucket` — enqueue the bucket's device program and
  return immediately (jax dispatch is asynchronous): one ``vmap(init)``
  over stacked per-row PRNG keys (bit-identical to per-row init —
  counter-based PRNG), then ``engine.run_trajectory_batch`` /
  ``run_dev_trajectory_batch``, a ``vmap(lax.scan)`` over the flattened
  (scenario × seed) axis, optionally sharded across a 1-D device mesh
  (``launch.mesh.make_batch_mesh``; rows padded cyclically, sliced back
  at collection).
* :func:`collect_bucket` — block on the device values and return host
  ``(losses, accs, times, global_batch)`` series, one row per *computed*
  row (callers fan out via ``Row.indices``).

Per-row rng streams (partitioner, batcher, scheduler channel draws) are
consumed in exactly the order the per-simulation path uses, so lowering a
grid produces bit-identical schedules to running each cell alone — and
the phases are pure functions of the bucket, so every executor schedule
(serial, async, meshed) produces bit-identical results.

Fleet size is NOT structural (``spec.bucket_key``): a bucket's rows may
carry different fleets.  Planning always runs at each row's true K (same
rng streams and ledgers as a solo run; Algorithm-1 rows fuse across
fleets via the masked ``core.solver.FleetRows`` path), then schedules /
index blocks are zero-padded to the bucket's ``k_pad`` and a per-row
``active`` mask ({0,1} per user row) rides into the device program,
where padded users contribute zero weight, zero batch and are excluded
from every parameter average — padded rows are bit-identical to solo
unpadded runs (test-enforced).

Chunked horizons (:class:`BucketRun`)
-------------------------------------
The phases also run *per chunk*: a bucket's horizon splits into
``chunk``-period pieces, each planned (host), dispatched (device, with
the engine's explicit :class:`~repro.fed.engine.EngineState` carried
between chunks) and collected independently.  Planner state — scheduler
rng streams / ``_b_cache`` / ``_period``, batcher rng streams, per-row
time offsets — persists across chunks, and every chunked accumulation
(the time ledger's seeded cumsum, the carried scan state) is arranged so
that with ξ frozen the chunked run is **bit-identical** to the monolithic
one (test-enforced across executors and meshes).  Because planning now
happens *between* chunks, a bucket whose specs set ``replan=`` closes the
Algorithm-1 loop: chunk *c*'s realized loss decays feed each row's ξ
estimator (``observe_series``) before chunk *c+1* is planned — the
paper's adaptive re-planning, with warm-started B* grids
(``plan_horizons_batch(..., warm_start=True)``).  Closed-loop rows each
own their scheduler (realized decays are per-trajectory, so the
``_plan_key`` horizon dedup does not apply).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import ScenarioSpec
from repro.channels.model import Cell
from repro.core.scheduler import (DevScheduler, FeelScheduler,
                                  plan_horizons_batch)
from repro.data.pipeline import (FederatedBatcher, partition_iid,
                                 partition_noniid)
from repro.fed import engine, feel_model, model_engine
from repro.launch.mesh import pad_batch
from repro.topology import band_width

tree_map = jax.tree_util.tree_map


@dataclass(frozen=True)
class Row:
    """One *computed* (spec, seed) pair of a bucket's batch axis.

    ``indices`` are the experiment-output row positions this computation
    feeds: more than one when the same ``ScenarioSpec`` was declared
    twice — the duplicate is computed once and fanned back out.
    """
    spec: ScenarioSpec
    seed: int
    indices: Tuple[int, ...]

    @property
    def index(self) -> int:
        return self.indices[0]


@dataclass
class Bucket:
    """All rows sharing one ``bucket_key`` → one compiled program.

    Rows may carry fleets of different sizes (fleet is not structural —
    see ``spec.bucket_key``): the plan/dispatch phases pad every row's
    user axis to :attr:`k_pad` and thread a per-row active mask, so the
    compiled shape is one (padded) family for the whole bucket.

    ``replan`` is the bucket's closed-loop ξ interval (``None`` = open
    loop): it comes from the rows' specs (structural, so all rows agree)
    or from a run-level override, and executors must execute such a
    bucket as ``replan``-period chunks via :class:`BucketRun`.

    ``band`` is the K-band sub-bucketing width (``group_rows(...,
    bands=True)``): rows pad to the power-of-two band instead of the
    bucket max, so a mixed-K grid compiles one program per *band* — a
    K=8 row stops paying for a K=10240 neighbour's padding — while bands
    of equal width keep sharing one compiled program (``program_key``
    already carries ``k_pad``).  ``None`` (the default) is the PR-4
    single-program behaviour.
    """
    key: tuple
    rows: List[Row]
    replan: Optional[int] = None
    band: Optional[int] = None

    @property
    def kind(self) -> str:
        return self.key[0]      # "feel" | "dev"

    @property
    def k_pad(self) -> int:
        """The padded user-axis width: the K band when sub-bucketed,
        else max K over the bucket's rows."""
        if self.band is not None:
            return self.band
        return max(r.spec.k for r in self.rows)

    def active_mask(self) -> np.ndarray:
        """(n, k_pad) f32 {0,1}: row r's first ``spec.k`` users active."""
        mask = np.zeros((len(self.rows), self.k_pad), np.float32)
        for i, r in enumerate(self.rows):
            mask[i, :r.spec.k] = 1.0
        return mask


def group_rows(specs: Sequence[ScenarioSpec],
               replan: Optional[int] = None,
               bands: bool = False) -> List[Bucket]:
    """Flatten specs × seeds into rows, grouped into first-seen-order
    buckets by shape compatibility.

    Duplicate (spec, seed) pairs — the same spec declared twice —
    deduplicate onto one row carrying every output index, so an
    experiment never runs one trajectory twice.

    ``replan`` overrides every FEEL-family spec's own ``replan`` for this
    lowering (the ``Experiment.run(replan=...)`` convenience — one knob
    for a whole grid).  Dev-family specs have no ξ loop and silently keep
    open-loop execution, so a mixed grid accepts the override.

    ``bands=True`` further splits each bucket by the power-of-two K band
    (``repro.topology.band_width``) of its rows: one :class:`Bucket` —
    and hence one compiled program — per band, each padded to the band
    width instead of the grid max.  Results are bit-identical to the
    unbanded lowering (each row's plan and trajectory never depended on
    its neighbours' padding); only compile-shape economics change.
    """
    if replan is not None and (not isinstance(replan, int)
                               or isinstance(replan, bool) or replan < 1):
        raise ValueError(
            f"replan must be a positive int (periods per closed-loop "
            f"chunk), got {replan!r}")
    entries: Dict[tuple, List[list]] = {}
    seen: Dict[tuple, list] = {}
    replans: Dict[tuple, Optional[int]] = {}
    index = 0
    for spec in specs:
        if spec.is_dev_scheme:
            eff = None
            eff_spec = spec
        else:
            eff = spec.replan if replan is None else replan
            # dedup and group on the spec AS EXECUTED: under a run-level
            # override, specs differing only in replan are one trajectory
            eff_spec = (spec if eff == spec.replan
                        else replace(spec, replan=eff))
        key = eff_spec.bucket_key()
        band = band_width(eff_spec.k) if bands else None
        replans[key] = eff
        for seed in spec.seeds:
            row_key = (eff_spec, seed)
            if row_key in seen:
                seen[row_key].append(index)
            else:
                # Row keeps the first-seen ORIGINAL spec (coords and
                # Study.axis_coords lookups are keyed by declared specs)
                entry = [spec, seed, [index]]
                seen[row_key] = entry[2]
                entries.setdefault((key, band), []).append(entry)
            index += 1
    return [Bucket(key=key,
                   rows=[Row(spec=s, seed=sd, indices=tuple(ix))
                         for s, sd, ix in rows],
                   replan=replans[key], band=band)
            for (key, band), rows in entries.items()]


def _partition(spec: ScenarioSpec, data, seed: int):
    if spec.partition == "iid":
        return partition_iid(len(data.y), spec.k, seed)
    return partition_noniid(data.y, spec.k, seed=seed)


def _n_params(spec: ScenarioSpec, input_dim: int, classes: int = 10) -> int:
    if spec.model_family != "feel_mlp":
        # the big-model families price the uplink at the true parameter
        # count of the derived ArchConfig
        return model_engine.family_n_params(
            spec.model_family, spec.hidden, spec.depth)
    dims = [input_dim] + [spec.hidden] * (spec.depth - 1) + [classes]
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


def _init_params_batch(rows: Sequence[Row], input_dim: int):
    """One vmapped init over the stacked per-row keys (bit-identical to
    per-row ``feel_model.init`` — threefry is counter-based)."""
    spec = rows[0].spec
    keys = jnp.stack([jax.random.key(r.seed) for r in rows])
    if spec.model_family != "feel_mlp":
        return model_engine.init_params_batch(
            spec.model_family, spec.hidden, spec.depth, keys)
    return jax.vmap(lambda k: feel_model.init(
        k, spec.hidden, depth=spec.depth, input_dim=input_dim))(keys)


def _pad_rows(trees, n: int, pad: int):
    """Cyclically repeat rows along every leading axis so a bucket divides
    the mesh — valid even when the mesh is larger than the bucket
    (pad > n); callers slice outputs back to ``n``."""
    if pad == 0:
        return trees
    wrap = np.arange(n + pad) % n
    return tree_map(
        lambda a: a[wrap] if hasattr(a, "ndim") else a, trees)


def _plan_key(r: Row) -> tuple:
    """Scheduler identity modulo ``base_lr``: two rows with equal keys
    consume identical rng streams and produce identical horizons (the
    partition only affects the *batcher*, and base_lr only rescales the
    lr row — rebuilt per row below), so the whole-grid lowering plans each
    unique key ONCE.  The full frozen ``CellConfig`` is part of the key:
    distinct wireless geometries (radius, bandwidth, tx power, frames)
    never share a planned horizon.  This is a structural win a per-cell
    driver cannot have: it never sees that its cells share planning
    work."""
    s = r.spec
    return (s.fleet, s.effective_policy, s.b_max, s.compression, s.cell,
            s.hidden, s.depth, r.seed, s.sampling, s.topology,
            s.fading, s.faults, s.energy, s.adapt_tau, s.model_family)


def _rescale_lr(horizon, base_lr: float, ref_batch: float):
    """Per-row lr row for a shared horizon: η = η₀·√(B/B_ref), identical
    to what a scheduler constructed with this base_lr would emit."""
    return replace(horizon, lr=base_lr * np.sqrt(
        horizon.global_batch / ref_batch))


# ---------------------------------------------------------------------------
# phase containers
# ---------------------------------------------------------------------------


@dataclass
class BucketPlan:
    """Phase-1 output: everything host planning produced for one bucket.

    ``times``/``global_batch`` are final host-side results (one row per
    computed row); ``payload`` holds the kind-specific arrays the dispatch
    phase feeds the device program.
    """
    bucket: Bucket
    input_dim: int
    times: np.ndarray            # (n, P) cumulative simulated seconds
    global_batch: np.ndarray     # (n, P) int64
    payload: dict


@dataclass
class BucketHandle:
    """Phase-2 output: in-flight device values + finished host ledgers.

    ``losses``/``accs`` are (possibly padded) device arrays whose
    computation has been *dispatched* but not necessarily finished —
    :func:`collect_bucket` blocks and slices.  ``decays`` (FEEL family)
    are the realized per-period loss decays — the closed-loop ξ feedback
    signal — and ``state`` is the engine carry after this dispatch, which
    the chunked path resumes from without blocking.
    """
    bucket: Bucket
    losses: object               # (n+pad, P) device array
    accs: object                 # (n+pad, P) device array
    times: np.ndarray
    global_batch: np.ndarray
    decays: object = None        # (n+pad, P) device array (feel only)
    state: object = None         # engine.EngineState after this chunk
    energy: object = None        # (n, P, k_pad) host joules ledger, or None


# ---------------------------------------------------------------------------
# phase 1: plan (pure host NumPy) — stateful planners shared by the
# monolithic path (one plan() covering the whole horizon) and the chunked
# path (one plan() per chunk, rng streams / time offsets carried)
# ---------------------------------------------------------------------------


class _FeelPlanner:
    """Host planning state for one FEEL bucket, resumable chunk by chunk.

    ``per_row=False`` (open loop): one scheduler — and one planned
    horizon — per unique ``_plan_key`` (scheduler-identical rows modulo
    partition/base_lr share a plan; lr rebuilt per row).  Successive
    ``plan()`` calls continue every rng stream and time offset, so N
    chunked plans are bit-identical to one monolithic plan.

    ``per_row=True`` (closed loop): every row owns its scheduler and ξ
    estimator — realized decays are per-trajectory, so horizon sharing
    would feed one EWMA from diverging series.  ``observe()`` lands chunk
    *c*'s decays before ``plan()`` produces chunk *c+1*.
    """

    def __init__(self, bucket: Bucket, data, per_row: bool = False):
        rows = bucket.rows
        self.bucket = bucket
        self.per_row = per_row
        self.input_dim = data.x.shape[1]
        n_params = _n_params(rows[0].spec, self.input_dim)

        def make_scheduler(r: Row) -> FeelScheduler:
            return FeelScheduler(
                devices=r.spec.fleet, n_params=n_params,
                policy=r.spec.effective_policy, b_max=r.spec.b_max,
                base_lr=r.spec.base_lr, compression=r.spec.compression,
                cell_cfg=r.spec.cell, seed=r.seed,
                sampling=r.spec.sampling, topology=r.spec.topology,
                fading=r.spec.fading, faults=r.spec.faults,
                energy=r.spec.energy)

        self.schedulers: List[FeelScheduler] = []
        self._sched_of: List[int] = []
        if per_row:
            for r in rows:
                self._sched_of.append(len(self.schedulers))
                self.schedulers.append(make_scheduler(r))
        else:
            unique: Dict[tuple, int] = {}
            for r in rows:
                key = _plan_key(r)
                if key not in unique:
                    unique[key] = len(self.schedulers)
                    self.schedulers.append(make_scheduler(r))
                self._sched_of.append(unique[key])
        self.batchers = [
            FederatedBatcher(_partition(r.spec, data, r.seed),
                             r.spec.b_max, r.seed) for r in rows]
        self._offsets = np.zeros(len(rows))
        # adaptive local steps: the bucket-consensus τ the NEXT chunk
        # executes (starts at the structural local_steps; re-scored at
        # every plan() once ξ feedback has landed)
        self._tau = rows[0].spec.local_steps

    def plan(self, periods: int, warm_start: bool = False) -> BucketPlan:
        rows = self.bucket.rows
        spec0 = rows[0].spec
        tau = None
        if spec0.adapt_tau is not None:
            # bucket consensus: every row scores the candidate set with
            # its own realized comm/comp split and ξ estimate; the bucket
            # takes the MIN (conservative — never more local compute than
            # the most communication-starved row wants), because τ shapes
            # the scan body and the whole bucket must agree per chunk
            tau = min(s.recommend_tau(spec0.adapt_tau.choices, self._tau)
                      for s in self.schedulers)
            self._tau = tau
        # per_row IS the closed loop: the decay-cap steer only applies
        # once rows own their estimators (and only after feedback landed)
        planned = plan_horizons_batch(self.schedulers, periods,
                                      warm_start=warm_start,
                                      closed_loop=self.per_row)
        # per-row planning runs at the row's TRUE fleet size (identical
        # rng streams and ledgers to a solo run); only the finished
        # schedules are zero-padded to the bucket's K so one program fits
        # every row
        k_pad = self.bucket.k_pad
        schedules = []
        parts: List[Optional[np.ndarray]] = []
        clouds: List[Optional[np.ndarray]] = []
        energies: List[Optional[np.ndarray]] = []
        for i, r in enumerate(rows):
            sched = self.schedulers[self._sched_of[i]]
            horizon = planned[self._sched_of[i]]
            if r.spec.base_lr != sched.base_lr:
                horizon = _rescale_lr(horizon, r.spec.base_lr,
                                      sched.ref_batch)
            parts.append(horizon.participation)
            clouds.append(horizon.cloud)
            energies.append(horizon.energy)
            s = engine.build_schedule(
                sched, self.batchers[i], r.spec.fleet, periods,
                r.spec.local_steps if tau is None else tau,
                horizon=horizon,
                time_offset=float(self._offsets[i]))
            self._offsets[i] = s.times[-1]
            schedules.append(engine.pad_schedule(s, k_pad))
        # static (n, k_pad) padding mask unless some row sampled this
        # chunk — then the realized cohorts ride a time-varying
        # (n, P, k_pad) mask whose padded columns stay exactly 0
        active = self.bucket.active_mask()
        if any(p is not None for p in parts):
            active = np.repeat(active[:, None, :], periods, axis=1)
            for i, (r, p) in enumerate(zip(rows, parts)):
                if p is not None:
                    active[i, :, :r.spec.k] = p
        payload = {"schedules": schedules, "active": active}
        if tau is not None:
            payload["tau"] = tau
        if any(e is not None for e in energies):
            # host-only per-user joules ledger (never crosses the device
            # boundary); padded columns stay exactly 0
            en = np.zeros((len(rows), periods, k_pad))
            for i, (r, e) in enumerate(zip(rows, energies)):
                if e is not None:
                    en[i, :, :r.spec.k] = e
            payload["energy"] = en
        if rows[0].spec.topology is not None:   # structural: all rows agree
            payload["member"] = np.stack([
                r.spec.topology.member_matrix(r.spec.k, k_pad)
                for r in rows])
            payload["cloud"] = np.stack(clouds).astype(np.float32)
        return BucketPlan(
            bucket=self.bucket, input_dim=self.input_dim,
            times=np.stack([s.times for s in schedules]),
            global_batch=np.stack([s.global_batch for s in schedules]),
            payload=payload)

    def observe(self, decays: np.ndarray, global_batch: np.ndarray):
        """Feed one collected chunk's realized per-period loss decays —
        (n, P_c) row-major — into each row's ξ estimator."""
        assert self.per_row, "closed-loop feedback needs per-row schedulers"
        for i in range(len(self.bucket.rows)):
            self.schedulers[i].observe_series(decays[i], global_batch[i])


class _DevPlanner:
    """Host planning state for one dev-family bucket (chunk-resumable;
    no ξ loop — ``observe`` does not exist by design)."""

    def __init__(self, bucket: Bucket, data, per_row: bool = False):
        rows = bucket.rows
        spec0 = rows[0].spec
        self.bucket = bucket
        self.input_dim = data.x.shape[1]
        self.batch = spec0.dev_epoch_batch
        n_params = _n_params(spec0, self.input_dim)
        self.schedulers = [
            DevScheduler(
                devices=r.spec.fleet, parts=_partition(r.spec, data, r.seed),
                batch=self.batch,
                # model-based FL uploads the raw parameters: d·p bits
                payload_bits=32.0 * n_params,
                upload=(r.spec.scheme == "model_fl"),
                seed=r.seed, cell=Cell.make(r.seed, r.spec.cell),
                sampling=r.spec.sampling)
            for r in rows]
        self._offsets = np.zeros(len(rows))

    def plan(self, periods: int, warm_start: bool = False) -> BucketPlan:
        rows = self.bucket.rows
        k_pad = self.bucket.k_pad
        horizons = []
        for i, s in enumerate(self.schedulers):
            h = s.plan_horizon(periods, time_offset=float(self._offsets[i]))
            self._offsets[i] = h.times[-1]
            horizons.append(h)
        n = len(rows)
        # rows plan at their true K; pad idx user rows with index 0 (the
        # active mask keeps those devices out of every parameter average)
        idx = np.zeros((n, periods, k_pad, self.batch), np.int64)
        for i, (r, h) in enumerate(zip(rows, horizons)):
            idx[i, :, :r.spec.k] = h.idx
        active = self.bucket.active_mask()
        if any(h.participation is not None for h in horizons):
            active = np.repeat(active[:, None, :], periods, axis=1)
            for i, (r, h) in enumerate(zip(rows, horizons)):
                if h.participation is not None:
                    active[i, :, :r.spec.k] = h.participation
            gb = np.stack([
                (self.batch * h.participation.astype(np.int64).sum(1)
                 if h.participation is not None
                 else np.full(periods, self.batch * r.spec.k, np.int64))
                for r, h in zip(rows, horizons)])
        else:
            gb = np.stack([
                np.full(periods, self.batch * r.spec.k, np.int64)
                for r in rows])
        return BucketPlan(
            bucket=self.bucket, input_dim=self.input_dim,
            times=np.stack([h.times for h in horizons]),
            global_batch=gb,
            payload={"idx": idx,
                     "lr": np.array([r.spec.base_lr for r in rows],
                                    np.float32),
                     "active": active})


def _make_planner(bucket: Bucket, data, per_row: bool = False):
    cls = _FeelPlanner if bucket.kind == "feel" else _DevPlanner
    return cls(bucket, data, per_row=per_row)


def plan_bucket(bucket: Bucket, data, periods: int) -> BucketPlan:
    """Host-side planning for one bucket (no device work dispatched)."""
    return _make_planner(bucket, data).plan(periods)


# ---------------------------------------------------------------------------
# compiled-program identity (the serve-layer compile cache key)
# ---------------------------------------------------------------------------


def chunk_lengths(periods: int, chunk: Optional[int]) -> Tuple[int, ...]:
    """The per-chunk period counts a ``chunk``-chunked horizon dispatches:
    ``chunk_lengths(7, 3) == (3, 3, 1)`` — one compiled program per
    *distinct* length (``None`` → one monolithic chunk)."""
    if chunk is None:
        return (periods,)
    chunk = min(max(1, chunk), periods)
    out = [chunk] * (periods // chunk)
    if periods % chunk:
        out.append(periods % chunk)
    return tuple(out)


def program_key(bucket: Bucket, n_rows: int, periods: int,
                data, test) -> tuple:
    """Hashable identity of the compiled program one dispatch would run.

    Two dispatches with equal keys hit the same jitted executable (zero
    new traces — the warm-admission contract ``repro.serve``'s
    :class:`~repro.serve.ProgramCache` keeps counters on); two dispatches
    with different keys *may* still share one (the key is deliberately an
    over-approximation, never the reverse).  Soundness rests on
    ``bucket.key`` carrying every static-config knob of the engine's
    program caches (scheme family, ``b_max``/epoch batch = the slot
    width, ``local_steps``, compression, model dims, ``replan``) while
    the remaining axes of the abstract trace signature are exactly
    ``n_rows`` (the padded batch axis), ``k_pad``, the chunk's period
    count, and the dataset/test shapes — all named here.  Dtypes never
    vary: every input crosses ``engine.host_to_device``.

    ``n_rows`` is the batch axis *as dispatched* (mesh-padded when the
    executor pads the bucket to a device mesh).
    """
    return (bucket.key, int(n_rows), bucket.k_pad, int(periods),
            tuple(data.x.shape), tuple(data.y.shape),
            tuple(test.x.shape), tuple(test.y.shape))


def bucket_program_keys(bucket: Bucket, n_rows: int, periods: int,
                        chunk: Optional[int], data, test) -> Tuple[tuple, ...]:
    """Every distinct :func:`program_key` a chunked run of this bucket
    will dispatch (first-use order, deduplicated): one per distinct
    chunk length."""
    out, seen = [], set()
    for p_c in chunk_lengths(periods, chunk):
        key = program_key(bucket, n_rows, p_c, data, test)
        if key not in seen:
            seen.add(key)
            out.append(key)
    return tuple(out)


# ---------------------------------------------------------------------------
# phase 2: dispatch (enqueue the device program, return without blocking)
# ---------------------------------------------------------------------------


def _dispatch_feel(plan: BucketPlan, data, test, mesh,
                   state=None) -> BucketHandle:
    rows = plan.bucket.rows
    spec0 = rows[0].spec
    schedules = plan.payload["schedules"]
    active = plan.payload["active"]
    member = plan.payload.get("member")      # hierarchical buckets only
    # adaptive buckets execute the chunk at the planner's consensus τ
    local_steps = plan.payload.get("tau", spec0.local_steps)
    k_pad = plan.bucket.k_pad

    n = len(rows)
    pad = 0 if mesh is None else pad_batch(n, mesh)
    if state is None:
        params0 = _init_params_batch(rows, plan.input_dim)
        residual0 = tree_map(
            lambda p: jnp.zeros((p.shape[0], k_pad) + p.shape[1:], p.dtype),
            params0)
        if member is not None:
            # every edge replica starts from the row's global init
            params0 = tree_map(
                lambda a: jnp.broadcast_to(
                    a[:, None], (a.shape[0], member.shape[1]) + a.shape[1:]),
                params0)
        if pad:
            params0, residual0 = _pad_rows((params0, residual0), n, pad)
        state = engine.EngineState(params=params0, residual=residual0)
    if pad:
        active = _pad_rows(active, n, pad)
        schedules = [schedules[i % n] for i in range(n + pad)]
    if member is not None:
        cloud = plan.payload["cloud"]
        if pad:
            member, cloud = _pad_rows((member, cloud), n, pad)
        state, (losses, accs, decays) = engine.resume_hier_trajectory_batch(
            state, member, cloud, schedules, data, test,
            local_steps=local_steps, compress=spec0.compress,
            ratio=spec0.compression, mesh=mesh, active=active)
    elif spec0.model_family != "feel_mlp":
        # big-model families: the transformer / mamba2 train-step scan
        state, (losses, accs, decays) = \
            model_engine.resume_model_trajectory_batch(
                state, schedules, data, test,
                model_family=spec0.model_family, hidden=spec0.hidden,
                depth=spec0.depth, compress=spec0.compress,
                ratio=spec0.compression, mesh=mesh, active=active)
    else:
        state, (losses, accs, decays) = engine.resume_trajectory_batch(
            state, schedules, data, test,
            local_steps=local_steps, compress=spec0.compress,
            ratio=spec0.compression, mesh=mesh, active=active)
    return BucketHandle(bucket=plan.bucket, losses=losses, accs=accs,
                        times=plan.times, global_batch=plan.global_batch,
                        decays=decays, state=state,
                        energy=plan.payload.get("energy"))


def _dispatch_dev(plan: BucketPlan, data, test, mesh,
                  state=None) -> BucketHandle:
    rows = plan.bucket.rows
    spec0 = rows[0].spec
    k_pad = plan.bucket.k_pad
    idx, lr = plan.payload["idx"], plan.payload["lr"]
    active = plan.payload["active"]

    n = len(rows)
    pad = 0 if mesh is None else pad_batch(n, mesh)
    if state is None:
        p0 = _init_params_batch(rows, plan.input_dim)
        dev_params0 = tree_map(
            lambda a: jnp.broadcast_to(
                a[:, None], (a.shape[0], k_pad) + a.shape[1:]), p0)
        if pad:
            dev_params0 = _pad_rows(dev_params0, n, pad)
        state = engine.EngineState(params=dev_params0)
    if pad:
        idx, lr, active = _pad_rows((idx, lr, active), n, pad)
    state, (losses, accs) = engine.resume_dev_trajectory_batch(
        state, idx, lr, data, test,
        average=(spec0.scheme == "model_fl"), mesh=mesh, active=active)
    return BucketHandle(bucket=plan.bucket, losses=losses, accs=accs,
                        times=plan.times, global_batch=plan.global_batch,
                        state=state)


def dispatch_bucket(plan: BucketPlan, data, test, mesh=None,
                    state=None) -> BucketHandle:
    """Enqueue one planned bucket's device program; returns immediately
    with in-flight device values (jax dispatch is asynchronous).

    ``state`` resumes from a previous chunk's engine carry (chunked
    horizons); ``None`` initializes a fresh trajectory."""
    dispatcher = (_dispatch_feel if plan.bucket.kind == "feel"
                  else _dispatch_dev)
    return dispatcher(plan, data, test, mesh, state=state)


# ---------------------------------------------------------------------------
# phase 2b: probe (lower the bucket program WITHOUT running it — the
# static-analysis entry point)
# ---------------------------------------------------------------------------


@dataclass
class TracedBucket:
    """One bucket program lowered for inspection, with taint labels.

    ``closed`` is the closed jaxpr of the exact jitted program the
    dispatch phase would run; ``in_labels`` / ``out_contracts`` are the
    padding-taint annotations aligned with its flattened inputs/outputs
    (see :mod:`repro.analysis.taint`).  Built by :func:`trace_bucket`
    under ``engine.suspend_trace_count`` so probing never pollutes the
    trace ledger the compile audit certifies.
    """
    program: str
    closed: object               # jax.core.ClosedJaxpr
    in_labels: list
    out_contracts: dict
    bucket: Bucket
    periods: int


def _flat_labels(label_tree) -> list:
    return jax.tree_util.tree_leaves(label_tree)


def trace_bucket(plan: BucketPlan, data, test) -> TracedBucket:
    """Lower one planned bucket's device program to a labeled jaxpr.

    Mirrors the dispatch phase's argument assembly exactly (fresh-state
    form, no mesh — sharding does not change program semantics), then
    traces with ``jax.make_jaxpr`` instead of executing.  The labels
    state the padded-lane facts the schedule construction guarantees:

    * FEEL: ``residual0`` and ``active`` hold exact zeros on padded
      lanes; ``idx``/``weight``/``batch`` padded lanes are *variant* —
      deliberately weaker than ``pad_schedule`` provides, so the
      certificate also covers hand-built (garbage) schedules and rests
      only on the program's own ``w*=active`` / ``bk*=active`` masking;
    * dev: per-device params are variant on padded lanes, ``active`` is
      zero; the program's masked means must do all the work.

    The FEEL output contract pins the SBC ``residual`` carry to
    ``Known(0)`` on padded lanes — the inductive step that extends the
    single-program certificate across chunked/replanned horizons (the
    next chunk's ``residual0`` label is exactly this output's contract).
    """
    from repro.analysis.taint import LaneLabel, NO_LABEL, OutContract

    rows = plan.bucket.rows
    spec0 = rows[0].spec
    k_pad = plan.bucket.k_pad
    n = len(rows)
    periods = plan.times.shape[1]
    # adaptive buckets: probe the program variant THIS chunk would run
    local_steps = plan.payload.get("tau", spec0.local_steps)
    name = f"{plan.bucket.key}/P{periods}"
    if plan.bucket.band is not None:
        name += f"/B{plan.bucket.band}"
    if "tau" in plan.payload:
        name += f"/T{local_steps}"
    with engine.suspend_trace_count():
        if plan.bucket.kind == "feel":
            schedules = plan.payload["schedules"]
            # the engine always hands the scan a time-varying (n, P, K)
            # mask (a static mask broadcasts) — trace what it runs.  The
            # label states only the structural fact: padded-user lanes
            # are exact zeros (a sampled-out participant is data, not a
            # lane, so it needs no certificate).
            active = engine._normalize_active_batch(
                plan.payload["active"], n, periods, k_pad)
            params0 = _init_params_batch(rows, plan.input_dim)
            residual0 = tree_map(
                lambda p: jnp.zeros((p.shape[0], k_pad) + p.shape[1:],
                                    p.dtype), params0)
            member = plan.payload.get("member")
            xs = engine.stack_schedules(schedules)
            data_args = engine.host_to_device(
                (data.x, data.y, test.x, test.y))
            if member is not None:
                params_e0 = tree_map(
                    lambda a: jnp.broadcast_to(
                        a[:, None],
                        (a.shape[0], member.shape[1]) + a.shape[1:]),
                    params0)
                member_d = engine.host_to_device(np.asarray(member))
                cloud = engine.host_to_device(
                    np.asarray(plan.payload["cloud"]))
                fn = engine.hier_trajectory_program(
                    local_steps, spec0.compress, spec0.compression,
                    n_edges=member.shape[1])
                closed = jax.make_jaxpr(fn)(
                    params_e0, residual0, member_d, active, cloud, xs,
                    *data_args)
                # member's padded-user columns are all-zero one-hots —
                # the monoid identity of the routing contraction — and
                # active's padded lanes are zero; per-edge replicas are
                # global values (no user lane), so NO_LABEL
                labels = (
                    tree_map(lambda _: NO_LABEL, params_e0),
                    tree_map(lambda _: LaneLabel(1, 0.0), residual0),
                    LaneLabel(2, 0.0),
                    LaneLabel(2, 0.0),
                    NO_LABEL,
                    {"idx": LaneLabel(2), "weight": LaneLabel(2),
                     "batch": LaneLabel(2), "lr": NO_LABEL,
                     "aggden": NO_LABEL},
                    NO_LABEL, NO_LABEL, NO_LABEL, NO_LABEL)
                n_leaves = len(jax.tree_util.tree_leaves(params_e0))
            elif spec0.model_family != "feel_mlp":
                # big-model families trace against the tokenized datasets
                # but share the MLP scan's label/contract story verbatim:
                # the program's own masking must re-establish padding
                # safety from variant schedule lanes
                tok, lab = model_engine.tokenize(data)
                test_tok, _ = model_engine.tokenize(test)
                data_args = engine.host_to_device(
                    (tok, lab, test_tok, np.asarray(test.y)))
                fn = model_engine.model_trajectory_program(
                    spec0.model_family, spec0.hidden, spec0.depth,
                    spec0.compress, spec0.compression)
                closed = jax.make_jaxpr(fn)(
                    params0, residual0, active, xs, *data_args)
                labels = (
                    tree_map(lambda _: NO_LABEL, params0),
                    tree_map(lambda _: LaneLabel(1, 0.0), residual0),
                    LaneLabel(2, 0.0),
                    {"idx": LaneLabel(2), "weight": LaneLabel(2),
                     "batch": LaneLabel(2), "lr": NO_LABEL,
                     "aggden": NO_LABEL},
                    NO_LABEL, NO_LABEL, NO_LABEL, NO_LABEL)
                n_leaves = len(jax.tree_util.tree_leaves(params0))
            else:
                fn = engine.trajectory_program(
                    local_steps, spec0.compress, spec0.compression)
                closed = jax.make_jaxpr(fn)(
                    params0, residual0, active, xs, *data_args)
                # aggden is a per-period scalar (no user lane): NO_LABEL
                labels = (
                    tree_map(lambda _: NO_LABEL, params0),
                    tree_map(lambda _: LaneLabel(1, 0.0), residual0),
                    LaneLabel(2, 0.0),
                    {"idx": LaneLabel(2), "weight": LaneLabel(2),
                     "batch": LaneLabel(2), "lr": NO_LABEL,
                     "aggden": NO_LABEL},
                    NO_LABEL, NO_LABEL, NO_LABEL, NO_LABEL)
                n_leaves = len(jax.tree_util.tree_leaves(params0))
            # outputs: (params, residual, (losses, accs, decays))
            contracts = {n_leaves + i: OutContract(axis=1, value=0.0)
                         for i in range(n_leaves)}
        else:
            idx, lr = plan.payload["idx"], plan.payload["lr"]
            active = engine._normalize_active_batch(
                plan.payload["active"], n, periods, k_pad)
            p0 = _init_params_batch(rows, plan.input_dim)
            dev_params0 = tree_map(
                lambda a: jnp.broadcast_to(
                    a[:, None], (a.shape[0], k_pad) + a.shape[1:]), p0)
            idx = engine.host_to_device(np.asarray(idx))
            batched = (dev_params0, idx, *engine.host_to_device(
                (np.asarray(lr), active)))
            data_args = engine.host_to_device(
                (data.x, data.y, test.x, test.y))
            fn = engine.dev_trajectory_program(
                average=(spec0.scheme == "model_fl"))
            closed = jax.make_jaxpr(fn)(*batched, *data_args)
            labels = (
                tree_map(lambda _: LaneLabel(1, "variant"), dev_params0),
                LaneLabel(2), NO_LABEL, LaneLabel(2, 0.0),
                NO_LABEL, NO_LABEL, NO_LABEL, NO_LABEL)
            contracts = {}
    return TracedBucket(program=name, closed=closed,
                        in_labels=_flat_labels(labels),
                        out_contracts=contracts, bucket=plan.bucket,
                        periods=periods)


def audit_bucket_taint(plan: BucketPlan, data, test, report=None):
    """Run the padding-taint pass over one planned bucket's program."""
    from repro.analysis import taint
    traced = trace_bucket(plan, data, test)
    return taint.analyze_jaxpr(
        traced.closed, traced.in_labels, traced.out_contracts,
        program=traced.program, report=report)


# ---------------------------------------------------------------------------
# phase 3: collect (block, slice padding, hand back host arrays)
# ---------------------------------------------------------------------------


def collect_bucket(handle: BucketHandle):
    """Block until the bucket's device values are ready; returns
    ``(losses, accs, times, global_batch)`` — (n, P) host arrays, one row
    per computed row (fan out duplicates via ``Row.indices``)."""
    n = len(handle.bucket.rows)
    losses = np.asarray(handle.losses)[:n]
    accs = np.asarray(handle.accs)[:n]
    return losses, accs, handle.times, handle.global_batch


# ---------------------------------------------------------------------------
# chunked horizons: the per-chunk phase loop as a resumable state machine
# ---------------------------------------------------------------------------


@dataclass
class BucketRun:
    """Chunked, resumable execution of one bucket — the intra-bucket
    pipeline.

    The horizon splits into ``chunk``-period pieces; each piece runs the
    plan → dispatch → collect phases with all host state (scheduler /
    batcher rng streams, time offsets) and device state (the engine's
    :class:`~repro.fed.engine.EngineState` carry) threaded through.  The
    executor composes three operations:

    * :meth:`advance` — plan the next chunk (host NumPy) and dispatch its
      device program (non-blocking).  Because jax dispatch is
      asynchronous, calling ``advance`` while the previous chunk is still
      executing overlaps chunk *c+1*'s bisections and channel Monte-Carlo
      behind chunk *c*'s device work.
    * :meth:`collect` — block on the oldest in-flight chunk and bank its
      series.  When the bucket is closed-loop (``bucket.replan``), this is
      also where the chunk's realized loss decays feed every row's ξ
      estimator — so the *next* ``advance`` re-plans Algorithm 1 with the
      updated estimate (warm-started B* grids).
    * :attr:`can_advance` — scheduling guard: closed-loop buckets must
      collect chunk *c* before planning chunk *c+1* (the feedback is the
      point); open-loop buckets may run arbitrarily far ahead.

    With ξ frozen (open loop) any chunk size — and any interleaving of
    ``advance``/``collect`` the guard admits — is bit-identical to the
    monolithic three-phase path (test-enforced).
    """
    bucket: Bucket
    data: object
    test: object
    periods: int
    chunk: int
    mesh: object = None
    planned: int = 0
    dispatched: int = 0
    collected: int = 0
    _planner: object = None
    _state: object = None
    _pending: deque = field(default_factory=deque)
    _chunks: list = field(default_factory=list)
    _decays: list = field(default_factory=list)
    _energy: list = field(default_factory=list)

    def __post_init__(self):
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        self.chunk = min(self.chunk, self.periods)
        self.closed_loop = (self.bucket.replan is not None
                            and self.bucket.kind == "feel")
        self._planner = _make_planner(self.bucket, self.data,
                                      per_row=self.closed_loop)

    @property
    def done(self) -> bool:
        return self.collected >= self.periods

    @property
    def can_advance(self) -> bool:
        """Whether the next chunk can be planned+dispatched right now
        (without a blocking collect first)."""
        if self.dispatched >= self.periods:
            return False
        return not (self.closed_loop and self._pending)

    def advance(self) -> None:
        """Plan and dispatch the next chunk (host work + async enqueue)."""
        if not self.can_advance:
            raise RuntimeError(
                "cannot advance: horizon fully dispatched, or a "
                "closed-loop chunk awaits collection")
        p_c = min(self.chunk, self.periods - self.planned)
        warm = self.closed_loop and self.planned > 0
        plan = self._planner.plan(p_c, warm_start=warm)
        self.planned += p_c
        handle = dispatch_bucket(plan, self.data, self.test,
                                 mesh=self.mesh, state=self._state)
        self._state = handle.state
        self._pending.append((p_c, handle))
        self.dispatched += p_c

    def collect(self) -> tuple:
        """Block on the oldest in-flight chunk; bank its host series and
        (closed loop) feed its realized decays to the ξ estimators.
        Returns the banked ``(losses, accs, times, global_batch)`` chunk —
        each ``(n, P_c)`` — so streaming consumers (``repro.serve``) can
        forward per-chunk results without reaching into the run."""
        if not self._pending:
            raise RuntimeError("no chunk in flight to collect")
        p_c, handle = self._pending.popleft()
        n = len(self.bucket.rows)
        losses = np.asarray(handle.losses)[:n]
        accs = np.asarray(handle.accs)[:n]
        if self.closed_loop:
            decays = np.asarray(handle.decays)[:n]
            self._decays.append(decays)
            self._planner.observe(decays, handle.global_batch)
        chunk = (losses, accs, handle.times, handle.global_batch)
        self._chunks.append(chunk)
        if handle.energy is not None:
            self._energy.append(handle.energy)
        self.collected += p_c
        return chunk

    def park(self) -> list:
        """Suspend the run at the current chunk boundary: collect every
        in-flight chunk (returned, oldest first, so the caller can still
        stream them) and fence the engine carry
        (:meth:`~repro.fed.engine.EngineState.block_until_ready`).  A
        parked run holds only finished host/device buffers — resuming it
        later (plain :meth:`advance`) is bit-identical to never having
        parked, because chunked execution is interleaving-invariant by
        construction."""
        banked = []
        while self._pending:
            banked.append(self.collect())
        if self._state is not None:
            self._state.block_until_ready()
        return banked

    @property
    def realized_decays(self) -> Optional[np.ndarray]:
        """(n, collected) realized per-period loss decays banked so far
        (closed-loop runs only — ``None`` open loop)."""
        if not self._decays:
            return None
        return np.concatenate(self._decays, axis=1)

    @property
    def energy_ledger(self) -> Optional[np.ndarray]:
        """(n, collected, k_pad) per-user joules spent per period, banked
        chunk by chunk (``None`` unless the bucket's specs set an
        ``EnergyBudget``).  A host-side ledger like ``times`` — it never
        crosses the device boundary."""
        if not self._energy:
            return None
        return np.concatenate(self._energy, axis=1)

    def result(self):
        """The full-horizon ``(losses, accs, times, global_batch)`` —
        chunk series concatenated along the period axis."""
        if not self.done:
            raise RuntimeError(
                f"bucket not fully collected: {self.collected} of "
                f"{self.periods} periods")
        return tuple(np.concatenate([c[j] for c in self._chunks], axis=1)
                     for j in range(4))

    def run_serial(self):
        """The reference schedule: strictly plan → dispatch → collect one
        chunk at a time.  Returns :meth:`result`."""
        while not self.done:
            if self.can_advance:
                self.advance()
            self.collect()
        return self.result()

    def drain(self):
        """Finish the bucket with maximal plan-ahead: dispatch whatever
        the closed-loop guard admits, collect otherwise.  Returns
        :meth:`result`."""
        while not self.done:
            while self.can_advance:
                self.advance()
            self.collect()
        return self.result()

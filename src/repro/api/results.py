"""Structured sweep results with named scenario axes.

A :class:`Results` is a flat table: one row per realized (scenario, seed)
pair, one column block per trajectory series (period-major).  Row
coordinates — ``fleet``, ``partition``, ``policy``, ``scheme``, ``seed`` —
are first-class, so reductions and selections are label-driven instead of
string-key parsing:

    res = Experiment(data, test, specs).run(periods=100)
    res.sel(policy="proposed", partition="noniid").speed(0.6)
    res.sel(scheme="model_fl").final_acc.mean()

The label coordinates are conveniences and need not be unique: two specs
differing only in, say, ``base_lr`` or ``b_max`` share every label.  The
``spec`` coordinate is the precise one — it holds the originating
:class:`ScenarioSpec` itself, so ``res.sel(spec=my_spec)`` always
isolates exactly one scenario's seed rows, and :meth:`Results.cells`
groups by it (never merging distinct scenarios, whatever their labels).

Experiments built from a :func:`repro.api.study.grid` additionally carry
one coordinate per swept axis (dotted geometry axes sanitized:
``cell.radius_m`` → ``cell_radius_m``; the fleet-size axis ``users``
surfaces as ``num_users``), so ``res.sel(cell_radius_m=200.0)`` or
``res.sel(num_users=8)`` selects an operating point without any string
parsing, and :meth:`Results.unique` walks an axis in declaration order
(``for k in res.unique("num_users"): res.sel(num_users=k)...`` is the
paper's accuracy-vs-K figure loop).

:class:`ResultsBuilder` assembles a ``Results`` incrementally from
per-bucket chunks as executors collect them — there is no preallocated
full block, and :meth:`ResultsBuilder.partial` exposes the rows collected
so far (the streaming surface behind ``Experiment.stream``).

NaN accuracies mean "not evaluated at this period" (the python reference
engine only scores at eval points); :func:`time_to_target` masks them
explicitly before comparing, so an unevaluated period never counts as a
miss *or* a hit and no invalid-compare warnings leak.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Mapping, Tuple

import numpy as np

COORD_NAMES = ("fleet", "partition", "policy", "scheme", "seed", "spec")


def empty_coords(n_rows: int, extra=()) -> Dict[str, np.ndarray]:
    """Allocate the per-row coordinate columns for ``n_rows`` output rows:
    the standard :data:`COORD_NAMES` plus any ``extra`` (study-axis)
    names.  Shared by ``Experiment`` and the ``repro.serve`` per-request
    views, so every ``Results`` producer agrees on column layout."""
    coords = {name: np.empty(n_rows, object)
              for name in (*COORD_NAMES, *extra)}
    coords["seed"] = np.empty(n_rows, np.int64)
    return coords


def assign_row_coords(coords: Dict[str, np.ndarray], i: int,
                      spec, seed: int) -> None:
    """Fill output row ``i``'s standard coordinates from its originating
    spec — the single definition of how a ``ScenarioSpec`` labels a row."""
    coords["fleet"][i] = spec.name or f"K{spec.k}"
    coords["partition"][i] = spec.partition
    coords["policy"][i] = spec.effective_policy
    coords["scheme"][i] = spec.scheme
    coords["seed"][i] = seed
    coords["spec"][i] = spec


def time_to_target(accs, times, target_acc: float):
    """Simulated seconds until accuracy first reaches ``target_acc``.

    ``accs``/``times``: (..., periods).  NaN accuracies are masked out
    before the comparison (explicitly "not evaluated", never "failed"),
    and rows that never reach the target return ``inf``.
    """
    accs = np.asarray(accs, float)
    times = np.asarray(times, float)
    hit = np.where(np.isnan(accs), False, accs >= target_acc)
    return np.where(hit, times, np.inf).min(axis=-1)


@dataclass(frozen=True)
class Results:
    """Named-axis sweep output: (row, period) series + per-row coords."""
    coords: Mapping[str, np.ndarray]   # each (rows,): COORD_NAMES keys
    losses: np.ndarray                 # (rows, periods)
    accs: np.ndarray                   # (rows, periods)
    times: np.ndarray                  # (rows, periods) cumulative seconds
    global_batch: np.ndarray           # (rows, periods)
    n_buckets: int = 1                 # compiled programs this run lowered to
    complete: bool = True              # False for streamed partials
    audit: object = None               # AuditReport when run(audit=True)

    @property
    def rows(self) -> int:
        return self.losses.shape[0]

    @property
    def periods(self) -> int:
        return self.losses.shape[1]

    @property
    def final_acc(self) -> np.ndarray:
        return self.accs[:, -1]

    @property
    def final_loss(self) -> np.ndarray:
        return self.losses[:, -1]

    def speed(self, target_acc: float) -> np.ndarray:
        """(rows,) simulated time to reach ``target_acc`` (inf if never)."""
        return time_to_target(self.accs, self.times, target_acc)

    def sel(self, **coords) -> "Results":
        """Filter rows by coordinate value(s): scalars or collections.

        ``res.sel(policy="proposed", seed=(0, 1))``

        A tuple ``want`` against a tuple-valued coordinate (e.g. a swept
        ``seeds`` axis, whose values are seed tuples) matches by
        *equality*, not membership — ``sel(seeds=(0, 1))`` selects the
        rows swept with exactly that seed set; wrap it in a list
        (``sel(seeds=[(0, 1), (2, 3)])``) for membership.

        Fails loudly instead of returning silently-empty selections: an
        unknown coordinate name raises ``KeyError``, and a value that
        matches no row of its own column (out-of-grid — e.g. a radius
        that was never swept, a typo'd policy) raises ``ValueError``.  An
        empty *intersection* of individually-valid values is still a
        legitimate (empty) selection — and so is any no-match selection
        on a streamed *partial* (``complete=False``): a valid value whose
        bucket simply hasn't collected yet must not crash the stream
        consumer, so partials return the empty selection instead of
        raising.
        """
        mask = np.ones(self.rows, bool)
        for name, want in coords.items():
            if name not in self.coords:
                raise KeyError(f"unknown coordinate {name!r}; "
                               f"have {tuple(self.coords)}")
            col = self.coords[name]
            if isinstance(want, tuple) and \
                    any(isinstance(c, tuple) for c in col):
                here = np.array([c == want for c in col], bool)
            elif isinstance(want, (list, tuple, set, frozenset,
                                   np.ndarray)):
                here = np.array([c in want for c in col], bool)
            else:
                here = np.asarray(col == want, bool)
            if not here.any() and self.complete:
                raise ValueError(
                    f"sel({name}={want!r}) matches no row: value not in "
                    f"this Results' {name!r} coordinate "
                    f"(have {tuple(dict.fromkeys(col.tolist()))!r})")
            mask &= here
        return Results(
            coords={k: v[mask] for k, v in self.coords.items()},
            losses=self.losses[mask], accs=self.accs[mask],
            times=self.times[mask], global_batch=self.global_batch[mask],
            n_buckets=self.n_buckets, complete=self.complete,
            audit=self.audit)

    def unique(self, name: str) -> Tuple:
        """Unique values of one coordinate, first-seen (row) order —
        e.g. ``res.unique("num_users")`` walks a swept K axis."""
        if name not in self.coords:
            raise KeyError(f"unknown coordinate {name!r}; "
                           f"have {tuple(self.coords)}")
        out: List[object] = []
        for v in self.coords[name]:
            if v not in out:
                out.append(v)
        return tuple(out)

    def cells(self) -> Iterator[Tuple[Dict[str, object], "Results"]]:
        """Iterate unique (fleet, partition, policy, scheme) cells in row
        order, yielding (labels, seed-rows Results)."""
        seen = []
        keys = list(zip(*(self.coords[n].tolist()
                          for n in COORD_NAMES if n != "seed")))
        for key in keys:
            if key in seen:
                continue
            seen.append(key)
            labels = dict(zip((n for n in COORD_NAMES if n != "seed"), key))
            yield labels, self.sel(**labels)


@dataclass
class ResultsBuilder:
    """Incremental per-bucket :class:`Results` assembly.

    Executors collect buckets one at a time (possibly long after
    dispatch); the builder accumulates each bucket's rows as a chunk —
    no full-experiment block is preallocated — and can produce a
    :meth:`partial` ``Results`` of everything collected so far at any
    point.  ``coords`` holds the full experiment's per-row coordinates
    (cheap host values, known at lowering time); chunk rows address into
    them by output index.
    """
    coords: Mapping[str, np.ndarray]   # full-length (n_rows,) per coord
    n_rows: int
    n_buckets: int
    _chunks: List[tuple] = field(default_factory=list)

    def add_rows(self, indices, losses, accs, times, global_batch) -> None:
        """Add one collected bucket's rows (already fanned out to output
        indices — ``len(indices)`` rows per series)."""
        self._chunks.append((np.asarray(indices, np.int64),
                             np.asarray(losses), np.asarray(accs),
                             np.asarray(times), np.asarray(global_batch)))

    @property
    def collected_rows(self) -> int:
        return sum(len(c[0]) for c in self._chunks)

    def partial(self) -> Results:
        """A ``Results`` of every row collected so far, in output-index
        order (equals the complete result once all buckets are in)."""
        if not self._chunks:
            raise ValueError("no buckets collected yet")
        idx = np.concatenate([c[0] for c in self._chunks])
        order = np.argsort(idx, kind="stable")
        sel = idx[order]
        stack = [np.concatenate([c[j] for c in self._chunks])[order]
                 for j in range(1, 5)]
        return Results(
            coords={k: v[sel] for k, v in self.coords.items()},
            losses=stack[0], accs=stack[1], times=stack[2],
            global_batch=stack[3], n_buckets=self.n_buckets,
            complete=self.collected_rows == self.n_rows)

    def build(self) -> Results:
        """The complete ``Results``; raises if any bucket is missing."""
        if self.collected_rows != self.n_rows:
            raise ValueError(
                f"incomplete collection: {self.collected_rows} of "
                f"{self.n_rows} rows")
        return self.partial()

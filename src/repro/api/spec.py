"""Declarative scenario specification.

A :class:`ScenarioSpec` is everything that defines one experimental cell of
the paper's scenario family — device fleet, wireless cell, data partition,
batchsize policy, Table-II training scheme, compression, learning-rate
base, local-step count, and the seed set — as one frozen, hashable value.
Specs carry no arrays and no rng state: they are *static* configuration,
registered with jax as a static pytree node so they can ride through jit
boundaries untouched.

The bucketing rule (what makes two specs shape-compatible)
----------------------------------------------------------
``Experiment`` lowers each group of shape-compatible specs to ONE compiled
program; :meth:`ScenarioSpec.bucket_key` is that grouping rule.  Two specs
share a bucket iff every quantity that is *structural* for the compiled
trajectory matches:

* scheme family — ``feel``/``gradient_fl`` run the masked-slot FEEL scan;
  ``individual``/``model_fl`` run the per-device-parameter scan (and the
  FedAvg averaging flag is compiled in, so those two never merge);
* slot width (``b_max``, or the dev schemes' fixed epoch batch) — array
  shapes;
* ``local_steps``, ``compress`` and ``compression`` — scan-body structure
  (static python branching / top-k fraction inside the jitted step);
* model architecture (``model_family``, ``hidden``, ``depth``) — the
  per-device train step itself (MLP scan vs big-model transformer/mamba2
  step) and the parameter pytree shapes;
* ``replan`` (FEEL family) — the closed-loop ξ re-plan interval: the
  horizon executes as ``replan``-period chunked scans with estimator
  feedback between chunks, and every row of a bucket must chunk on the
  same boundary.

The fleet is deliberately NOT part of the key: fleet size and composition
are *sweepable* axes, not structural ones.  The lowering pads every
member's user axis to the bucket's max K and threads an ``active_mask``
({0,1} per user row) end to end — through the channel Monte-Carlo draws,
the masked Algorithm-1 rows solver, the schedules and the engine's
reductions — so a K-heterogeneous grid (``grid(base, users=[...])``)
still costs one compiled program, and every padded row stays bit-identical
to its solo unpadded run.  Device *profiles* never reach the device
program at all (they only shape host planning), so profile-heterogeneous
fleets are shape-compatible by construction.

Everything else — partition, policy, cell geometry, base_lr, seeds — only
changes *values* fed to the program (schedules, initial params), so specs
differing in those still share one bucket and one trace.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax

from repro.channels.model import CellConfig
from repro.core.baselines import POLICIES
from repro.core.latency import DeviceProfile
from repro.dynamics import EnergyBudget, Fading, Faults, TauAdapt
from repro.topology import Sampling, Topology

SCHEMES = ("feel", "gradient_fl", "model_fl", "individual")
# Per-device train-step families the FEEL engine can lower.  ``feel_mlp``
# is the paper's MLP scan; ``transformer`` / ``mamba2`` run the big-model
# train step (fed/train_step.py) with the pallas kernels in the hot path.
MODEL_FAMILIES = ("feel_mlp", "transformer", "mamba2")
# The dev-family schemes train full local epochs with a fixed per-device
# batch; PR-1 capped it at 64 — kept as the lowering rule.
DEV_EPOCH_BATCH_CAP = 64


@dataclass(frozen=True)
class ScenarioSpec:
    """One cell of the scenario family (all four Table-II schemes)."""
    fleet: Tuple[DeviceProfile, ...]
    name: str = ""                       # fleet/cell label for Results axes
    scheme: str = "feel"                 # feel|gradient_fl|model_fl|individual
    partition: str = "noniid"            # iid | noniid
    policy: str = "proposed"             # core.baselines key (feel only)
    cell: CellConfig = field(default_factory=CellConfig)
    compress: bool = True
    compression: float = 0.005           # SBC ratio r
    b_max: int = 128
    base_lr: float = 0.05
    local_steps: int = 1
    seeds: Tuple[int, ...] = (0,)
    hidden: int = 256
    depth: int = 3
    replan: Optional[int] = None         # closed-loop ξ re-plan interval
    sampling: Optional[Sampling] = None  # per-round S-of-K participation
    topology: Optional[Topology] = None  # cell→edge→cloud hierarchy
    fading: Optional[Fading] = None      # block-fading Markov channel drift
    faults: Optional[Faults] = None      # straggler slowdowns + dropout
    energy: Optional[EnergyBudget] = None  # per-user per-period energy caps
    adapt_tau: Optional[TauAdapt] = None   # re-planned local-steps knob
    model_family: str = "feel_mlp"       # feel_mlp | transformer | mamba2

    def __post_init__(self):
        object.__setattr__(self, "fleet", tuple(self.fleet))
        object.__setattr__(self, "seeds", tuple(self.seeds))
        if self.scheme not in SCHEMES:
            raise ValueError(f"scheme {self.scheme!r} not in {SCHEMES}")
        if self.partition not in ("iid", "noniid"):
            raise ValueError(f"partition {self.partition!r}")
        if self.policy not in POLICIES:
            raise ValueError(
                f"policy {self.policy!r} not in {tuple(POLICIES)}")
        if not self.seeds:
            raise ValueError("seeds must be non-empty")
        if self.replan is not None:
            if self.is_dev_scheme:
                raise ValueError(
                    "replan= is the FEEL family's closed-loop ξ interval; "
                    f"the {self.scheme!r} scheme has no batchsize policy "
                    "to re-plan")
            if not isinstance(self.replan, int) or \
                    isinstance(self.replan, bool) or self.replan < 1:
                raise ValueError(
                    f"replan must be a positive int (periods per "
                    f"closed-loop chunk), got {self.replan!r}")
        if self.sampling is not None and \
                not isinstance(self.sampling, Sampling):
            raise TypeError(
                f"sampling= expects a repro.topology.Sampling, got "
                f"{type(self.sampling).__name__}")
        if self.topology is not None:
            if not isinstance(self.topology, Topology):
                raise TypeError(
                    f"topology= expects a repro.topology.Topology, got "
                    f"{type(self.topology).__name__}")
            if self.is_dev_scheme:
                raise ValueError(
                    "topology= hierarchizes the server aggregation; the "
                    f"{self.scheme!r} scheme keeps per-device parameters "
                    "and has no aggregation tier to split")
            if self.k < self.topology.cells:
                raise ValueError(
                    f"fleet of {self.k} users cannot populate the "
                    f"topology's {self.topology.cells} cells")
        for fld, typ in (("fading", Fading), ("faults", Faults),
                         ("energy", EnergyBudget), ("adapt_tau", TauAdapt)):
            val = getattr(self, fld)
            if val is not None and not isinstance(val, typ):
                raise TypeError(
                    f"{fld}= expects a repro.dynamics.{typ.__name__}, got "
                    f"{type(val).__name__}")
        if self.has_dynamics:
            if self.is_dev_scheme:
                raise ValueError(
                    "dynamics (fading/faults/energy/adapt_tau) act through "
                    f"the FEEL planner; the {self.scheme!r} scheme has no "
                    "planner to perturb")
            if self.topology is not None:
                raise ValueError(
                    "dynamics are not threaded through the hierarchical "
                    "per-cell solves yet; drop topology= or the dynamics "
                    "fields")
        if self.adapt_tau is not None:
            if self.replan is None:
                raise ValueError(
                    "adapt_tau= re-plans local steps at closed-loop chunk "
                    "boundaries; set replan= on the spec")
            if self.local_steps not in self.adapt_tau.choices:
                raise ValueError(
                    f"local_steps={self.local_steps} is the starting point "
                    "of the adaptive schedule and must appear in adapt_tau "
                    f"choices {self.adapt_tau.choices!r}")
        if self.model_family not in MODEL_FAMILIES:
            raise ValueError(
                f"model_family {self.model_family!r} not in {MODEL_FAMILIES}")
        if self.model_family != "feel_mlp":
            if self.is_dev_scheme:
                raise ValueError(
                    "big-model families run the FEEL train step; the "
                    f"{self.scheme!r} scheme keeps per-device MLPs")
            if self.topology is not None:
                raise ValueError(
                    "the hierarchical scan is feel_mlp-only; drop "
                    "topology= or use model_family='feel_mlp'")
            if self.local_steps != 1 or self.adapt_tau is not None:
                raise ValueError(
                    "big-model families take one aggregated step per "
                    "period (local_steps=1, no adapt_tau); the local-SGD "
                    "delta-upload loop is feel_mlp-only")
            if self.hidden % 4 != 0:
                raise ValueError(
                    f"model_family={self.model_family!r} derives its "
                    f"ArchConfig from hidden={self.hidden}, which must be "
                    "divisible by 4 (attention heads / SSM head grouping)")
        if self.sampling is not None and self.sampling.weighted:
            if self.topology is not None:
                raise ValueError(
                    "weighted (1/p) sampling corrects the flat server "
                    "aggregation; the hierarchical path does not support it")
            if self.energy is not None:
                raise ValueError(
                    "weighted (1/p) sampling needs probabilistic "
                    "inclusion; deterministic energy drops break the "
                    "Horvitz-Thompson correction")

    # ---- derived lowering attributes -------------------------------------
    @property
    def k(self) -> int:
        return len(self.fleet)

    @property
    def is_dev_scheme(self) -> bool:
        """True for the per-device-parameter schemes (no gradient fusion)."""
        return self.scheme in ("individual", "model_fl")

    @property
    def has_dynamics(self) -> bool:
        """True when any time-varying-world process is configured."""
        return (self.fading is not None or self.faults is not None
                or self.energy is not None or self.adapt_tau is not None)

    @property
    def effective_policy(self) -> str:
        """The batchsize policy the lowering actually applies.

        gradient_fl [40] is the full-batch policy on the FEEL engine; the
        per-device-parameter schemes have no batchsize policy at all —
        they report ``"none"`` so ``Results.sel(policy=...)`` never mixes
        them into FEEL-policy selections."""
        if self.is_dev_scheme:
            return "none"
        return "full" if self.scheme == "gradient_fl" else self.policy

    @property
    def dev_epoch_batch(self) -> int:
        return min(self.b_max, DEV_EPOCH_BATCH_CAP)

    @property
    def label(self) -> str:
        base = self.name or f"K{self.k}"
        return f"{base}/{self.partition}/{self.scheme}/{self.effective_policy}"

    def bucket_key(self) -> tuple:
        """Shape-compatibility class (see module docstring).

        The fleet is absent on purpose: K is padded to the bucket max at
        lowering time (active-mask contract), so fleet size/composition
        sweep *within* a bucket.  ``compression`` is structural only while
        ``compress`` is on (it sets the static top-k fraction inside the
        jitted step); with compression off it affects nothing but the
        *planned* payload bits, so compress-off specs merge regardless of
        ratio — a ``grid(base, compression=[...], compress=[True,
        False])`` ablation costs one program for the whole off column.

        ``replan`` is structural for the FEEL family: a closed-loop spec
        executes its horizon as ``replan``-period chunked scans (the chunk
        boundary is where ξ feedback lands), and a bucket's rows must
        chunk together — one device program per chunk covers the whole
        bucket.

        ``topology`` contributes its structural part — ``(cells, edges,
        agg_every)`` shape the hierarchical scan (number of edge replicas,
        cloud cadence), while ``backhaul_bps`` only changes ledger values
        and is absent.  ``sampling`` is deliberately NOT structural: a
        participation mask is per-period *data* through the same active
        machinery as fleet padding, so sampled and unsampled scenarios
        share one program.

        Dynamics (PR 9): ``faults`` and ``energy`` are value-only (they
        arrive as schedule values and masks), as are a ``Fading`` spec's
        gain values — but the fading *state count* and the ``adapt_tau``
        choice set are structural program-family coordinates: the
        auditor certifies per family, and an adaptive bucket compiles
        one scan-body variant per realized τ, so only rows agreeing on
        the candidate set may chunk together.

        ``model_family`` is structural: the scan body is a different
        program per family (MLP scan vs the big-model train step on the
        pallas kernels), so a ``grid(base, model_family=[...])`` sweep
        lowers to exactly one program per family-bucket."""
        if self.is_dev_scheme:
            return ("dev", self.scheme, self.dev_epoch_batch,
                    self.hidden, self.depth)
        topo = (None if self.topology is None
                else self.topology.structural_key())
        return ("feel", self.b_max, self.local_steps,
                self.compress, self.compression if self.compress else None,
                self.hidden, self.depth, self.replan, topo,
                None if self.fading is None else self.fading.states,
                None if self.adapt_tau is None else self.adapt_tau.choices,
                self.model_family)


jax.tree_util.register_static(ScenarioSpec)

"""Study grids: product-expansion sweeps over any ``ScenarioSpec`` field.

``grid(base, **axes)`` expands a base spec along named axes into a
:class:`Study` — a deduplicated, ordered sequence of ``ScenarioSpec``
values that an ``Experiment`` accepts directly, plus the per-spec axis
coordinates that :class:`repro.api.results.Results` carries so swept
values are selectable without string parsing:

    study = grid(base,
                 policy=["proposed", "full"],
                 **{"cell.radius_m": [100.0, 200.0, 400.0]})
    res = Experiment(data, test, study).run(periods=100)
    res.sel(cell_radius_m=200.0, policy="proposed").speed(0.6)

Axis kinds
----------
* **field axis** — the name is a ``ScenarioSpec`` field
  (``policy=[...]``, ``b_max=[...]``, ``seeds=[(0, 1), (2, 3)]``);
* **dotted axis** — the name paths into a nested frozen-dataclass field,
  e.g. ``cell.radius_m`` / ``cell.bandwidth_hz`` / ``cell.tx_power_dbm``
  sweep the wireless :class:`~repro.channels.model.CellConfig` geometry
  (pass via ``**{"cell.radius_m": [...]}``).  The Results coordinate name
  is the dotted path with ``.`` → ``_``;
* **labeled axis** — the value is a mapping ``{label: {field: value,
  ...}}`` bundling several (possibly dotted) field updates under one
  coordinate label, for paired knobs that are one conceptual axis:
  ``model={"resnet_stand_in": dict(hidden=256, depth=3), ...}``;
* **users axis** — fleet size/composition as a first-class sweep (the
  paper's "impact of number of users" knob).  ``users=[4, 8, 16]``
  resizes the base fleet to each K — truncating, or extending by cycling
  the base profiles round-robin — while ``users={label: fleet}`` sweeps
  explicit (heterogeneous) fleets.  The Results coordinate is
  ``num_users`` (the swept K, or the label for explicit fleets):
  ``res.sel(num_users=8)``.  Fleet size is *not* structural
  (``spec.bucket_key``): the whole K-sweep lowers into the same padded
  bucket as the base spec, one compiled program.

Expansion is the full cartesian product in axis-declaration order.
Expanded specs get auto-derived labels: ``name`` gains a ``key=value``
suffix per axis that the row label does not already carry (partition /
scheme / policy are label fields already).  Specs that expand identical
(duplicate axis values) are deduplicated, first combination wins —
``Experiment`` additionally dedupes identical (spec, seed) rows at
``lower()`` time, so a Study never pays twice for one trajectory.
"""
from __future__ import annotations

from dataclasses import fields, is_dataclass, replace
from typing import Dict, List, Mapping, Sequence, Set, Tuple

from itertools import combinations, product

from repro.api.results import COORD_NAMES
from repro.api.spec import ScenarioSpec

# axis names whose values the row label already shows (spec.label builds
# from name/partition/scheme/effective_policy) — no name suffix for these
_LABEL_FIELDS = ("name", "partition", "scheme", "policy")
# the only COORD_NAMES collisions allowed: plain field axes whose built-in
# Results coordinate carries the swept value verbatim (checked per
# expanded spec below — "policy" surfaces as effective_policy, which drops
# the swept value on dev/gradient_fl schemes).  Anything else (labeled
# axes named "fleet"/"policy"/…, a plain "fleet" sweep whose built-in
# coordinate holds the spec *name*) would silently never match a sel() on
# the declared axis — rejected at grid() time instead.
_PASSTHROUGH_COORDS = {
    "partition": lambda s: s.partition,
    "scheme": lambda s: s.scheme,
    "policy": lambda s: s.effective_policy,
}
# axes whose Results coordinate carries a different name than the axis
# (the ``users`` axis writes the ``fleet`` field; its swept value — K or
# an explicit-fleet label — surfaces as ``num_users``)
_COORD_RENAMES = {"users": "num_users"}


def _coord_name(axis: str) -> str:
    return _COORD_RENAMES.get(axis, axis.replace(".", "_"))


def _resize_fleet(fleet: Tuple, k: int) -> Tuple:
    """The ``users=[K, ...]`` resize rule: truncate to the first K
    profiles, or extend by cycling the base profiles round-robin."""
    if not isinstance(k, int) or isinstance(k, bool) or k < 1:
        raise ValueError(
            f"users axis: fleet size must be a positive int, got {k!r} "
            f"(use users={{label: fleet}} for explicit fleets)")
    return tuple(fleet[i % len(fleet)] for i in range(k))


def _users_choices(base: ScenarioSpec, values):
    """Normalize a ``users`` axis into (coord, {'fleet': fleet}) choices."""
    if isinstance(values, Mapping):
        choices = []
        for label, fl in values.items():
            fl = tuple(fl)
            if not fl:
                raise ValueError(
                    f"users axis: fleet for {label!r} is empty")
            choices.append((label, {"fleet": fl}))
        return choices
    return [(k, {"fleet": _resize_fleet(base.fleet, k)}) for k in values]


def _field_names(obj) -> Tuple[str, ...]:
    return tuple(f.name for f in fields(obj))


def _check_path(base: ScenarioSpec, path: str) -> None:
    """Validate a (possibly dotted) field path against the spec layout."""
    obj = base
    parts = path.split(".")
    for i, part in enumerate(parts):
        names = _field_names(obj)
        if part not in names:
            raise ValueError(
                f"axis {path!r}: {type(obj).__name__} has no field "
                f"{part!r}; valid fields: {names}")
        if i < len(parts) - 1:
            obj = getattr(obj, part)
            if not is_dataclass(obj):
                raise ValueError(
                    f"axis {path!r}: field {part!r} is not a nested "
                    f"dataclass, cannot path into it")


def _apply_updates(base: ScenarioSpec,
                   updates: Mapping[str, object]) -> ScenarioSpec:
    """``dataclasses.replace`` through dotted paths (one nesting level —
    the spec layout is flat apart from ``cell``)."""
    top: Dict[str, object] = {}
    nested: Dict[str, Dict[str, object]] = {}
    for path, value in updates.items():
        if "." in path:
            head, leaf = path.split(".", 1)
            nested.setdefault(head, {})[leaf] = value
        else:
            top[path] = value
    for head, sub in nested.items():
        top[head] = replace(getattr(base, head), **sub)
    return replace(base, **top)


def _fmt(value) -> str:
    if isinstance(value, float):
        return f"{value:g}"
    return str(value)


class Study(Sequence):
    """An expanded grid: ordered deduplicated specs + axis coordinates.

    Behaves as a ``Sequence[ScenarioSpec]`` (so ``Experiment(data, test,
    study)`` just works); additionally exposes the swept axes so the
    experiment can attach them to ``Results`` as named coordinates.
    """

    def __init__(self, base: ScenarioSpec,
                 axes: Mapping[str, Tuple[object, ...]],
                 specs: Sequence[ScenarioSpec],
                 coords: Mapping[ScenarioSpec, Mapping[str, object]]):
        self.base = base
        self.axes = dict(axes)             # axis name -> swept values/labels
        self._specs = tuple(specs)
        self._coords = dict(coords)

    # ---- Sequence protocol -------------------------------------------------
    def __len__(self) -> int:
        return len(self._specs)

    def __getitem__(self, i):
        return self._specs[i]

    def __iter__(self):
        return iter(self._specs)

    def __repr__(self) -> str:
        ax = ", ".join(f"{k}×{len(v)}" for k, v in self.axes.items())
        return f"Study({len(self._specs)} specs; axes: {ax or 'none'})"

    # ---- coordinate surface (consumed by Experiment) -----------------------
    @property
    def specs(self) -> Tuple[ScenarioSpec, ...]:
        return self._specs

    @property
    def coord_names(self) -> Tuple[str, ...]:
        """Sanitized Results coordinate names, axis-declaration order."""
        return tuple(_coord_name(name) for name in self.axes)

    def axis_coords(self, spec: ScenarioSpec) -> Mapping[str, object]:
        """The swept-axis values that produced ``spec`` (sanitized keys)."""
        return self._coords.get(spec, {})


def grid(base: ScenarioSpec, **axes) -> Study:
    """Expand ``base`` along ``axes`` into a deduplicated :class:`Study`.

    See the module docstring for axis kinds; dotted geometry axes are
    passed via ``**{"cell.radius_m": [...]}``.
    """
    # normalize: axis name -> list of (coord_value, {path: value}) choices
    normalized: Dict[str, List[Tuple[object, Dict[str, object]]]] = {}
    touched: Dict[str, Set[str]] = {}    # axis -> field paths it writes
    for name, values in axes.items():
        coord = _coord_name(name)
        if coord in COORD_NAMES and not (
                coord == name and name in _PASSTHROUGH_COORDS
                and not isinstance(values, Mapping)):
            raise ValueError(
                f"axis {name!r}: Results has a built-in {coord!r} "
                f"coordinate that would not carry the swept values — "
                f"rename the axis (e.g. a labeled axis "
                f"'{name}s={{label: {{field: value}}}}')")
        if name == "users":
            choices = _users_choices(base, values)
            touched[name] = {"fleet"}
        elif isinstance(values, Mapping):
            for label, updates in values.items():
                if not isinstance(updates, Mapping):
                    raise ValueError(
                        f"labeled axis {name!r}: value for {label!r} must "
                        f"be a mapping of field updates")
                for path in updates:
                    _check_path(base, path)
            choices = [(label, dict(updates))
                       for label, updates in values.items()]
            touched[name] = {p for upd in values.values() for p in upd}
        else:
            _check_path(base, name)
            choices = [(v, {name: v}) for v in values]
            touched[name] = {name}
        if not choices:
            raise ValueError(f"axis {name!r} has no values")
        normalized[name] = choices
    for (a, pa), (b, pb) in combinations(touched.items(), 2):
        clash = [(p, q) for p in pa for q in pb
                 if p == q or p.startswith(q + ".")
                 or q.startswith(p + ".")]
        if clash:
            raise ValueError(
                f"axes {a!r} and {b!r} both write field "
                f"{clash[0][0]!r}/{clash[0][1]!r}: overlapping axes would "
                f"silently override each other — make the axes disjoint")

    specs: List[ScenarioSpec] = []
    coords: Dict[ScenarioSpec, Dict[str, object]] = {}
    for combo in product(*normalized.values()):
        updates: Dict[str, object] = {}
        for _, upd in combo:
            updates.update(upd)
        spec = _apply_updates(base, updates)
        for name, (coord, _) in zip(normalized, combo):
            getter = _PASSTHROUGH_COORDS.get(name)
            if getter is not None and getter(spec) != coord:
                raise ValueError(
                    f"axis {name!r}: value {coord!r} does not survive to "
                    f"the Results {name!r} coordinate (scheme "
                    f"{spec.scheme!r} reports {getter(spec)!r}) — the "
                    f"swept rows would be unselectable; restrict the "
                    f"{name!r} axis to specs that honour it")
        suffix = [f"{name.split('.')[-1]}={_fmt(coord)}"
                  for name, (coord, _) in zip(normalized, combo)
                  if name not in _LABEL_FIELDS]
        if suffix:
            stem = spec.name or f"K{spec.k}"
            spec = replace(spec, name="/".join([stem] + suffix))
        if spec in coords:
            continue                       # duplicate combination: keep first
        specs.append(spec)
        coords[spec] = {_coord_name(name): coord
                        for name, (coord, _) in zip(normalized, combo)}
    return Study(base=base, axes={n: tuple(c for c, _ in ch)
                                  for n, ch in normalized.items()},
                 specs=specs, coords=coords)

"""Wireless channel substrate (paper §II-C, §VI-A).

Single-cell network, radius 200 m, BS at the center; path loss
``PL[dB] = 128.1 + 37.6 log10(d[km])`` with Rayleigh small-scale fading;
uplink/downlink Tx power 28 dBm, bandwidth 10 MHz, noise −174 dBm/Hz.
Average rates follow eqs. (5)-(6): R = W·E_h[log2(1 + P|h|²/N0)], estimated
by Monte-Carlo over the fading distribution (the paper's expectation).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CellConfig:
    radius_m: float = 200.0
    bandwidth_hz: float = 10e6         # W
    tx_power_dbm: float = 28.0         # uplink and downlink (paper §VI-A)
    noise_dbm_per_hz: float = -174.0   # N0 density
    frame_up_s: float = 0.010          # T_f^U (LTE)
    frame_down_s: float = 0.010        # T_f^D
    fading_samples: int = 2048


def path_loss_db(dist_km: np.ndarray) -> np.ndarray:
    return 128.1 + 37.6 * np.log10(np.maximum(dist_km, 1e-4))


def wired_latency(bits: float, rate_bps: float) -> float:
    """Deterministic wired-link transfer time (the cell→edge metro hop is
    folded into the radio frame; this models the edge→cloud backhaul of a
    :class:`~repro.topology.Topology`, which has no fading and hence no
    Monte-Carlo stream)."""
    if rate_bps <= 0:
        raise ValueError(f"wired rate must be positive, got {rate_bps!r}")
    return float(bits) / float(rate_bps)


@dataclass
class Cell:
    cfg: CellConfig
    rng: np.random.Generator

    @classmethod
    def make(cls, seed: int = 0, cfg: CellConfig = CellConfig()):
        return cls(cfg=cfg, rng=np.random.default_rng(seed))

    def drop_users(self, k: int) -> np.ndarray:
        """Uniform positions in the disc; returns distances (km)."""
        r = self.cfg.radius_m * np.sqrt(self.rng.uniform(size=k))
        return np.maximum(r, 1.0) / 1000.0

    def avg_rate(self, dist_km: np.ndarray) -> np.ndarray:
        """eqs. (5)/(6) via Monte-Carlo over Rayleigh fading."""
        c = self.cfg
        pl = path_loss_db(dist_km)                          # (K,)
        p_rx_dbm = c.tx_power_dbm - pl                      # mean rx power
        noise_dbm = c.noise_dbm_per_hz + 10 * np.log10(c.bandwidth_hz)
        snr_lin = 10 ** ((p_rx_dbm - noise_dbm) / 10)       # (K,)
        h2 = self.rng.exponential(size=(c.fading_samples, len(dist_km)))
        rate = c.bandwidth_hz * np.mean(np.log2(1 + snr_lin[None, :] * h2),
                                        axis=0)
        return rate                                          # bits/s

    def avg_rate_updown_rows(self, dist_km: np.ndarray, periods: int,
                             pad_to: int | None = None):
        """``periods`` consecutive (uplink, downlink) rate draws in ONE rng
        consumption.

        Bit-identical to the per-period loop ``for p: up = avg_rate(d);
        down = avg_rate(d)`` because ``Generator`` fills arrays variate by
        variate in C order, so one ``(P, 2, S, K)`` draw consumes the stream
        exactly like 2·P sequential ``(S, K)`` draws (test-covered).

        ``pad_to`` appends padded-user columns for the ragged-fleet
        lowering: the K *active* users draw exactly as above (the rng
        stream is untouched by padding — that is what keeps padded rows
        bit-identical to solo runs), while each padded column carries the
        deterministic unit-SNR rate W (finite and positive so masked
        intermediate math stays well-behaved; the solver's active mask
        zeroes its batchsize and bandwidth share, so the value never
        reaches a result).  Returns (rates_up (P, K'), rates_down (P, K'))
        with K' = ``pad_to`` or K."""
        c = self.cfg
        pl = path_loss_db(dist_km)
        p_rx_dbm = c.tx_power_dbm - pl
        noise_dbm = c.noise_dbm_per_hz + 10 * np.log10(c.bandwidth_hz)
        snr_lin = 10 ** ((p_rx_dbm - noise_dbm) / 10)        # (K,)
        h2 = self.rng.exponential(
            size=(periods, 2, c.fading_samples, len(dist_km)))
        rate = c.bandwidth_hz * np.mean(
            np.log2(1 + snr_lin[None, None, None, :] * h2), axis=2)
        up, down = rate[:, 0], rate[:, 1]                    # bits/s
        if pad_to is not None and pad_to > len(dist_km):
            fill = np.full((periods, pad_to - len(dist_km)), c.bandwidth_hz)
            up = np.concatenate([up, fill], axis=1)
            down = np.concatenate([down, fill], axis=1)
        return up, down

    def sample_rates(self, k: int):
        """Drop K users, return (dist_km, uplink rates, downlink rates)."""
        d = self.drop_users(k)
        up = self.avg_rate(d)
        down = self.avg_rate(d)
        return d, up, down

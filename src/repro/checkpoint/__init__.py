from repro.checkpoint.ckpt import save, restore, save_state, restore_state

__all__ = ["save", "restore", "save_state", "restore_state"]

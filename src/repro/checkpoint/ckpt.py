"""msgpack-based checkpointing for arbitrary jax pytrees (no orbax offline).

Arrays are serialized as {shape, dtype, raw bytes}; the tree structure is
preserved via jax.tree_util flatten-with-paths.  Atomic write (tmp+rename);
``save_state``/``restore_state`` add a step counter + metadata envelope.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    a = np.asarray(x)
    # extended dtypes (bfloat16, float8) are stored by name; numpy's .str
    # for them is an opaque void type
    return {b"shape": list(a.shape), b"dtype": a.dtype.name,
            b"data": a.tobytes()}


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _unpack_leaf(d):
    dt = _np_dtype(d[b"dtype"].decode() if isinstance(d[b"dtype"], bytes)
                   else d[b"dtype"])
    a = np.frombuffer(d[b"data"], dtype=dt)
    return jnp.asarray(a.reshape(d[b"shape"]))


def _key_str(path) -> str:
    return jax.tree_util.keystr(path)


def save(path: str, tree: Any) -> None:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    payload = {_key_str(p).encode(): _pack_leaf(v) for p, v in leaves}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves = jax.tree_util.tree_leaves_with_path(like)
    vals = []
    for p, ref in leaves:
        key = _key_str(p).encode()
        if key not in payload:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        v = _unpack_leaf(payload[key])
        if tuple(v.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch at {key!r}: "
                             f"{v.shape} vs {np.shape(ref)}")
        vals.append(v)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, vals)


def save_state(path: str, step: int, params: Any, opt_state: Any,
               extra: Any = ()) -> None:
    save(path, {"step": jnp.asarray(step), "params": params,
                "opt": opt_state, "extra": extra})


def restore_state(path: str, params_like: Any, opt_like: Any,
                  extra_like: Any = ()):
    out = restore(path, {"step": jnp.asarray(0), "params": params_like,
                         "opt": opt_like, "extra": extra_like})
    return int(out["step"]), out["params"], out["opt"], out["extra"]

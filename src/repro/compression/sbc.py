"""Sparse Binary Compression (Sattler et al. [24]) — the paper's gradient
compression substrate (r = 0.005, §VI-A).

Per tensor: (1) magnitude top-k sparsification at rate ``ratio``;
(2) among survivors, keep only the sign group (positive or negative) with
the larger magnitude sum; (3) binarize survivors to that group's mean
magnitude.  With error feedback (residual accumulation) this preserves
convergence.  ``compressed_bits`` reproduces the paper's payload model
s = r·d·p.

``compress_dense`` returns the *dense decompressed* gradient — the form the
in-graph federated all-reduce consumes (DESIGN.md §3: uplink compression
becomes a transform around the data-parallel mean).  The Pallas kernel
(kernels/sbc_topk) computes the per-block magnitude threshold + binarize
step on TPU; this module is its jnp oracle.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sbc_tensor(g: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Dense SBC approximation of one tensor (jnp oracle)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(round(n * ratio)))
    mag = jnp.abs(flat)
    # threshold = k-th largest magnitude
    thr = jax.lax.top_k(mag, k)[0][-1]
    keep = mag >= thr
    pos = keep & (flat > 0)
    neg = keep & (flat < 0)
    pos_sum = jnp.sum(jnp.where(pos, mag, 0.0))
    neg_sum = jnp.sum(jnp.where(neg, mag, 0.0))
    use_pos = pos_sum >= neg_sum
    grp = jnp.where(use_pos, pos, neg)
    grp_sum = jnp.where(use_pos, pos_sum, neg_sum)
    cnt = jnp.maximum(jnp.sum(grp), 1)
    mean_mag = grp_sum / cnt
    val = jnp.where(use_pos, mean_mag, -mean_mag)
    out = jnp.where(grp, val, 0.0)
    return out.reshape(g.shape).astype(g.dtype)


def compress_dense(grads, ratio: float = 0.005, residual=None):
    """Apply SBC to every leaf; with error-feedback residuals when given.

    Returns (approx_grads, new_residual).
    """
    if residual is None:
        residual = jax.tree_util.tree_map(jnp.zeros_like, grads)
    acc = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)
    approx = jax.tree_util.tree_map(lambda t: sbc_tensor(t, ratio), acc)
    new_res = jax.tree_util.tree_map(lambda a, ap: a - ap, acc, approx)
    return approx, new_res


def compressed_bits(n_params: int, ratio: float = 0.005,
                    bits_per_term: int = 64) -> float:
    """Paper's payload model: s = r·d·p."""
    return ratio * bits_per_term * n_params

"""Sparse Binary Compression (Sattler et al. [24]) — the paper's gradient
compression substrate (r = 0.005, §VI-A).

Per tensor: (1) magnitude top-k sparsification at rate ``ratio``;
(2) among survivors, keep only the sign group (positive or negative) with
the larger magnitude sum; (3) binarize survivors to that group's mean
magnitude.  With error feedback (residual accumulation) this preserves
convergence.  ``compressed_bits`` reproduces the paper's payload model
s = r·d·p.

``compress_dense`` returns the *dense decompressed* gradient — the form the
in-graph federated all-reduce consumes (DESIGN.md §3: uplink compression
becomes a transform around the data-parallel mean).  The Pallas kernels
(kernels/sbc.py, dispatched through ``kernels.ops.sbc_compress``) compute
the per-block magnitude stats + binarize step on TPU; this module is their
jnp oracle.  ``sbc_uplink`` is the backend-dispatching entry point: the
kernel path on accelerators, bitwise ``compress_dense`` on CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_threshold(mag: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exact k-th largest magnitude (XLA top_k — O(n·k) on CPU)."""
    return jax.lax.top_k(mag, k)[0][-1]


def topk_threshold_bisect(mag: jnp.ndarray, k: int,
                          iters: int = 20) -> jnp.ndarray:
    """~k-th largest magnitude by value-domain bisection: ``iters`` O(n)
    count passes instead of a sort/top_k, which is what makes in-graph SBC
    affordable inside the scanned training loop.  Returns the largest
    threshold t with ``|{mag >= t}| >= k`` up to ``max(mag)/2^iters``
    resolution (survivor count can exceed k only by boundary ties)."""
    lo = jnp.zeros((), jnp.float32)
    hi = jnp.max(mag) * (1.0 + 1e-6) + 1e-30

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        geq = jnp.sum(mag >= mid) >= k
        return jnp.where(geq, mid, lo), jnp.where(geq, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def sbc_tensor(g: jnp.ndarray, ratio: float,
               exact: bool = True) -> jnp.ndarray:
    """Dense SBC approximation of one tensor (jnp oracle).

    ``exact=True`` uses the literal top-k threshold (the Pallas kernels'
    oracle contract); ``exact=False`` uses the bisection threshold — the
    training hot path's choice.
    """
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(round(n * ratio)))
    mag = jnp.abs(flat)
    # threshold = k-th largest magnitude
    thr = topk_threshold(mag, k) if exact else topk_threshold_bisect(mag, k)
    keep = mag >= thr
    pos = keep & (flat > 0)
    neg = keep & (flat < 0)
    pos_sum = jnp.sum(jnp.where(pos, mag, 0.0))
    neg_sum = jnp.sum(jnp.where(neg, mag, 0.0))
    use_pos = pos_sum >= neg_sum
    grp = jnp.where(use_pos, pos, neg)
    grp_sum = jnp.where(use_pos, pos_sum, neg_sum)
    cnt = jnp.maximum(jnp.sum(grp), 1)
    mean_mag = grp_sum / cnt
    val = jnp.where(use_pos, mean_mag, -mean_mag)
    out = jnp.where(grp, val, 0.0)
    return out.reshape(g.shape).astype(g.dtype)


def compress_dense(grads, ratio: float = 0.005, residual=None,
                   exact: bool = False):
    """Apply SBC to every leaf; with error-feedback residuals when given.

    Defaults to the bisection threshold (``exact=False``): error feedback
    absorbs its boundary-tie slack, and it is orders of magnitude cheaper
    than top_k/sort on every backend, which matters because this runs once
    per period inside the compiled training scan.

    Returns (approx_grads, new_residual).
    """
    if residual is None:
        residual = jax.tree_util.tree_map(jnp.zeros_like, grads)
    acc = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)
    approx = jax.tree_util.tree_map(
        lambda t: sbc_tensor(t, ratio, exact=exact), acc)
    new_res = jax.tree_util.tree_map(lambda a, ap: a - ap, acc, approx)
    return approx, new_res


def sbc_uplink(grads, ratio: float = 0.005, residual=None):
    """Error-feedback SBC routed through the accelerator kernel path.

    On TPU each leaf goes through the two-kernel composition in
    ``kernels/sbc.py`` (``sbc_stats`` + ``sbc_apply`` via
    ``kernels.ops.sbc_compress``); on CPU this *is* ``compress_dense`` —
    bitwise, not merely allclose — so the engine path and the oracle are
    interchangeable in CPU CI.  Returns ``(approx_grads, new_residual)``
    with the same error-feedback contract as ``compress_dense``.
    """
    from repro.kernels import ops as kops  # lazy: kernels.ref imports us

    if not kops._on_tpu():
        return compress_dense(grads, ratio, residual)
    if residual is None:
        residual = jax.tree_util.tree_map(jnp.zeros_like, grads)
    acc = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)
    approx = jax.tree_util.tree_map(
        lambda t: kops.sbc_compress(t, ratio), acc)
    new_res = jax.tree_util.tree_map(lambda a, ap: a - ap, acc, approx)
    return approx, new_res


def compressed_bits(n_params: int, ratio: float = 0.005,
                    bits_per_term: int = 64) -> float:
    """Paper's payload model: s = r·d·p."""
    return ratio * bits_per_term * n_params

"""Registry: ``--arch <id>`` -> ArchConfig."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, get_shape

from repro.configs import (
    granite_34b,
    deepseek_v2_lite_16b,
    mistral_nemo_12b,
    musicgen_large,
    zamba2_7b,
    mamba2_2p7b,
    arctic_480b,
    qwen1p5_4b,
    llava_next_mistral_7b,
    minicpm3_4b,
    feel_mlp,
)

_MODULES = [
    granite_34b, deepseek_v2_lite_16b, mistral_nemo_12b, musicgen_large,
    zamba2_7b, mamba2_2p7b, arctic_480b, qwen1p5_4b,
    llava_next_mistral_7b, minicpm3_4b, feel_mlp,
]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The 10 assigned architectures (feel-mlp is the paper's own extra).
ASSIGNED = [
    "granite-34b", "deepseek-v2-lite-16b", "mistral-nemo-12b",
    "musicgen-large", "zamba2-7b", "mamba2-2.7b", "arctic-480b",
    "qwen1.5-4b", "llava-next-mistral-7b", "minicpm3-4b",
]


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "ASSIGNED",
    "get_arch", "get_shape",
]

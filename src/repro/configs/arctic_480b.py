"""arctic-480b — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56 Q heads (GQA kv=8), 128 routed experts top-2
(expert d_ff 4864) with a dense residual FFN in parallel. 56 heads are
unevenly sharded over the 16-way model axis via GSPMD padding.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                     # dense residual FFN width
    vocab=32000,
    head_dim=128,
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,
    ),
    source="hf:Snowflake/snowflake-arctic-base",
)

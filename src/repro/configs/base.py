"""Architecture / input-shape config system.

Every assigned architecture is a frozen ``ArchConfig`` living in its own
module under ``repro.configs``; the registry maps ``--arch <id>`` to it.
``ArchConfig.reduced()`` returns the CPU-smoke variant (2 layers,
d_model<=512, <=4 experts) of the *same family*, used by tests and examples.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek style
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    first_dense_layers: int = 0   # DeepSeek: layer 0 is a dense FFN
    router_noise: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""
    kv_lora_rank: int
    q_lora_rank: Optional[int]
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block."""
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # query heads; 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    qkv_bias: bool = False
    ffn_kind: str = "swiglu"      # swiglu | mlp (2-matrix GELU)
    rope_theta: float = 10_000.0
    attn_kind: str = "gqa"        # gqa | mla | none
    attn_window: Optional[int] = None   # sliding-window attention (tokens)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_every: int = 0         # zamba2: shared attn block every N ssm layers
    n_codebooks: int = 1          # musicgen: EnCodec codebooks
    vlm_prefix: int = 0           # llava: max patch-embedding prefix length
    norm_eps: float = 1e-5
    source: str = ""

    # ---- derived ----------------------------------------------------------
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def is_subquadratic(self) -> bool:
        """Can run long_500k without unbounded full-attention KV cache."""
        return (
            self.family in ("ssm", "hybrid")
            or self.attn_kind == "mla"
            or self.attn_window is not None
        )

    def param_count(self) -> int:
        """Exact parameter count of the model we instantiate (true vocab)."""
        from repro.models.model import param_spec
        import jax
        spec = param_spec(self)
        return sum(
            int(x.size) for x in jax.tree_util.tree_leaves(spec)
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k only + shared)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        m = self.moe
        moe_layers = self.n_layers - m.first_dense_layers
        per_expert = 3 * self.d_model * m.d_ff_expert
        inactive = moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive

    def reduced(self) -> "ArchConfig":
        """CPU smoke variant: same family/wiring, tiny dims."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=256,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=512,
            vocab=min(self.vocab, 512),
            head_dim=64 if self.n_heads else 0,
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            hybrid_every=1 if self.hybrid_every else 0,
            vlm_prefix=16 if self.vlm_prefix else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=128,
                n_shared=min(self.moe.n_shared, 1),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                kv_lora_rank=64,
                q_lora_rank=64 if self.mla.q_lora_rank else None,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=32)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]

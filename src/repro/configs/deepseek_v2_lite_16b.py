"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L, d_model 2048, 16 heads, MLA kv_lora=512 (no q_lora in Lite),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff 1408; layer 0 dense
(d_ff 10944).  The assignment line's "160 routed" aside describes full
V2-236B; we take the bracket numbers (64e top-6) literally — DESIGN.md §5.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                    # dense layer-0 FFN width
    vocab=102400,
    attn_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=None,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_ff_expert=1408,
        n_shared=2,
        first_dense_layers=1,
    ),
    source="arXiv:2405.04434",
)

"""feel-mlp — the paper's own experiment-scale model class.

The paper trains DenseNet121/ResNet18/MobileNetV2 on CIFAR-10; offline we
use a compact MLP classifier over 3072-dim (32x32x3) synthetic inputs with
10 classes, which exercises the identical FEEL scheduling problem
(batchsize selection + TDMA allocation) at laptop scale.  This config is
consumed by the federated trainer directly (not the transformer stack).
"""
from repro.configs.base import ArchConfig

# family "mlp" is handled by repro.fed.feel_model, not models.model.
CONFIG = ArchConfig(
    name="feel-mlp",
    family="mlp",
    n_layers=3,
    d_model=256,        # hidden width
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=10,           # classes
    attn_kind="none",
    source="paper §VI (CIFAR-10 class task, synthetic stand-in)",
)

INPUT_DIM = 3072

"""granite-34b — dense llama-arch code model [arXiv:2405.04324].

88L, d_model 6144, 48 Q heads, GQA kv=1 (MQA), d_ff 24576, vocab 49152.
long_500k runs with the sliding-window variant (window 8192) — see DESIGN.md §5.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    ffn_kind="mlp",                # GPT-BigCode 2-matrix MLP => ~34B params
    source="arXiv:2405.04324",
)

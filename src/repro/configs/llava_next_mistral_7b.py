"""llava-next-mistral-7b — VLM, Mistral-7B backbone + anyres vision prefix
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 32000.
The vision tower (CLIP-ViT) + projector are STUBBED per the brief:
input_specs() supplies pre-projected patch embeddings (anyres grid of up
to 2880 tokens = 5 tiles x 24x24) prepended to the text tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    vlm_prefix=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

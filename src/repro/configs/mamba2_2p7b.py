"""mamba2-2.7b — attention-free SSD state-space model [arXiv:2405.21060].

64L, d_model 2560, d_state 128, expand 2 (d_inner 5120, 80 SSD heads of
head_dim 64), vocab 50280.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    attn_kind="none",
    ssm=SSMConfig(d_state=128, head_dim=64),
    source="arXiv:2405.21060",
)

"""minicpm3-4b — dense with MLA [hf:openbmb/MiniCPM3-4B].

62L, d_model 2560, 40 heads, MLA (kv_lora 256, q_lora 768, qk_nope 64,
qk_rope 32, v_head 64), d_ff 6400, vocab 73448 (padded to 73456 for the
16-way model axis; padded logits masked).
"""
from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attn_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    source="hf:openbmb/MiniCPM3-4B",
)

"""mistral-nemo-12b — dense, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L, d_model 5120, 32 Q heads (head_dim 128), GQA kv=8, d_ff 14336,
vocab 131072 (Tekken tokenizer).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)

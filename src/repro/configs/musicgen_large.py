"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L, d_model 2048, 32 heads (MHA), d_ff 8192, vocab 2048 per codebook,
4 EnCodec codebooks (delay interleaving handled by the data pipeline stub).
The EnCodec frontend is STUBBED per the brief: input_specs() supplies codec
token ids; the model embeds each codebook and sums (MusicGen §3.1).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    n_codebooks=4,
    source="arXiv:2306.05284",
)

"""zamba2-7b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

81 Mamba2 layers (d_model 3584, ssm_state 64) with ONE shared
attention+MLP block (32H MHA, d_ff 14336) re-applied every 9 layers with
the same weights (81 = 9 segments x 9 layers) — DESIGN.md §5.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm=SSMConfig(d_state=64, head_dim=64),
    hybrid_every=9,
    source="arXiv:2411.15242",
)

"""The paper's primary contribution: learning-efficiency-optimal joint
batchsize selection + communication resource allocation (Theorems 1/2,
Algorithm 1) and the FEEL period scheduler that applies it at runtime."""
from repro.core.latency import (DeviceProfile, gradient_bits, period_latency,
                                uplink_latency, downlink_latency)
from repro.core.efficiency import (loss_decay, learning_efficiency, lr_scale,
                                   XiEstimator)
from repro.core.solver import (solve_uplink, solve_downlink, solve_period,
                               batch_closed_form, tau_closed_form,
                               e_up_bounds, mu_bounds, fixed_slot_rows,
                               FleetRows, UplinkSolution, DownlinkSolution,
                               PeriodSolution)
from repro.core.baselines import POLICIES, PolicyResult
from repro.core.scheduler import (DevHorizon, DevScheduler, FeelScheduler,
                                  PeriodPlan, PlanHorizon,
                                  plan_horizons_batch)

__all__ = [
    "DeviceProfile", "gradient_bits", "period_latency", "uplink_latency",
    "downlink_latency", "loss_decay", "learning_efficiency", "lr_scale",
    "XiEstimator", "solve_uplink", "solve_downlink", "solve_period",
    "batch_closed_form", "tau_closed_form", "e_up_bounds", "mu_bounds",
    "fixed_slot_rows", "FleetRows", "UplinkSolution", "DownlinkSolution",
    "PeriodSolution", "POLICIES", "PolicyResult", "DevHorizon",
    "DevScheduler", "FeelScheduler", "PeriodPlan", "PlanHorizon",
    "plan_horizons_batch",
]

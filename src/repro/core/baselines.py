"""Benchmark schemes from the paper (§VI-C batchsize/allocation policies and
§VI-B training schemes).

Allocation policies (GPU-scenario comparison, Figs. 4-5):
  * online   — B_k = 1
  * full     — B_k = B^max
  * random   — B_k ~ U{1..B^max} each period
  * proposed — Theorem 1/2 solution (core.solver)
All non-proposed policies use equal TDMA slots (τ_k = T_f/K), which is what
an allocation-unaware system does.

Training schemes (Table II):
  * individual   — no communication; each device trains alone.
  * model_fl     — FedAvg [19]: parameters uploaded each epoch, no gradient
                   compression (payload d·p bits).
  * gradient_fl  — one-step SGD + gradient upload [40], full local batch,
                   compressed payload, equal slots.
  * proposed     — gradient upload + joint batchsize/allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.latency import (DeviceProfile, downlink_latency,
                                period_latency, uplink_latency)
from repro.core.solver import PeriodSolution, solve_period


@dataclass(frozen=True)
class PolicyResult:
    batch: np.ndarray
    tau_up: np.ndarray
    tau_down: np.ndarray
    latency: float
    global_batch: float


def _fixed_batch_policy(batch, devices, rates_up, rates_down, s_bits,
                        frame_up, frame_down) -> PolicyResult:
    K = len(devices)
    batch = np.asarray(batch, float)
    tau_u = np.full(K, frame_up / K)
    tau_d = np.full(K, frame_down / K)
    t_local = np.array([d.local_grad_latency(b)
                        for d, b in zip(devices, batch)])
    t_up = uplink_latency(s_bits, tau_u, frame_up, rates_up)
    t_down = downlink_latency(s_bits, tau_d, frame_down, rates_down)
    t_upd = np.array([d.update_latency() for d in devices])
    T = period_latency(t_local, t_up, t_down, t_upd)
    return PolicyResult(batch, tau_u, tau_d, T, float(batch.sum()))


def online_policy(devices, rates_up, rates_down, s_bits, frame_up,
                  frame_down, b_max, rng=None) -> PolicyResult:
    return _fixed_batch_policy(np.ones(len(devices)), devices, rates_up,
                               rates_down, s_bits, frame_up, frame_down)


def full_batch_policy(devices, rates_up, rates_down, s_bits, frame_up,
                      frame_down, b_max, rng=None) -> PolicyResult:
    return _fixed_batch_policy(np.full(len(devices), b_max), devices,
                               rates_up, rates_down, s_bits, frame_up,
                               frame_down)


def random_batch_policy(devices, rates_up, rates_down, s_bits, frame_up,
                        frame_down, b_max, rng: Optional[np.random.Generator]
                        = None) -> PolicyResult:
    rng = rng or np.random.default_rng(0)
    batch = rng.integers(1, b_max + 1, size=len(devices))
    return _fixed_batch_policy(batch, devices, rates_up, rates_down, s_bits,
                               frame_up, frame_down)


def proposed_policy(devices, rates_up, rates_down, s_bits, frame_up,
                    frame_down, b_max, xi: float = 0.05, rng=None,
                    B: Optional[float] = None) -> PolicyResult:
    sol = solve_period(devices, rates_up, rates_down, s_bits, frame_up,
                       frame_down, xi, b_max, B=B)
    return PolicyResult(sol.batch, sol.tau_up, sol.tau_down, sol.latency,
                        sol.global_batch)


POLICIES = {
    "online": online_policy,
    "full": full_batch_policy,
    "random": random_batch_policy,
    "proposed": proposed_policy,
}

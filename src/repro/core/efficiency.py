"""Learning-efficiency criterion (paper Definition 1) and the ΔL = ξ√B
global-loss-decay model (eq. 8) with an online ξ estimator.

The √B law comes from keeping gradient-estimate variance constant under the
η ∝ √B learning-rate scaling [36,37]; ξ is model/task specific, so the
trainer re-estimates it from observed decays (EWMA) each period —
the paper treats ξ as a known constant; the estimator is our runtime
counterpart (same role as its offline fit).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def loss_decay(xi: float, global_batch) -> np.ndarray:
    """eq. (8): ΔL = ξ·√B."""
    return xi * np.sqrt(np.asarray(global_batch, float))


def learning_efficiency(xi: float, global_batch: float, period_latency: float
                        ) -> float:
    """Definition 1: E = ΔL / T."""
    return float(loss_decay(xi, global_batch) / period_latency)


def lr_scale(base_lr: float, global_batch: float, ref_batch: float) -> float:
    """η = η₀·√(B/B_ref) (paper §III-A scaling law)."""
    return base_lr * float(np.sqrt(global_batch / ref_batch))


@dataclass
class XiEstimator:
    """EWMA estimate of ξ from observed per-period loss decays.

    A scalar ξ is *decision-inert* for Algorithm 1: the fixed-B
    subproblems depend on ΔL only through the products ΔL·E and ΔL·μ
    (which the frame/batch constraints pin jointly — the allocation for a
    given B is the same at any ξ), and the outer search minimizes
    T(B)/(ξ√B) whose argmin drops ξ.  So re-estimating ξ alone can never
    change a plan; it only calibrates predicted-efficiency reporting.

    What realized decays *can* teach the planner is where the √B credit
    stops being supported: per-period decay saturates once B exceeds the
    task's useful batch (and as training converges), while the model
    extrapolates ξ√B forever.  ``delta`` tracks the realized per-period
    decay (same EWMA), and :meth:`decay_cap` exposes ``cap_headroom·δ̂``
    as a ceiling on the decay the planner may credit to *any* candidate
    B — the closed-loop chunked path plans with
    ΔL_eff(B) = min(ξ√B, cap), which clips oversized B* precisely when
    the extrapolation is unsupported and reduces to the paper's model
    otherwise (cap is ``None`` until feedback arrives).
    """
    xi: float = 0.05
    beta: float = 0.9
    cap_headroom: float = 2.0
    delta: float = field(default=float("nan"))
    _n: int = field(default=0)

    def update(self, observed_decay: float, global_batch: float) -> float:
        if global_batch > 0 and np.isfinite(observed_decay):
            sample = max(observed_decay, 0.0) / np.sqrt(global_batch)
            self.xi = self.beta * self.xi + (1 - self.beta) * sample
            d = max(observed_decay, 0.0)
            self.delta = (d if not np.isfinite(self.delta)
                          else self.beta * self.delta + (1 - self.beta) * d)
            self._n += 1
        return self.xi

    @property
    def decay_cap(self):
        """ΔL ceiling for closed-loop planning, or ``None`` before any
        feedback (the open-loop model, uncapped)."""
        if not np.isfinite(self.delta):
            return None
        return self.cap_headroom * self.delta

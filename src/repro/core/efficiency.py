"""Learning-efficiency criterion (paper Definition 1) and the ΔL = ξ√B
global-loss-decay model (eq. 8) with an online ξ estimator.

The √B law comes from keeping gradient-estimate variance constant under the
η ∝ √B learning-rate scaling [36,37]; ξ is model/task specific, so the
trainer re-estimates it from observed decays (EWMA) each period —
the paper treats ξ as a known constant; the estimator is our runtime
counterpart (same role as its offline fit).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def loss_decay(xi: float, global_batch) -> np.ndarray:
    """eq. (8): ΔL = ξ·√B."""
    return xi * np.sqrt(np.asarray(global_batch, float))


def learning_efficiency(xi: float, global_batch: float, period_latency: float
                        ) -> float:
    """Definition 1: E = ΔL / T."""
    return float(loss_decay(xi, global_batch) / period_latency)


def lr_scale(base_lr: float, global_batch: float, ref_batch: float) -> float:
    """η = η₀·√(B/B_ref) (paper §III-A scaling law)."""
    return base_lr * float(np.sqrt(global_batch / ref_batch))


@dataclass
class XiEstimator:
    """EWMA estimate of ξ from observed per-period loss decays."""
    xi: float = 0.05
    beta: float = 0.9
    _n: int = field(default=0)

    def update(self, observed_decay: float, global_batch: float) -> float:
        if global_batch > 0 and np.isfinite(observed_decay):
            sample = max(observed_decay, 0.0) / np.sqrt(global_batch)
            self.xi = self.beta * self.xi + (1 - self.beta) * sample
            self._n += 1
        return self.xi

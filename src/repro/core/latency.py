"""End-to-end latency models of one FEEL training period (paper §III-B, §V-A).

CPU devices (eq. 9):   t^L = B·C^L / f          (serial)
GPU devices (eq. 26):  t^L = t_ℓ                  for B <= B_th   (data bound)
                             c·(B - B_th) + t_ℓ   for B  > B_th   (compute bound)

Both are affine in B on the region the optimum lives in (Lemma 2), so the
solver works with the unified affine form  t^L = a + b·B  (see solver.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class DeviceProfile:
    """One edge device's compute profile."""
    kind: str                       # "cpu" | "gpu"
    # CPU scenario (eq. 9, 12)
    f_cpu: float = 2.0e9            # CPU cycles/s
    cycles_per_sample: float = 4.0e8   # C^L
    cycles_update: float = 2.0e8       # M^C
    # GPU scenario (Assumption 1, eq. 27)
    gpu_t_low: float = 0.02         # t_ℓ  (s)
    gpu_slope: float = 5.0e-4       # c    (s/sample)
    gpu_b_th: int = 16              # B_th
    f_gpu: float = 1.0e13           # FLOP/s
    flops_update: float = 2.0e9     # M^G

    # ---- affine coefficients  t^L = a + b*B on the feasible region --------
    def affine(self):
        if self.kind == "cpu":
            return 0.0, self.cycles_per_sample / self.f_cpu
        a = self.gpu_t_low - self.gpu_slope * self.gpu_b_th
        return a, self.gpu_slope

    def local_grad_latency(self, batch) -> np.ndarray:
        """eq. (9) / (26); vectorized over batch."""
        batch = np.asarray(batch, float)
        if self.kind == "cpu":
            return batch * self.cycles_per_sample / self.f_cpu
        return np.where(batch <= self.gpu_b_th, self.gpu_t_low,
                        self.gpu_slope * (batch - self.gpu_b_th)
                        + self.gpu_t_low)

    def update_latency(self) -> float:
        """eq. (12) / (27)."""
        if self.kind == "cpu":
            return self.cycles_update / self.f_cpu
        return self.flops_update / self.f_gpu

    def batch_lo(self) -> int:
        return 1 if self.kind == "cpu" else self.gpu_b_th

    def speed(self) -> float:
        """Local training speed V_k (paper's indicator, CPU: f/C^L)."""
        a, b = self.affine()
        return 1.0 / b


def uplink_latency(s_bits: float, tau: np.ndarray, frame: float,
                   rate: np.ndarray) -> np.ndarray:
    """eq. (10): t^U = s·T_f / (τ·R)."""
    return s_bits * frame / (np.maximum(tau, 1e-30) * rate)


def downlink_latency(s_bits: float, tau: np.ndarray, frame: float,
                     rate: np.ndarray) -> np.ndarray:
    """eq. (11)."""
    return uplink_latency(s_bits, tau, frame, rate)


def gradient_bits(n_params: int, bits_per_term: int = 64,
                  compression: float = 0.005) -> float:
    """s = r·d·p (paper §III-B)."""
    return compression * bits_per_term * n_params


def period_latency(t_local, t_up, t_down, t_update) -> float:
    """eq. (14): synchronous aggregation barrier + downlink/update barrier."""
    return float(np.max(np.asarray(t_local) + np.asarray(t_up))
                 + np.max(np.asarray(t_down) + np.asarray(t_update)))

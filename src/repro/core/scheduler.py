"""FEEL period scheduler — the paper's technique as a first-class runtime
feature (DESIGN.md §4).

Each training period: sample the wireless channel → solve 𝒫₁ → emit a
``PeriodPlan`` that the federated trainer consumes (per-device batchsizes
as masks, η = η₀√(B/B_ref), simulated latency ledger).  Baseline policies
are drop-in replacements via ``policy=``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.channels.model import Cell, CellConfig
from repro.core.baselines import POLICIES
from repro.core.efficiency import XiEstimator, lr_scale
from repro.core.latency import DeviceProfile, gradient_bits


@dataclass(frozen=True)
class PeriodPlan:
    period: int
    batch: np.ndarray            # B_k per device (int)
    tau_up: np.ndarray
    tau_down: np.ndarray
    lr: float
    predicted_latency: float     # seconds (simulated wall-clock)
    global_batch: int
    rates_up: np.ndarray
    rates_down: np.ndarray


@dataclass(frozen=True)
class PlanHorizon:
    """``periods`` stacked :class:`PeriodPlan` arrays — the scheduler's
    output in the form the device-resident engine consumes (one array per
    field, leading period axis, zero per-period Python objects)."""
    batch: np.ndarray            # (P, K) int
    tau_up: np.ndarray           # (P, K)
    tau_down: np.ndarray         # (P, K)
    lr: np.ndarray               # (P,) float
    latency: np.ndarray          # (P,) predicted seconds per period
    global_batch: np.ndarray     # (P,) int

    @property
    def periods(self) -> int:
        return self.batch.shape[0]


@dataclass
class FeelScheduler:
    devices: Sequence[DeviceProfile]
    n_params: int
    policy: str = "proposed"
    b_max: int = 128
    base_lr: float = 0.05
    ref_batch: float = 128.0
    bits_per_term: int = 64          # d (paper §VI-A)
    compression: float = 0.005       # r (sparse binary compression [24])
    cell: Optional[Cell] = None
    cell_cfg: CellConfig = field(default_factory=CellConfig)
    seed: int = 0
    xi_est: XiEstimator = field(default_factory=XiEstimator)
    reopt_every: int = 5         # outer B* search cadence (channel stats
                                 # are stationary; warm-start in between)
    _period: int = 0
    _dist_km: Optional[np.ndarray] = None
    _b_cache: Optional[float] = None

    def __post_init__(self):
        if self.cell is None:
            self.cell = Cell.make(self.seed, self.cell_cfg)
        self.rng = np.random.default_rng(self.seed + 1)
        # user positions are fixed for a training run; fading varies per period
        self._dist_km = self.cell.drop_users(len(self.devices))

    @property
    def payload_bits(self) -> float:
        return gradient_bits(self.n_params, self.bits_per_term,
                             self.compression)

    def observe(self, loss_decay: float, global_batch: float):
        """Feed back the realized ΔL to the ξ estimator."""
        self.xi_est.update(loss_decay, global_batch)

    def observe_series(self, loss_decays: Sequence[float],
                       global_batches: Sequence[float]):
        """Post-hoc ξ feedback for a whole trajectory at once.

        The scan engine runs the trajectory open-loop (ξ held at its value
        when the horizon was planned — the paper's known-constant treatment)
        and feeds every realized decay back here afterwards, so ξ still
        adapts across successive ``run``/``plan_horizon`` calls.
        """
        for d, g in zip(loss_decays, global_batches):
            self.xi_est.update(float(d), float(g))

    def plan_horizon(self, periods: int) -> PlanHorizon:
        """Plan ``periods`` consecutive periods open-loop and stack them.

        Channel fading is re-drawn per period (same rng stream as repeated
        ``plan()`` calls); ξ is frozen at its current estimate for the whole
        horizon instead of drifting with realized decays — the paper treats
        ξ as a known constant, and this is what makes the trajectory
        pre-plannable and therefore scan/vmap-compilable.

        The proposed policy routes through the lockstep-vectorized solver
        (one batched bisection for the whole horizon instead of P scalar
        Algorithm-1 runs); the fixed-batch baselines stay on the cheap
        per-period closed forms.
        """
        if self.policy == "proposed":
            return self._plan_horizon_proposed(periods)
        plans = [self.plan() for _ in range(periods)]
        return PlanHorizon(
            batch=np.stack([p.batch for p in plans]),
            tau_up=np.stack([p.tau_up for p in plans]),
            tau_down=np.stack([p.tau_down for p in plans]),
            lr=np.array([p.lr for p in plans], np.float64),
            latency=np.array([p.predicted_latency for p in plans],
                             np.float64),
            global_batch=np.array([p.global_batch for p in plans], np.int64))

    def _plan_horizon_proposed(self, periods: int) -> PlanHorizon:
        from repro.core.solver import optimize_batch_rows, solve_period_rows
        c = self.cell.cfg
        K = len(self.devices)
        rates_up = np.empty((periods, K))
        rates_down = np.empty((periods, K))
        for p in range(periods):                 # same rng stream as plan()
            rates_up[p] = self.cell.avg_rate(self._dist_km)
            rates_down[p] = self.cell.avg_rate(self._dist_km)
        xi = self.xi_est.xi
        # B* re-optimized on the reopt cadence; rows are independent given
        # their rates, so every reopt period solves in one batched call
        reopt = np.array([(self._period + p) % self.reopt_every == 0
                          or (p == 0 and self._b_cache is None)
                          for p in range(periods)])
        B = np.empty(periods)
        carry = self._b_cache
        if reopt.any():
            b_star = optimize_batch_rows(
                self.devices, rates_up[reopt], rates_down[reopt],
                self.payload_bits, c.frame_up_s, c.frame_down_s, xi,
                self.b_max)
            j = 0
            for p in range(periods):
                if reopt[p]:
                    carry = float(b_star[j])
                    j += 1
                B[p] = carry
        else:
            B[:] = carry
        sol = solve_period_rows(self.devices, rates_up, rates_down,
                                self.payload_bits, c.frame_up_s,
                                c.frame_down_s, xi, B, self.b_max)
        self._b_cache = float(B[-1])
        self._period += periods
        batch = np.maximum(np.round(sol["batch"]).astype(int), 1)
        gb = batch.sum(1)
        return PlanHorizon(
            batch=batch, tau_up=sol["tau_up"], tau_down=sol["tau_down"],
            lr=np.array([lr_scale(self.base_lr, g, self.ref_batch)
                         for g in gb], np.float64),
            latency=sol["latency"], global_batch=gb.astype(np.int64))

    def plan(self) -> PeriodPlan:
        c = self.cell.cfg
        rates_up = self.cell.avg_rate(self._dist_km)
        rates_down = self.cell.avg_rate(self._dist_km)
        kw = dict(rng=self.rng)
        if self.policy == "proposed":
            kw["xi"] = self.xi_est.xi
            if self._b_cache is not None and self._period % self.reopt_every:
                kw["B"] = self._b_cache
        res = POLICIES[self.policy](
            self.devices, rates_up, rates_down, self.payload_bits,
            c.frame_up_s, c.frame_down_s, self.b_max, **kw)
        if self.policy == "proposed":
            self._b_cache = res.global_batch
        batch = np.maximum(np.round(res.batch).astype(int), 1)
        gb = int(batch.sum())
        plan = PeriodPlan(
            period=self._period, batch=batch, tau_up=res.tau_up,
            tau_down=res.tau_down,
            lr=lr_scale(self.base_lr, gb, self.ref_batch),
            predicted_latency=res.latency, global_batch=gb,
            rates_up=rates_up, rates_down=rates_down)
        self._period += 1
        return plan

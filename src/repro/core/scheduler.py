"""FEEL period scheduler — the paper's technique as a first-class runtime
feature (DESIGN.md §4).

Each training period: sample the wireless channel → solve 𝒫₁ → emit a
``PeriodPlan`` that the federated trainer consumes (per-device batchsizes
as masks, η = η₀√(B/B_ref), simulated latency ledger).  Baseline policies
are drop-in replacements via ``policy=``.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.channels.model import Cell, CellConfig
from repro.core.baselines import POLICIES
from repro.core.efficiency import XiEstimator, lr_scale
from repro.core.latency import (DeviceProfile, downlink_latency,
                                gradient_bits, uplink_latency)
from repro.dynamics import (EnergyBudget, Fading, FadingProcess, Faults,
                            FaultProcess)
from repro.dynamics.energy import batch_caps, energy_spend
from repro.topology import ParticipationSampler, Sampling, Topology


@dataclass(frozen=True)
class PeriodPlan:
    period: int
    batch: np.ndarray            # B_k per device (int)
    tau_up: np.ndarray
    tau_down: np.ndarray
    lr: float
    predicted_latency: float     # seconds (simulated wall-clock)
    global_batch: int
    rates_up: np.ndarray
    rates_down: np.ndarray


@dataclass(frozen=True)
class PlanHorizon:
    """``periods`` stacked :class:`PeriodPlan` arrays — the scheduler's
    output in the form the device-resident engine consumes (one array per
    field, leading period axis, zero per-period Python objects).

    ``participation`` is the realized per-round S-of-K cohort mask when
    the scheduler carries a :class:`~repro.topology.Sampling` (None =
    everyone participates every period); ``cloud`` flags the cloud-round
    periods of a :class:`~repro.topology.Topology` horizon (None = flat
    single-tier aggregation).  Both are *outputs*: the lowering threads
    them into the engine's time-varying active mask and the hierarchical
    scan's merge cadence."""
    batch: np.ndarray            # (P, K) int
    tau_up: np.ndarray           # (P, K)
    tau_down: np.ndarray         # (P, K)
    lr: np.ndarray               # (P,) float
    latency: np.ndarray          # (P,) predicted seconds per period
    global_batch: np.ndarray     # (P,) int
    participation: Optional[np.ndarray] = None   # (P, K) f32 {0,1}
    cloud: Optional[np.ndarray] = None           # (P,) f32 {0,1}
    # --- dynamics outputs (PR 9) ---
    aggden: Optional[np.ndarray] = None          # (P,) HT fixed denominator
    energy: Optional[np.ndarray] = None          # (P, K) realized spend (J)
    slowdown: Optional[np.ndarray] = None        # (P, K) straggler factors

    @property
    def periods(self) -> int:
        return self.batch.shape[0]


@dataclass
class FeelScheduler:
    devices: Sequence[DeviceProfile]
    n_params: int
    policy: str = "proposed"
    b_max: int = 128
    base_lr: float = 0.05
    ref_batch: float = 128.0
    bits_per_term: int = 64          # d (paper §VI-A)
    compression: float = 0.005       # r (sparse binary compression [24])
    cell: Optional[Cell] = None
    cell_cfg: CellConfig = field(default_factory=CellConfig)
    seed: int = 0
    xi_est: XiEstimator = field(default_factory=XiEstimator)
    reopt_every: int = 5         # outer B* search cadence (channel stats
                                 # are stationary; warm-start in between)
    sampling: Optional[Sampling] = None    # per-round S-of-K participation
    topology: Optional[Topology] = None    # cell→edge→cloud hierarchy
    fading: Optional[Fading] = None        # block-fading Markov drift
    faults: Optional[Faults] = None        # stragglers + dropout
    energy: Optional[EnergyBudget] = None  # per-user per-period caps
    _period: int = 0
    _dist_km: Optional[np.ndarray] = None
    _b_cache: Optional[float] = None       # topology horizons: (cells,) array

    def __post_init__(self):
        if self.cell is None:
            self.cell = Cell.make(self.seed, self.cell_cfg)
        self.rng = np.random.default_rng(self.seed + 1)
        # user positions are fixed for a training run; fading varies per
        # period.  Under a topology each user's distance is read as the
        # distance to its OWN cell's base station — the single disc draw
        # is reused unchanged, so adding a topology leaves the channel
        # stream bit-identical to the flat scenario's.
        self._dist_km = self.cell.drop_users(len(self.devices))
        # participation draws live on their own stream (sampling.py) so
        # they perturb no existing draw order
        self._participation = (
            None if self.sampling is None else
            ParticipationSampler(self.sampling, len(self.devices),
                                 self.seed))
        # dynamics processes: dedicated streams (0xFAD1 / 0xFA17), same
        # disjointness contract as the participation sampler
        self._fading_proc = (
            None if self.fading is None else
            FadingProcess(self.fading, len(self.devices), self.seed))
        self._faults_proc = (
            None if self.faults is None else
            FaultProcess(self.faults, len(self.devices), self.seed))
        if self.topology is not None and (
                self.fading is not None or self.faults is not None
                or self.energy is not None):
            raise ValueError(
                "dynamics are not threaded through the hierarchical "
                "per-cell solves")
        # realized comm/comp split of the last planned chunk — the
        # adaptive-τ recommendation's inputs (bookkeeping only)
        self._last_lat: Optional[float] = None
        self._last_comp: Optional[float] = None

    @property
    def dynamic(self) -> bool:
        """True when this scheduler's world is time-varying (or its
        aggregation is importance-weighted) — such horizons plan solo in
        :func:`plan_horizons_batch` (correctness over fusion)."""
        return (self.fading is not None or self.faults is not None
                or self.energy is not None
                or (self.sampling is not None and self.sampling.weighted))

    def _draw_participation(self, periods: int) -> Optional[np.ndarray]:
        """The next ``periods`` cohort masks (None when unsampled);
        exactly one draw per planned period, so chunked horizons consume
        the stream like the monolithic plan."""
        if self._participation is None:
            return None
        return self._participation.draw(periods)

    def _draw_dynamics(self, periods: int):
        """Advance the fading and fault streams by ``periods`` — a fixed
        number of variates per period on each dedicated stream, mirroring
        the participation discipline (chunked == monolithic, and the
        draws perturb no pre-existing stream).  Returns
        ``(gains, slowdown, keep)``, each ``(P, K)`` or None."""
        gains = (None if self._fading_proc is None
                 else self._fading_proc.draw(periods))
        slow = keep = None
        if self._faults_proc is not None:
            slow, keep = self._faults_proc.draw(periods)
        return gains, slow, keep

    def _compose_avail(self, part: Optional[np.ndarray],
                       keep: Optional[np.ndarray],
                       periods: int) -> Optional[np.ndarray]:
        """Participation ∧ dropout.  Returns an array whenever faults or
        an energy budget are *configured* — mask presence must be a
        function of the spec, never of realized values, so every chunk of
        a bucket lowers with the same (time-varying) active signature —
        and None only in the static-mask world (bitwise the PR-8 path).
        A period nobody would survive suppresses its dropouts instead of
        starving the aggregation (documented soft guarantee)."""
        if keep is None and self.energy is None:
            return part
        base = (np.ones((periods, len(self.devices)))
                if part is None else np.asarray(part, float))
        if keep is None:
            return base
        avail = base * keep
        dead = avail.sum(1) <= 0
        if dead.any():
            avail = np.where(dead[:, None], base, avail)
        return avail

    def _shed_energy(self, batch_f: np.ndarray, avail: np.ndarray,
                     tau_up: np.ndarray, rates_up_p: np.ndarray,
                     periods: int):
        """Energy-budget enforcement after the per-period solve: clip
        each user to the batch it can afford at its allocated uplink
        slot; a user that cannot afford even its minimum batch drops for
        the period (one more participation mask through the same active
        machinery), unless that would empty the round — then the period
        runs at the minimum batch instead (soft floor: a zero-progress
        round helps no one).  An unreachable budget is the exact
        identity: caps are +inf, ``min(B, inf) == B``, nobody drops."""
        from repro.core.solver import FleetRows
        c = self.cell.cfg
        fr = FleetRows.from_devices(self.devices, periods)
        cap = batch_caps(self.energy, fr, tau_up, rates_up_p,
                         self.payload_bits, c.frame_up_s)
        floor_cap = np.floor(cap)
        active = avail > 0.5
        drop = active & (floor_cap < fr.lo)
        dead = ~((active & ~drop).any(1))
        drop &= ~dead[:, None]
        batch_f = np.where(drop, 0.0,
                           np.minimum(batch_f, np.maximum(floor_cap, fr.lo)))
        avail = np.where(drop, 0.0, avail)
        return batch_f, avail

    def _realize(self, batch_f: np.ndarray, avail: Optional[np.ndarray],
                 tau_up: np.ndarray, tau_down: np.ndarray,
                 rates_up: np.ndarray, rates_down: np.ndarray,
                 gains: Optional[np.ndarray], slow: Optional[np.ndarray],
                 periods: int):
        """Re-price the horizon at the REALIZED world — per-period fading
        gains (not the planner's belief), straggler slowdowns, the
        post-shed cohort.  Mirrors ``solve_period_rows``' ledger lines
        operand-for-operand, so with identity dynamics (unit gains, unit
        slowdowns, unbinding budget) the result is bitwise the solver's
        own latency.  Also returns the realized per-user energy spend
        when a budget is configured, and stores the chunk's mean
        comm/comp split for adaptive-τ recommendations."""
        from repro.core.solver import FleetRows
        c = self.cell.cfg
        s = self.payload_bits
        fr = FleetRows.from_devices(self.devices, periods)
        if avail is not None:
            fr = fr.with_mask(avail)
        ru = rates_up if gains is None else rates_up * gains
        rd = rates_down if gains is None else rates_down * gains
        t_local = fr.local_latency(batch_f)
        if slow is not None:
            t_local = t_local * slow
        t_up = s * c.frame_up_s / (np.maximum(tau_up, 1e-30) * ru)
        t_down = s * c.frame_down_s / (np.maximum(tau_down, 1e-30) * rd)
        latency = fr.mmax(t_local + t_up) + fr.mmax(t_down + fr.t_upd)
        energy = None
        if self.energy is not None:
            energy = np.where(fr.active,
                              energy_spend(self.energy, t_local, t_up), 0.0)
        self._last_lat = float(np.mean(latency))
        self._last_comp = float(np.mean(fr.mmax(t_local)))
        return latency, energy

    def recommend_tau(self, choices, current: int) -> int:
        """Score each candidate local-steps count with the paper's
        learning-efficiency criterion at the last chunk's realized
        comm/comp split — E(τ) = min(ξ√(τ·B̄), cap) / (t_comm + τ·t_comp)
        — and return the best (ties break toward fewer steps).  Before
        any feedback exists the current τ stands."""
        if self._last_lat is None or self._last_comp is None \
                or self._b_cache is None:
            return current
        try:
            b_bar = float(np.mean(self._b_cache))
        except (TypeError, ValueError):
            return current
        comp = max(self._last_comp, 0.0)
        comm = max(self._last_lat - comp, 1e-12)
        cap = self.xi_est.decay_cap
        best, best_e = current, -np.inf
        for t in sorted(choices):
            dl = self.xi_est.xi * float(np.sqrt(t * b_bar))
            if cap is not None:
                dl = min(dl, cap)
            e = dl / (comm + t * comp)
            if e > best_e:
                best, best_e = t, e
        return int(best)

    @property
    def payload_bits(self) -> float:
        return gradient_bits(self.n_params, self.bits_per_term,
                             self.compression)

    def observe(self, loss_decay: float, global_batch: float):
        """Feed back the realized ΔL to the ξ estimator."""
        self.xi_est.update(loss_decay, global_batch)

    def observe_series(self, loss_decays: Sequence[float],
                       global_batches: Sequence[float]):
        """Post-hoc ξ feedback for a whole trajectory at once.

        The scan engine runs the trajectory open-loop (ξ held at its value
        when the horizon was planned — the paper's known-constant treatment)
        and feeds every realized decay back here afterwards, so ξ still
        adapts across successive ``run``/``plan_horizon`` calls.
        """
        for d, g in zip(loss_decays, global_batches):
            self.xi_est.update(float(d), float(g))

    def plan_horizon(self, periods: int, warm_start: bool = False,
                     closed_loop: bool = False) -> PlanHorizon:
        """Plan ``periods`` consecutive periods open-loop and stack them.

        Channel fading is re-drawn per period (same rng stream as repeated
        ``plan()`` calls); ξ is frozen at its current estimate for the whole
        horizon instead of drifting with realized decays — the paper treats
        ξ as a known constant, and this is what makes the trajectory
        pre-plannable and therefore scan/vmap-compilable.  Closed-loop
        callers (chunked re-planning, ``api.lowering.BucketRun``) call this
        once per chunk with ``observe_series`` feedback in between — the
        chunked calls consume the same rng streams and, with ξ untouched,
        stay bit-identical to one monolithic call (test-enforced).

        ``warm_start`` narrows the outer B* candidate grid around the
        previous solution (``_b_cache``) — re-planning chunk *c+1* rarely
        moves B* far from chunk *c*'s optimum, so the warm grid is denser
        where it matters and ~3x cheaper.  It changes which candidates are
        evaluated, so it is opt-in and only the closed-loop path (whose
        results carry no bit-identity contract) enables it.

        ``closed_loop`` lets the realized-decay feedback actually steer
        B*: a scalar ξ cancels from every Algorithm-1 decision (see
        :class:`repro.core.efficiency.XiEstimator`), so the estimator's
        ``decay_cap`` — "credit no candidate more per-period decay than
        recently realized" — is applied to the outer B* search.  Off (the
        default, and always before any feedback has arrived) the planner
        is exactly the paper's open-loop model.

        The proposed policy routes through the lockstep-vectorized solver
        (one batched bisection for the whole horizon instead of P scalar
        Algorithm-1 runs); the fixed-batch baselines stay on the cheap
        per-period closed forms.

        With ``sampling`` set, the horizon first draws the per-round
        participation masks (their own rng stream), restricts every
        allocation to the period's cohort via the masked rows solver, and
        returns the masks as ``PlanHorizon.participation``.  With
        ``topology`` set, Algorithm 1 allocates per cell per period and
        the latency ledger adds the edge→cloud backhaul on cloud rounds
        (``PlanHorizon.cloud``).
        """
        part = self._draw_participation(periods)
        dyn = self._draw_dynamics(periods)
        if self.topology is not None:
            return self._plan_horizon_topo(periods, part, warm_start,
                                           closed_loop)
        if self.policy == "proposed":
            return self._plan_horizon_proposed(periods, warm_start,
                                               closed_loop, part, dyn)
        if self.policy in ("online", "full", "random"):
            return self._plan_horizon_fixed(periods, part, dyn, closed_loop)
        if part is not None:
            raise ValueError(
                f"sampling is not supported for policy {self.policy!r}")
        if self.dynamic:
            raise ValueError(
                f"dynamics are not supported for policy {self.policy!r}")
        plans = [self.plan() for _ in range(periods)]
        return PlanHorizon(
            batch=np.stack([p.batch for p in plans]),
            tau_up=np.stack([p.tau_up for p in plans]),
            tau_down=np.stack([p.tau_down for p in plans]),
            lr=np.array([p.lr for p in plans], np.float64),
            latency=np.array([p.predicted_latency for p in plans],
                             np.float64),
            global_batch=np.array([p.global_batch for p in plans], np.int64))

    def _plan_horizon_fixed(self, periods: int,
                            part: Optional[np.ndarray] = None,
                            dyn=(None, None, None),
                            closed_loop: bool = False) -> PlanHorizon:
        """Fixed-batch baselines, whole horizon in one lockstep evaluation.

        Bit-identical to ``periods`` successive ``plan()`` calls: the
        channel draws come from one batched interleaved (up, down) pull of
        the same rng stream, the random policy pulls one (P, K) integer
        block (≡ P sequential (K,) pulls), and the equal-slot latency math
        is ``solver.fixed_slot_rows`` — the rows analog of
        ``baselines._fixed_batch_policy``.

        ``part``: per-round participation masks.  The random policy still
        draws its full (P, K) block first (stream invariance: a sampled
        horizon consumes the rng exactly like an unsampled one) and the
        mask then zeroes out absent users; the equal TDMA slots split the
        frame among the period's cohort only.

        ``dyn``: realized (gains, slowdown, keep) dynamics (see
        ``_draw_dynamics``).  The slot math prices rates at the planner's
        *belief* gain (first-period realization; chunk-start when
        ``closed_loop``), dropout composes into the cohort mask, energy
        caps shed load post-hoc, and the ledger is re-priced at the
        realized world by ``_realize``.
        """
        from repro.core.solver import FleetRows, fixed_slot_rows
        c = self.cell.cfg
        K = len(self.devices)
        gains, slow, keep = dyn
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        if gains is None:
            pup, pdown = rates_up, rates_down
        else:
            pg = self._fading_proc.planning_gain(closed_loop)[None, :]
            pup, pdown = rates_up * pg, rates_down * pg
        if self.policy == "online":
            batch = np.ones((periods, K))
        elif self.policy == "full":
            batch = np.full((periods, K), float(self.b_max))
        else:                                    # random
            batch = self.rng.integers(
                1, self.b_max + 1, size=(periods, K)).astype(float)
        avail = self._compose_avail(part, keep, periods)
        if avail is None:
            tau_up, tau_down, latency = fixed_slot_rows(
                self.devices, batch, pup, pdown,
                self.payload_bits, c.frame_up_s, c.frame_down_s)
            batch_f = batch
        else:
            fr = FleetRows.from_devices(self.devices,
                                        periods).with_mask(avail)
            tau_up, tau_down, latency = fixed_slot_rows(
                fr, batch * avail, pup, pdown,
                self.payload_bits, c.frame_up_s, c.frame_down_s)
            batch_f = batch * avail
        mask_now = avail
        if self.energy is not None:
            batch_f, mask_now = self._shed_energy(batch_f, mask_now,
                                                  tau_up, pup, periods)
        if mask_now is None:
            ib = np.maximum(np.round(batch).astype(int), 1)
        else:
            ib = np.where(mask_now > 0.5,
                          np.maximum(np.round(batch_f).astype(int), 1), 0)
        aggden = None
        if self.sampling is not None and self.sampling.weighted:
            # Horvitz-Thompson fixed denominator: p · Σ_all b̄_k (the
            # policy batch is the full-fleet plan here)
            p_inc = self.sampling.p_of(K)
            if self.faults is not None:
                p_inc *= self.faults.keep_prob
            full = np.maximum(np.round(batch).astype(int), 1)
            aggden = p_inc * full.sum(1).astype(np.float64)
        realize = (gains is not None or slow is not None
                   or self.energy is not None)
        energy_led = None
        if realize:
            latency, energy_led = self._realize(
                batch_f, mask_now, tau_up, tau_down,
                rates_up, rates_down, gains, slow, periods)
        gb = ib.sum(1)
        self._period += periods
        return PlanHorizon(
            batch=ib, tau_up=tau_up, tau_down=tau_down,
            lr=self.base_lr * np.sqrt(gb / self.ref_batch),
            latency=latency, global_batch=gb.astype(np.int64),
            participation=mask_now, aggden=aggden, energy=energy_led,
            slowdown=slow)

    def _plan_horizon_proposed(self, periods: int, warm_start: bool = False,
                               closed_loop: bool = False,
                               part: Optional[np.ndarray] = None,
                               dyn=(None, None, None)) -> PlanHorizon:
        from repro.core.solver import (FleetRows, optimize_batch_rows,
                                       solve_period_rows)
        c = self.cell.cfg
        K = len(self.devices)
        gains, slow, keep = dyn
        # one batched interleaved draw — same rng stream order as plan().
        # A sampled horizon draws rates for ALL K users regardless (the
        # cohort mask selects; it never re-shapes the Monte-Carlo stream).
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        # planner belief under fading: open loop prices every period at
        # the horizon's FIRST realized gain (the paper's static
        # assumption — and chunking-invariant); closed loop re-reads the
        # chain at the chunk start, which is what finally makes replan
        # decision-relevant.  Realized per-period gains price the ledger
        # in ``_realize`` below.
        if gains is None:
            pup, pdown = rates_up, rates_down
        else:
            pg = self._fading_proc.planning_gain(closed_loop)[None, :]
            pup, pdown = rates_up * pg, rates_down * pg
        weighted = self.sampling is not None and self.sampling.weighted
        avail = self._compose_avail(part, keep, periods)
        # part=None keeps the plain devices path (bitwise the PR-4 code);
        # a cohort mask routes through the masked rows solver, whose
        # per-row bounds and reductions see participants only.  Weighted
        # (Horvitz-Thompson) aggregation instead plans the FULL fleet so
        # every user owns a planned share b̄_k — the fixed denominator
        # p·Σ_all b̄_k needs it — and the cohort mask applies only to the
        # executed schedule.
        solve_mask = None if weighted else avail
        rows = (self.devices if solve_mask is None else
                FleetRows.from_devices(self.devices, periods)
                .with_mask(solve_mask))
        xi = self.xi_est.xi
        # B* re-optimized on the reopt cadence; rows are independent given
        # their rates, so every reopt period solves in one batched call
        reopt = np.array([(self._period + p) % self.reopt_every == 0
                          or (p == 0 and self._b_cache is None)
                          for p in range(periods)])
        B = np.empty(periods)
        carry = self._b_cache
        if reopt.any():
            warm = warm_start and self._b_cache is not None
            b_prev = (np.full(int(reopt.sum()), self._b_cache)
                      if warm else None)
            cap = self.xi_est.decay_cap if closed_loop else None
            b_star = optimize_batch_rows(
                rows if solve_mask is None else rows.take(reopt),
                pup[reopt], pdown[reopt],
                self.payload_bits, c.frame_up_s, c.frame_down_s, xi,
                self.b_max, b_prev=b_prev,
                n_candidates=33 if warm else 97,
                dl_cap=(None if cap is None
                        else np.full(int(reopt.sum()), cap)),
                energy=self.energy)
            j = 0
            for p in range(periods):
                if reopt[p]:
                    carry = float(b_star[j])
                    j += 1
                B[p] = carry
        else:
            B[:] = carry
        sol = solve_period_rows(rows, pup, pdown,
                                self.payload_bits, c.frame_up_s,
                                c.frame_down_s, xi, B, self.b_max)
        self._b_cache = float(B[-1])
        self._period += periods
        batch_f = sol["batch"]
        mask_now = avail
        if self.energy is not None:
            batch_f, mask_now = self._shed_energy(batch_f, mask_now,
                                                  sol["tau_up"], pup,
                                                  periods)
        batch = np.maximum(np.round(batch_f).astype(int), 1)
        aggden = None
        if weighted:
            # fixed HT denominator from the full-fleet plan, BEFORE the
            # cohort mask zeroes absentees
            p_inc = self.sampling.p_of(K)
            if self.faults is not None:
                p_inc *= self.faults.keep_prob
            aggden = p_inc * batch.sum(1).astype(np.float64)
        if mask_now is not None:
            batch = np.where(mask_now > 0.5, batch, 0)
        gb = batch.sum(1)
        # the realized-world ledger re-price (and adaptive-τ stats); the
        # static world keeps the solver's own latency untouched
        realize = (gains is not None or slow is not None
                   or self.energy is not None or weighted)
        rl, energy_led = self._realize(
            batch_f, mask_now, sol["tau_up"], sol["tau_down"],
            rates_up, rates_down, gains, slow, periods)
        latency = rl if realize else sol["latency"]
        return PlanHorizon(
            batch=batch, tau_up=sol["tau_up"], tau_down=sol["tau_down"],
            lr=np.array([lr_scale(self.base_lr, g, self.ref_batch)
                         for g in gb], np.float64),
            latency=latency, global_batch=gb.astype(np.int64),
            participation=mask_now, aggden=aggden,
            energy=energy_led if realize else None, slowdown=slow)

    def _plan_horizon_topo(self, periods: int,
                           part: Optional[np.ndarray],
                           warm_start: bool = False,
                           closed_loop: bool = False) -> PlanHorizon:
        """Hierarchical horizon: Algorithm 1 allocates *within each cell*
        per period (the paper's single-cell 𝒫₁, one masked row per
        (cell, period)), and cloud-round periods add the edge→cloud
        backhaul round trip to the latency ledger.

        The wireless substrate is untouched: one disc draw, one batched
        fading draw for all K users — each user's distance is to its own
        cell's BS and each cell runs the full ``CellConfig`` spectrum, so
        the cell partition enters ONLY as a mask on the rows solver.  The
        per-period radio latency is the slowest cell's round (cells
        transmit concurrently); user-level arrays (batch, τ) recombine by
        summing the disjoint per-cell rows.

        A cell whose whole cohort is sampled out this period solves a
        deterministic dummy problem (its full-cell mask) that is zeroed
        from every output and consumes no rng — the lockstep arrays stay
        rectangular and warning-free, and the cell's B* carry is simply
        not advanced.
        """
        from repro.core.solver import (FleetRows, fixed_slot_rows,
                                       optimize_batch_rows,
                                       solve_period_rows)
        topo = self.topology
        c = self.cell.cfg
        K = len(self.devices)
        C, P = topo.cells, periods
        cloud = topo.cloud_rounds(periods, offset=self._period)
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        cmask = topo.cell_masks(K)                        # (C, K)
        mask = (cmask[:, None, :] if part is None
                else cmask[:, None, :] * part[None])      # (C, P, K)
        mask = np.broadcast_to(mask, (C, P, K))
        nonempty = mask.sum(2) > 0                        # (C, P)
        # solver rows are cell-major (row c*P + p)
        solve_mask = np.where(nonempty[:, :, None], mask,
                              np.broadcast_to(cmask[:, None, :],
                                              (C, P, K))).reshape(C * P, K)
        fr = FleetRows.from_devices(self.devices,
                                    C * P).with_mask(solve_mask)
        flat_up = np.broadcast_to(rates_up, (C, P, K)).reshape(C * P, K)
        flat_down = np.broadcast_to(rates_down,
                                    (C, P, K)).reshape(C * P, K)
        if self.policy == "proposed":
            xi = self.xi_est.xi
            carry = (np.full(C, np.nan) if self._b_cache is None
                     else np.asarray(self._b_cache, float).copy())
            base = np.array([(self._period + p) % self.reopt_every == 0
                             for p in range(P)])
            # per-cell B* cadence; a cold cell re-opts at its first
            # non-empty period even off-cadence
            reopt_cp = np.zeros((C, P), bool)
            cold = np.isnan(carry)
            for p in range(P):
                need = nonempty[:, p] & (base[p] | cold)
                reopt_cp[:, p] = need
                cold = cold & ~need
            rf = reopt_cp.reshape(C * P)
            B_cp = np.empty((C, P))
            if rf.any():
                warm = warm_start and not np.isnan(carry).all()
                b_prev = (np.repeat(carry, P)[rf] if warm else None)
                cap = self.xi_est.decay_cap if closed_loop else None
                b_star = optimize_batch_rows(
                    fr.take(rf), flat_up[rf], flat_down[rf],
                    self.payload_bits, c.frame_up_s, c.frame_down_s, xi,
                    self.b_max, b_prev=b_prev,
                    n_candidates=33 if warm else 97,
                    dl_cap=(None if cap is None
                            else np.full(int(rf.sum()), cap)))
                j = 0
                for ci in range(C):
                    cur = carry[ci]
                    for p in range(P):
                        if reopt_cp[ci, p]:
                            cur = float(b_star[j])
                            j += 1
                        B_cp[ci, p] = 1.0 if np.isnan(cur) else cur
                    carry[ci] = cur
            else:
                B_cp[:] = np.where(np.isnan(carry), 1.0, carry)[:, None]
            sol = solve_period_rows(fr, flat_up, flat_down,
                                    self.payload_bits, c.frame_up_s,
                                    c.frame_down_s, xi,
                                    B_cp.reshape(C * P), self.b_max)
            bt = np.where(fr.active,
                          np.maximum(np.round(np.nan_to_num(sol["batch"]))
                                     .astype(int), 1), 0)
            tau_u_r, tau_d_r = sol["tau_up"], sol["tau_down"]
            lat_r = sol["latency"]
            self._b_cache = carry
        else:                                    # online / full / random
            if self.policy == "online":
                pol = np.ones((P, K))
            elif self.policy == "full":
                pol = np.full((P, K), float(self.b_max))
            else:
                pol = self.rng.integers(
                    1, self.b_max + 1, size=(P, K)).astype(float)
            batch_rows = np.broadcast_to(pol, (C, P, K)).reshape(C * P, K)
            tau_u_r, tau_d_r, lat_r = fixed_slot_rows(
                fr, batch_rows * solve_mask, flat_up, flat_down,
                self.payload_bits, c.frame_up_s, c.frame_down_s)
            bt = np.where(fr.active,
                          np.maximum(np.round(batch_rows).astype(int), 1),
                          0)
        # recombine: zero the dummy rows, sum disjoint cells per user,
        # barrier (max) across concurrent cells per period
        live = nonempty[:, :, None]
        bt = np.where(live, bt.reshape(C, P, K), 0)
        tau_up = np.where(live, np.nan_to_num(tau_u_r).reshape(C, P, K),
                          0.0).sum(0)
        tau_down = np.where(live, np.nan_to_num(tau_d_r).reshape(C, P, K),
                            0.0).sum(0)
        radio = np.where(nonempty, np.nan_to_num(lat_r).reshape(C, P),
                         0.0).max(0)
        latency = radio + cloud.astype(float) * topo.backhaul_roundtrip(
            self.payload_bits)
        batch = bt.sum(0)                                 # (P, K)
        gb = batch.sum(1)
        if self.policy == "proposed":
            lr = np.array([lr_scale(self.base_lr, g, self.ref_batch)
                           for g in gb], np.float64)
        else:
            lr = self.base_lr * np.sqrt(gb / self.ref_batch)
        self._period += periods
        return PlanHorizon(
            batch=batch, tau_up=tau_up, tau_down=tau_down, lr=lr,
            latency=latency, global_batch=gb.astype(np.int64),
            participation=part, cloud=cloud)

    def plan(self) -> PeriodPlan:
        c = self.cell.cfg
        rates_up = self.cell.avg_rate(self._dist_km)
        rates_down = self.cell.avg_rate(self._dist_km)
        kw = dict(rng=self.rng)
        if self.policy == "proposed":
            kw["xi"] = self.xi_est.xi
            if self._b_cache is not None and self._period % self.reopt_every:
                kw["B"] = self._b_cache
        res = POLICIES[self.policy](
            self.devices, rates_up, rates_down, self.payload_bits,
            c.frame_up_s, c.frame_down_s, self.b_max, **kw)
        if self.policy == "proposed":
            self._b_cache = res.global_batch
        batch = np.maximum(np.round(res.batch).astype(int), 1)
        gb = int(batch.sum())
        plan = PeriodPlan(
            period=self._period, batch=batch, tau_up=res.tau_up,
            tau_down=res.tau_down,
            lr=lr_scale(self.base_lr, gb, self.ref_batch),
            predicted_latency=res.latency, global_batch=gb,
            rates_up=rates_up, rates_down=rates_down)
        self._period += 1
        return plan


# ---------------------------------------------------------------------------
# Cross-scenario lockstep planning (the api.Experiment lowering path)
# ---------------------------------------------------------------------------


def plan_horizons_batch(schedulers: Sequence[FeelScheduler],
                        periods: int, warm_start: bool = False,
                        closed_loop: bool = False) -> List[PlanHorizon]:
    """Plan many schedulers' horizons with proposed-policy rows fused —
    across fleets of ANY size or composition.

    ``warm_start`` and ``closed_loop`` forward to every proposed-policy
    solve (see :meth:`FeelScheduler.plan_horizon`): chunked closed-loop
    re-planning narrows each reopt period's B* candidate grid around that
    scheduler's previous solution and caps the decay credited to any
    candidate at the scheduler's realized-decay ceiling.  Off (the
    default), planning is bit-identical to
    ``[s.plan_horizon(periods) for s in schedulers]``:
    each scheduler's own rng streams are consumed in exactly the per-call
    order, but Algorithm-1 / Theorem-2 bisections for every proposed-policy
    scheduler that shares (payload, frames, b_max) run as ONE lockstep
    masked rows solve over the flattened (scenario × period) axis.  Fleets
    are padded to the group's max K as :class:`~repro.core.solver.FleetRows`
    (padded user columns: deterministic rate fill, active mask 0 — zero
    batchsize and bandwidth share, outside every reduction), so a K-sweep
    plans as one solve instead of one per fleet; the rows are independent
    given their rates and mask, so fusing changes nothing but wall-clock
    (test-enforced bitwise).  Scheduler state (ξ cache, ``_b_cache``,
    ``_period``) is advanced exactly as the per-call path would.
    """
    from repro.core.solver import (FleetRows, optimize_batch_rows,
                                   solve_period_rows)
    out: List[Optional[PlanHorizon]] = [None] * len(schedulers)
    groups = defaultdict(list)
    for i, s in enumerate(schedulers):
        if s.policy != "proposed":
            out[i] = s.plan_horizon(periods, warm_start=warm_start,
                                    closed_loop=closed_loop)
        elif s.topology is not None or s.dynamic:
            # hierarchical horizons solve per (cell, period) with their
            # own reopt bookkeeping, and time-varying worlds (fading /
            # faults / energy / weighted sampling) carry belief-vs-
            # realized state the lockstep fuse does not model — solo,
            # flags forwarded.  Stream discipline makes solo-vs-fused
            # bitwise anyway, so only wall-clock differs.
            out[i] = s.plan_horizon(periods, warm_start=warm_start,
                                    closed_loop=closed_loop)
        else:
            key = (s.payload_bits, s.cell.cfg.frame_up_s,
                   s.cell.cfg.frame_down_s, s.b_max, s.reopt_every)
            groups[key].append(i)
    for key, idxs in groups.items():
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = schedulers[i].plan_horizon(periods,
                                                warm_start=warm_start,
                                                closed_loop=closed_loop)
            continue
        scheds = [schedulers[i] for i in idxs]
        s0 = scheds[0]
        c = s0.cell.cfg
        M, P = len(scheds), periods
        ks = [len(s.devices) for s in scheds]
        K = max(ks)
        fleet_rows = FleetRows.from_fleets(
            [tuple(s.devices) for s in scheds], k_pad=K)
        # participation first, matching plan_horizon's draw order; its
        # dedicated stream means fused vs. solo stays bitwise either way
        parts = [s._draw_participation(P) for s in scheds]
        rates_up = np.empty((M, P, K))
        rates_down = np.empty((M, P, K))
        for m, s in enumerate(scheds):           # per-scheduler rng streams
            rates_up[m], rates_down[m] = s.cell.avg_rate_updown_rows(
                s._dist_km, P, pad_to=K)
        xi = np.array([s.xi_est.xi for s in scheds])
        reopt = np.array([[(s._period + p) % s.reopt_every == 0
                           or (p == 0 and s._b_cache is None)
                           for p in range(P)] for s in scheds])
        flat_up = rates_up.reshape(M * P, K)
        flat_down = rates_down.reshape(M * P, K)
        flat_fleets = fleet_rows.repeat(P)       # row m*P+p = scheduler m
        if any(p_m is not None for p_m in parts):
            pm = np.ones((M, P, K))
            for m, p_m in enumerate(parts):
                if p_m is not None:              # pad cols stay 1; the
                    pm[m, :, :ks[m]] = p_m       # fleet mask zeroes them
            flat_fleets = flat_fleets.with_mask(pm.reshape(M * P, K))
        xi_rows = np.repeat(xi, P)
        B = np.empty((M, P))
        if reopt.any():
            rf = reopt.reshape(M * P)
            b_prev = None
            n_cand = 97
            if warm_start:
                # per-scheduler previous-solution hints (NaN = cold row)
                prev = np.repeat(np.array(
                    [np.nan if s._b_cache is None else s._b_cache
                     for s in scheds]), P)[rf]
                if np.isfinite(prev).any():
                    b_prev = prev
                    n_cand = 33
            dl_cap = None
            if closed_loop:
                caps = np.repeat(np.array(
                    [np.inf if s.xi_est.decay_cap is None
                     else s.xi_est.decay_cap for s in scheds]), P)[rf]
                if np.isfinite(caps).any():
                    dl_cap = caps
            b_star = optimize_batch_rows(
                flat_fleets.take(rf), flat_up[rf], flat_down[rf],
                s0.payload_bits, c.frame_up_s, c.frame_down_s, xi_rows[rf],
                s0.b_max, b_prev=b_prev, n_candidates=n_cand,
                dl_cap=dl_cap)
            j = 0
            for m, s in enumerate(scheds):
                carry = s._b_cache
                for p in range(P):
                    if reopt[m, p]:
                        carry = float(b_star[j])
                        j += 1
                    B[m, p] = carry
        else:
            for m, s in enumerate(scheds):
                B[m, :] = s._b_cache
        sol = solve_period_rows(flat_fleets, flat_up, flat_down,
                                s0.payload_bits, c.frame_up_s, c.frame_down_s,
                                xi_rows, B.reshape(M * P), s0.b_max)
        # round active batches up to >= 1; padded columns and sampled-out
        # users stay exactly 0
        batch = np.where(flat_fleets.active.reshape(M, P, K),
                         np.maximum(np.round(sol["batch"]).astype(int)
                                    .reshape(M, P, K), 1), 0)
        gb = batch.sum(2)
        # adaptive-τ bookkeeping (values only — no output depends on it):
        # mean realized comm/comp split per scheduler for recommend_tau
        comp_mp = flat_fleets.mmax(
            flat_fleets.local_latency(sol["batch"])).reshape(M, P)
        lat_mp = sol["latency"].reshape(M, P)
        for m, (i, s) in enumerate(zip(idxs, scheds)):
            s._b_cache = float(B[m, -1])
            s._period += P
            s._last_lat = float(np.mean(lat_mp[m]))
            s._last_comp = float(np.mean(comp_mp[m]))
            k_m = ks[m]                          # slice back to the true K
            out[i] = PlanHorizon(
                batch=batch[m, :, :k_m],
                tau_up=sol["tau_up"].reshape(M, P, K)[m, :, :k_m],
                tau_down=sol["tau_down"].reshape(M, P, K)[m, :, :k_m],
                lr=np.array([lr_scale(s.base_lr, g, s.ref_batch)
                             for g in gb[m]], np.float64),
                latency=sol["latency"].reshape(M, P)[m],
                global_batch=gb[m].astype(np.int64),
                participation=parts[m])
    return out


# ---------------------------------------------------------------------------
# Per-device-parameter schemes (individual / model_fl): the latency ledger
# as a planner, not a hand-rolled Python loop in the trainer
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DevHorizon:
    """Pre-planned horizon for the per-device-parameter schemes — the same
    role :class:`PlanHorizon` plays for the FEEL schemes: everything the
    scan engine consumes, one array per field, leading period axis."""
    idx: np.ndarray              # (P, K, batch) int64 sample indices
    times: np.ndarray            # (P,) cumulative simulated seconds
    tau_up: np.ndarray           # (P, K) equal TDMA slots (scheme-defined)
    tau_down: np.ndarray         # (P, K)
    rates_up: np.ndarray         # (P, K)
    rates_down: np.ndarray       # (P, K)
    participation: Optional[np.ndarray] = None   # (P, K) f32 {0,1}

    @property
    def periods(self) -> int:
        return self.idx.shape[0]


@dataclass
class DevScheduler:
    """Vectorized horizon planner for ``individual`` / ``model_fl``.

    Replaces the trainer's hand-rolled per-period ``_epoch_latency`` Python
    ledger: channel rates come from the same batched interleaved (up, down)
    draw the FEEL scheduler uses, and — fixing the PR-1 bug — the downlink
    subperiod is routed through the planner's ``tau_down``/``rates_down``
    path via ``latency.downlink_latency`` (eq. (11)) instead of a second
    ad-hoc ``uplink_latency`` call, so formula and rng stream match the
    FEEL scheme's planner.  eqs. (10) and (11) coincide numerically, so the
    fix is stream/formula hygiene: ledgers stay bit-identical to PR 1
    (test-covered).
    """
    devices: Sequence[DeviceProfile]
    parts: Sequence[np.ndarray]          # per-device index sets
    batch: int                           # fixed per-device batchsize
    payload_bits: float                  # model upload: d·p, uncompressed
    upload: bool                         # model_fl syncs; individual doesn't
    seed: int = 0
    cell: Optional[Cell] = None
    cell_cfg: CellConfig = field(default_factory=CellConfig)
    sampling: Optional[Sampling] = None    # per-round S-of-K participation

    def __post_init__(self):
        if self.cell is None:
            self.cell = Cell.make(self.seed, self.cell_cfg)
        self.rng = np.random.default_rng(self.seed)
        self._dist_km = self.cell.drop_users(len(self.parts))
        self._participation = (
            None if self.sampling is None else
            ParticipationSampler(self.sampling, len(self.parts), self.seed))

    def plan_horizon(self, periods: int,
                     time_offset: float = 0.0) -> DevHorizon:
        """``time_offset`` seeds the cumulative time axis (chunked
        horizons accumulate *from* the offset — the seeded cumsum is the
        only form bit-identical to the monolithic ledger; 0.0 degenerates
        to the plain cumsum bitwise).

        With ``sampling`` set, each period's cohort alone splits the TDMA
        frame (equal slots over S, zero for absent users) and alone enters
        the straggler max; every rng draw (positions, minibatch indices,
        fading) is still made for all K users so the streams — and hence
        every participant's trajectory — are untouched by who sat out."""
        K = len(self.parts)
        c = self.cell.cfg
        part = (None if self._participation is None
                else self._participation.draw(periods))
        idx = np.empty((periods, K, self.batch), np.int64)
        for p in range(periods):         # same rng order as the PR-1 loop
            idx[p] = np.stack(
                [self.rng.choice(part_k, size=self.batch,
                                 replace=len(part_k) < self.batch)
                 for part_k in self.parts])
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        # one local epoch per period: ⌈|D_k|/B⌉ minibatch steps
        t_local = np.array([
            d.local_grad_latency(self.batch) * max(1, len(p_k) // self.batch)
            for d, p_k in zip(self.devices, self.parts)])
        if part is None:
            tau_u = np.full((periods, K), c.frame_up_s / K)
            tau_d = np.full((periods, K), c.frame_down_s / K)
        else:
            # float64 cohort sizes: the f32 mask must not demote the slot
            # widths below the unsampled path's precision
            s_p = part.astype(np.float64).sum(1)     # >= 1 per period
            tau_u = np.where(part > 0.5, c.frame_up_s / s_p[:, None], 0.0)
            tau_d = np.where(part > 0.5, c.frame_down_s / s_p[:, None], 0.0)
        if self.upload:
            # absent users get a dummy full-frame slot for the latency
            # math (keeps it finite/warning-free) and are then masked out
            # of the straggler max; part=None leaves tau untouched (the
            # where selects the original values), so that path is bitwise
            su = np.where(tau_u > 0, tau_u, c.frame_up_s)
            sd = np.where(tau_d > 0, tau_d, c.frame_down_s)
            t_up = uplink_latency(self.payload_bits, su, c.frame_up_s,
                                  rates_up)
            t_down = downlink_latency(self.payload_bits, sd,
                                      c.frame_down_s, rates_down)
            t_upd = np.array([d.update_latency() for d in self.devices])
            up_leg = t_local + t_up
            down_leg = t_down + t_upd
            if part is not None:
                up_leg = np.where(part > 0.5, up_leg, 0.0)
                down_leg = np.where(part > 0.5, down_leg, 0.0)
            per_period = up_leg.max(1) + down_leg.max(1)
        elif part is None:
            per_period = np.full(periods, t_local.max())
        else:
            per_period = np.where(part > 0.5, t_local[None, :], 0.0).max(1)
        times = np.cumsum(np.concatenate([[time_offset], per_period]))[1:]
        return DevHorizon(idx=idx, times=times,
                          tau_up=tau_u, tau_down=tau_d,
                          rates_up=rates_up, rates_down=rates_down,
                          participation=part)

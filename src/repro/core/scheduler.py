"""FEEL period scheduler — the paper's technique as a first-class runtime
feature (DESIGN.md §4).

Each training period: sample the wireless channel → solve 𝒫₁ → emit a
``PeriodPlan`` that the federated trainer consumes (per-device batchsizes
as masks, η = η₀√(B/B_ref), simulated latency ledger).  Baseline policies
are drop-in replacements via ``policy=``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.channels.model import Cell, CellConfig
from repro.core.baselines import POLICIES
from repro.core.efficiency import XiEstimator, lr_scale
from repro.core.latency import DeviceProfile, gradient_bits


@dataclass(frozen=True)
class PeriodPlan:
    period: int
    batch: np.ndarray            # B_k per device (int)
    tau_up: np.ndarray
    tau_down: np.ndarray
    lr: float
    predicted_latency: float     # seconds (simulated wall-clock)
    global_batch: int
    rates_up: np.ndarray
    rates_down: np.ndarray


@dataclass
class FeelScheduler:
    devices: Sequence[DeviceProfile]
    n_params: int
    policy: str = "proposed"
    b_max: int = 128
    base_lr: float = 0.05
    ref_batch: float = 128.0
    bits_per_term: int = 64          # d (paper §VI-A)
    compression: float = 0.005       # r (sparse binary compression [24])
    cell: Optional[Cell] = None
    cell_cfg: CellConfig = field(default_factory=CellConfig)
    seed: int = 0
    xi_est: XiEstimator = field(default_factory=XiEstimator)
    reopt_every: int = 5         # outer B* search cadence (channel stats
                                 # are stationary; warm-start in between)
    _period: int = 0
    _dist_km: Optional[np.ndarray] = None
    _b_cache: Optional[float] = None

    def __post_init__(self):
        if self.cell is None:
            self.cell = Cell.make(self.seed, self.cell_cfg)
        self.rng = np.random.default_rng(self.seed + 1)
        # user positions are fixed for a training run; fading varies per period
        self._dist_km = self.cell.drop_users(len(self.devices))

    @property
    def payload_bits(self) -> float:
        return gradient_bits(self.n_params, self.bits_per_term,
                             self.compression)

    def observe(self, loss_decay: float, global_batch: float):
        """Feed back the realized ΔL to the ξ estimator."""
        self.xi_est.update(loss_decay, global_batch)

    def plan(self) -> PeriodPlan:
        c = self.cell.cfg
        rates_up = self.cell.avg_rate(self._dist_km)
        rates_down = self.cell.avg_rate(self._dist_km)
        kw = dict(rng=self.rng)
        if self.policy == "proposed":
            kw["xi"] = self.xi_est.xi
            if self._b_cache is not None and self._period % self.reopt_every:
                kw["B"] = self._b_cache
        res = POLICIES[self.policy](
            self.devices, rates_up, rates_down, self.payload_bits,
            c.frame_up_s, c.frame_down_s, self.b_max, **kw)
        if self.policy == "proposed":
            self._b_cache = res.global_batch
        batch = np.maximum(np.round(res.batch).astype(int), 1)
        gb = int(batch.sum())
        plan = PeriodPlan(
            period=self._period, batch=batch, tau_up=res.tau_up,
            tau_down=res.tau_down,
            lr=lr_scale(self.base_lr, gb, self.ref_batch),
            predicted_latency=res.latency, global_batch=gb,
            rates_up=rates_up, rates_down=rates_down)
        self._period += 1
        return plan

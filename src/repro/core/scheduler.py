"""FEEL period scheduler — the paper's technique as a first-class runtime
feature (DESIGN.md §4).

Each training period: sample the wireless channel → solve 𝒫₁ → emit a
``PeriodPlan`` that the federated trainer consumes (per-device batchsizes
as masks, η = η₀√(B/B_ref), simulated latency ledger).  Baseline policies
are drop-in replacements via ``policy=``.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.channels.model import Cell, CellConfig
from repro.core.baselines import POLICIES
from repro.core.efficiency import XiEstimator, lr_scale
from repro.core.latency import (DeviceProfile, downlink_latency,
                                gradient_bits, uplink_latency)


@dataclass(frozen=True)
class PeriodPlan:
    period: int
    batch: np.ndarray            # B_k per device (int)
    tau_up: np.ndarray
    tau_down: np.ndarray
    lr: float
    predicted_latency: float     # seconds (simulated wall-clock)
    global_batch: int
    rates_up: np.ndarray
    rates_down: np.ndarray


@dataclass(frozen=True)
class PlanHorizon:
    """``periods`` stacked :class:`PeriodPlan` arrays — the scheduler's
    output in the form the device-resident engine consumes (one array per
    field, leading period axis, zero per-period Python objects)."""
    batch: np.ndarray            # (P, K) int
    tau_up: np.ndarray           # (P, K)
    tau_down: np.ndarray         # (P, K)
    lr: np.ndarray               # (P,) float
    latency: np.ndarray          # (P,) predicted seconds per period
    global_batch: np.ndarray     # (P,) int

    @property
    def periods(self) -> int:
        return self.batch.shape[0]


@dataclass
class FeelScheduler:
    devices: Sequence[DeviceProfile]
    n_params: int
    policy: str = "proposed"
    b_max: int = 128
    base_lr: float = 0.05
    ref_batch: float = 128.0
    bits_per_term: int = 64          # d (paper §VI-A)
    compression: float = 0.005       # r (sparse binary compression [24])
    cell: Optional[Cell] = None
    cell_cfg: CellConfig = field(default_factory=CellConfig)
    seed: int = 0
    xi_est: XiEstimator = field(default_factory=XiEstimator)
    reopt_every: int = 5         # outer B* search cadence (channel stats
                                 # are stationary; warm-start in between)
    _period: int = 0
    _dist_km: Optional[np.ndarray] = None
    _b_cache: Optional[float] = None

    def __post_init__(self):
        if self.cell is None:
            self.cell = Cell.make(self.seed, self.cell_cfg)
        self.rng = np.random.default_rng(self.seed + 1)
        # user positions are fixed for a training run; fading varies per period
        self._dist_km = self.cell.drop_users(len(self.devices))

    @property
    def payload_bits(self) -> float:
        return gradient_bits(self.n_params, self.bits_per_term,
                             self.compression)

    def observe(self, loss_decay: float, global_batch: float):
        """Feed back the realized ΔL to the ξ estimator."""
        self.xi_est.update(loss_decay, global_batch)

    def observe_series(self, loss_decays: Sequence[float],
                       global_batches: Sequence[float]):
        """Post-hoc ξ feedback for a whole trajectory at once.

        The scan engine runs the trajectory open-loop (ξ held at its value
        when the horizon was planned — the paper's known-constant treatment)
        and feeds every realized decay back here afterwards, so ξ still
        adapts across successive ``run``/``plan_horizon`` calls.
        """
        for d, g in zip(loss_decays, global_batches):
            self.xi_est.update(float(d), float(g))

    def plan_horizon(self, periods: int, warm_start: bool = False,
                     closed_loop: bool = False) -> PlanHorizon:
        """Plan ``periods`` consecutive periods open-loop and stack them.

        Channel fading is re-drawn per period (same rng stream as repeated
        ``plan()`` calls); ξ is frozen at its current estimate for the whole
        horizon instead of drifting with realized decays — the paper treats
        ξ as a known constant, and this is what makes the trajectory
        pre-plannable and therefore scan/vmap-compilable.  Closed-loop
        callers (chunked re-planning, ``api.lowering.BucketRun``) call this
        once per chunk with ``observe_series`` feedback in between — the
        chunked calls consume the same rng streams and, with ξ untouched,
        stay bit-identical to one monolithic call (test-enforced).

        ``warm_start`` narrows the outer B* candidate grid around the
        previous solution (``_b_cache``) — re-planning chunk *c+1* rarely
        moves B* far from chunk *c*'s optimum, so the warm grid is denser
        where it matters and ~3x cheaper.  It changes which candidates are
        evaluated, so it is opt-in and only the closed-loop path (whose
        results carry no bit-identity contract) enables it.

        ``closed_loop`` lets the realized-decay feedback actually steer
        B*: a scalar ξ cancels from every Algorithm-1 decision (see
        :class:`repro.core.efficiency.XiEstimator`), so the estimator's
        ``decay_cap`` — "credit no candidate more per-period decay than
        recently realized" — is applied to the outer B* search.  Off (the
        default, and always before any feedback has arrived) the planner
        is exactly the paper's open-loop model.

        The proposed policy routes through the lockstep-vectorized solver
        (one batched bisection for the whole horizon instead of P scalar
        Algorithm-1 runs); the fixed-batch baselines stay on the cheap
        per-period closed forms.
        """
        if self.policy == "proposed":
            return self._plan_horizon_proposed(periods, warm_start,
                                               closed_loop)
        if self.policy in ("online", "full", "random"):
            return self._plan_horizon_fixed(periods)
        plans = [self.plan() for _ in range(periods)]
        return PlanHorizon(
            batch=np.stack([p.batch for p in plans]),
            tau_up=np.stack([p.tau_up for p in plans]),
            tau_down=np.stack([p.tau_down for p in plans]),
            lr=np.array([p.lr for p in plans], np.float64),
            latency=np.array([p.predicted_latency for p in plans],
                             np.float64),
            global_batch=np.array([p.global_batch for p in plans], np.int64))

    def _plan_horizon_fixed(self, periods: int) -> PlanHorizon:
        """Fixed-batch baselines, whole horizon in one lockstep evaluation.

        Bit-identical to ``periods`` successive ``plan()`` calls: the
        channel draws come from one batched interleaved (up, down) pull of
        the same rng stream, the random policy pulls one (P, K) integer
        block (≡ P sequential (K,) pulls), and the equal-slot latency math
        is ``solver.fixed_slot_rows`` — the rows analog of
        ``baselines._fixed_batch_policy``.
        """
        from repro.core.solver import fixed_slot_rows
        c = self.cell.cfg
        K = len(self.devices)
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        if self.policy == "online":
            batch = np.ones((periods, K))
        elif self.policy == "full":
            batch = np.full((periods, K), float(self.b_max))
        else:                                    # random
            batch = self.rng.integers(
                1, self.b_max + 1, size=(periods, K)).astype(float)
        tau_up, tau_down, latency = fixed_slot_rows(
            self.devices, batch, rates_up, rates_down, self.payload_bits,
            c.frame_up_s, c.frame_down_s)
        ib = np.maximum(np.round(batch).astype(int), 1)
        gb = ib.sum(1)
        self._period += periods
        return PlanHorizon(
            batch=ib, tau_up=tau_up, tau_down=tau_down,
            lr=self.base_lr * np.sqrt(gb / self.ref_batch),
            latency=latency, global_batch=gb.astype(np.int64))

    def _plan_horizon_proposed(self, periods: int, warm_start: bool = False,
                               closed_loop: bool = False) -> PlanHorizon:
        from repro.core.solver import optimize_batch_rows, solve_period_rows
        c = self.cell.cfg
        K = len(self.devices)
        # one batched interleaved draw — same rng stream order as plan()
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        xi = self.xi_est.xi
        # B* re-optimized on the reopt cadence; rows are independent given
        # their rates, so every reopt period solves in one batched call
        reopt = np.array([(self._period + p) % self.reopt_every == 0
                          or (p == 0 and self._b_cache is None)
                          for p in range(periods)])
        B = np.empty(periods)
        carry = self._b_cache
        if reopt.any():
            warm = warm_start and self._b_cache is not None
            b_prev = (np.full(int(reopt.sum()), self._b_cache)
                      if warm else None)
            cap = self.xi_est.decay_cap if closed_loop else None
            b_star = optimize_batch_rows(
                self.devices, rates_up[reopt], rates_down[reopt],
                self.payload_bits, c.frame_up_s, c.frame_down_s, xi,
                self.b_max, b_prev=b_prev,
                n_candidates=33 if warm else 97,
                dl_cap=(None if cap is None
                        else np.full(int(reopt.sum()), cap)))
            j = 0
            for p in range(periods):
                if reopt[p]:
                    carry = float(b_star[j])
                    j += 1
                B[p] = carry
        else:
            B[:] = carry
        sol = solve_period_rows(self.devices, rates_up, rates_down,
                                self.payload_bits, c.frame_up_s,
                                c.frame_down_s, xi, B, self.b_max)
        self._b_cache = float(B[-1])
        self._period += periods
        batch = np.maximum(np.round(sol["batch"]).astype(int), 1)
        gb = batch.sum(1)
        return PlanHorizon(
            batch=batch, tau_up=sol["tau_up"], tau_down=sol["tau_down"],
            lr=np.array([lr_scale(self.base_lr, g, self.ref_batch)
                         for g in gb], np.float64),
            latency=sol["latency"], global_batch=gb.astype(np.int64))

    def plan(self) -> PeriodPlan:
        c = self.cell.cfg
        rates_up = self.cell.avg_rate(self._dist_km)
        rates_down = self.cell.avg_rate(self._dist_km)
        kw = dict(rng=self.rng)
        if self.policy == "proposed":
            kw["xi"] = self.xi_est.xi
            if self._b_cache is not None and self._period % self.reopt_every:
                kw["B"] = self._b_cache
        res = POLICIES[self.policy](
            self.devices, rates_up, rates_down, self.payload_bits,
            c.frame_up_s, c.frame_down_s, self.b_max, **kw)
        if self.policy == "proposed":
            self._b_cache = res.global_batch
        batch = np.maximum(np.round(res.batch).astype(int), 1)
        gb = int(batch.sum())
        plan = PeriodPlan(
            period=self._period, batch=batch, tau_up=res.tau_up,
            tau_down=res.tau_down,
            lr=lr_scale(self.base_lr, gb, self.ref_batch),
            predicted_latency=res.latency, global_batch=gb,
            rates_up=rates_up, rates_down=rates_down)
        self._period += 1
        return plan


# ---------------------------------------------------------------------------
# Cross-scenario lockstep planning (the api.Experiment lowering path)
# ---------------------------------------------------------------------------


def plan_horizons_batch(schedulers: Sequence[FeelScheduler],
                        periods: int, warm_start: bool = False,
                        closed_loop: bool = False) -> List[PlanHorizon]:
    """Plan many schedulers' horizons with proposed-policy rows fused —
    across fleets of ANY size or composition.

    ``warm_start`` and ``closed_loop`` forward to every proposed-policy
    solve (see :meth:`FeelScheduler.plan_horizon`): chunked closed-loop
    re-planning narrows each reopt period's B* candidate grid around that
    scheduler's previous solution and caps the decay credited to any
    candidate at the scheduler's realized-decay ceiling.  Off (the
    default), planning is bit-identical to
    ``[s.plan_horizon(periods) for s in schedulers]``:
    each scheduler's own rng streams are consumed in exactly the per-call
    order, but Algorithm-1 / Theorem-2 bisections for every proposed-policy
    scheduler that shares (payload, frames, b_max) run as ONE lockstep
    masked rows solve over the flattened (scenario × period) axis.  Fleets
    are padded to the group's max K as :class:`~repro.core.solver.FleetRows`
    (padded user columns: deterministic rate fill, active mask 0 — zero
    batchsize and bandwidth share, outside every reduction), so a K-sweep
    plans as one solve instead of one per fleet; the rows are independent
    given their rates and mask, so fusing changes nothing but wall-clock
    (test-enforced bitwise).  Scheduler state (ξ cache, ``_b_cache``,
    ``_period``) is advanced exactly as the per-call path would.
    """
    from repro.core.solver import (FleetRows, optimize_batch_rows,
                                   solve_period_rows)
    out: List[Optional[PlanHorizon]] = [None] * len(schedulers)
    groups = defaultdict(list)
    for i, s in enumerate(schedulers):
        if s.policy != "proposed":
            out[i] = s.plan_horizon(periods)
        else:
            key = (s.payload_bits, s.cell.cfg.frame_up_s,
                   s.cell.cfg.frame_down_s, s.b_max, s.reopt_every)
            groups[key].append(i)
    for key, idxs in groups.items():
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = schedulers[i].plan_horizon(periods,
                                                warm_start=warm_start,
                                                closed_loop=closed_loop)
            continue
        scheds = [schedulers[i] for i in idxs]
        s0 = scheds[0]
        c = s0.cell.cfg
        M, P = len(scheds), periods
        ks = [len(s.devices) for s in scheds]
        K = max(ks)
        fleet_rows = FleetRows.from_fleets(
            [tuple(s.devices) for s in scheds], k_pad=K)
        rates_up = np.empty((M, P, K))
        rates_down = np.empty((M, P, K))
        for m, s in enumerate(scheds):           # per-scheduler rng streams
            rates_up[m], rates_down[m] = s.cell.avg_rate_updown_rows(
                s._dist_km, P, pad_to=K)
        xi = np.array([s.xi_est.xi for s in scheds])
        reopt = np.array([[(s._period + p) % s.reopt_every == 0
                           or (p == 0 and s._b_cache is None)
                           for p in range(P)] for s in scheds])
        flat_up = rates_up.reshape(M * P, K)
        flat_down = rates_down.reshape(M * P, K)
        flat_fleets = fleet_rows.repeat(P)       # row m*P+p = scheduler m
        xi_rows = np.repeat(xi, P)
        B = np.empty((M, P))
        if reopt.any():
            rf = reopt.reshape(M * P)
            b_prev = None
            n_cand = 97
            if warm_start:
                # per-scheduler previous-solution hints (NaN = cold row)
                prev = np.repeat(np.array(
                    [np.nan if s._b_cache is None else s._b_cache
                     for s in scheds]), P)[rf]
                if np.isfinite(prev).any():
                    b_prev = prev
                    n_cand = 33
            dl_cap = None
            if closed_loop:
                caps = np.repeat(np.array(
                    [np.inf if s.xi_est.decay_cap is None
                     else s.xi_est.decay_cap for s in scheds]), P)[rf]
                if np.isfinite(caps).any():
                    dl_cap = caps
            b_star = optimize_batch_rows(
                flat_fleets.take(rf), flat_up[rf], flat_down[rf],
                s0.payload_bits, c.frame_up_s, c.frame_down_s, xi_rows[rf],
                s0.b_max, b_prev=b_prev, n_candidates=n_cand,
                dl_cap=dl_cap)
            j = 0
            for m, s in enumerate(scheds):
                carry = s._b_cache
                for p in range(P):
                    if reopt[m, p]:
                        carry = float(b_star[j])
                        j += 1
                    B[m, p] = carry
        else:
            for m, s in enumerate(scheds):
                B[m, :] = s._b_cache
        sol = solve_period_rows(flat_fleets, flat_up, flat_down,
                                s0.payload_bits, c.frame_up_s, c.frame_down_s,
                                xi_rows, B.reshape(M * P), s0.b_max)
        # round active batches up to >= 1; padded columns stay exactly 0
        batch = np.where(fleet_rows.active[:, None, :],
                         np.maximum(np.round(sol["batch"]).astype(int)
                                    .reshape(M, P, K), 1), 0)
        gb = batch.sum(2)
        for m, (i, s) in enumerate(zip(idxs, scheds)):
            s._b_cache = float(B[m, -1])
            s._period += P
            k_m = ks[m]                          # slice back to the true K
            out[i] = PlanHorizon(
                batch=batch[m, :, :k_m],
                tau_up=sol["tau_up"].reshape(M, P, K)[m, :, :k_m],
                tau_down=sol["tau_down"].reshape(M, P, K)[m, :, :k_m],
                lr=np.array([lr_scale(s.base_lr, g, s.ref_batch)
                             for g in gb[m]], np.float64),
                latency=sol["latency"].reshape(M, P)[m],
                global_batch=gb[m].astype(np.int64))
    return out


# ---------------------------------------------------------------------------
# Per-device-parameter schemes (individual / model_fl): the latency ledger
# as a planner, not a hand-rolled Python loop in the trainer
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DevHorizon:
    """Pre-planned horizon for the per-device-parameter schemes — the same
    role :class:`PlanHorizon` plays for the FEEL schemes: everything the
    scan engine consumes, one array per field, leading period axis."""
    idx: np.ndarray              # (P, K, batch) int64 sample indices
    times: np.ndarray            # (P,) cumulative simulated seconds
    tau_up: np.ndarray           # (P, K) equal TDMA slots (scheme-defined)
    tau_down: np.ndarray         # (P, K)
    rates_up: np.ndarray         # (P, K)
    rates_down: np.ndarray       # (P, K)

    @property
    def periods(self) -> int:
        return self.idx.shape[0]


@dataclass
class DevScheduler:
    """Vectorized horizon planner for ``individual`` / ``model_fl``.

    Replaces the trainer's hand-rolled per-period ``_epoch_latency`` Python
    ledger: channel rates come from the same batched interleaved (up, down)
    draw the FEEL scheduler uses, and — fixing the PR-1 bug — the downlink
    subperiod is routed through the planner's ``tau_down``/``rates_down``
    path via ``latency.downlink_latency`` (eq. (11)) instead of a second
    ad-hoc ``uplink_latency`` call, so formula and rng stream match the
    FEEL scheme's planner.  eqs. (10) and (11) coincide numerically, so the
    fix is stream/formula hygiene: ledgers stay bit-identical to PR 1
    (test-covered).
    """
    devices: Sequence[DeviceProfile]
    parts: Sequence[np.ndarray]          # per-device index sets
    batch: int                           # fixed per-device batchsize
    payload_bits: float                  # model upload: d·p, uncompressed
    upload: bool                         # model_fl syncs; individual doesn't
    seed: int = 0
    cell: Optional[Cell] = None
    cell_cfg: CellConfig = field(default_factory=CellConfig)

    def __post_init__(self):
        if self.cell is None:
            self.cell = Cell.make(self.seed, self.cell_cfg)
        self.rng = np.random.default_rng(self.seed)
        self._dist_km = self.cell.drop_users(len(self.parts))

    def plan_horizon(self, periods: int,
                     time_offset: float = 0.0) -> DevHorizon:
        """``time_offset`` seeds the cumulative time axis (chunked
        horizons accumulate *from* the offset — the seeded cumsum is the
        only form bit-identical to the monolithic ledger; 0.0 degenerates
        to the plain cumsum bitwise)."""
        K = len(self.parts)
        c = self.cell.cfg
        idx = np.empty((periods, K, self.batch), np.int64)
        for p in range(periods):         # same rng order as the PR-1 loop
            idx[p] = np.stack(
                [self.rng.choice(part, size=self.batch,
                                 replace=len(part) < self.batch)
                 for part in self.parts])
        rates_up, rates_down = self.cell.avg_rate_updown_rows(
            self._dist_km, periods)
        # one local epoch per period: ⌈|D_k|/B⌉ minibatch steps
        t_local = np.array([
            d.local_grad_latency(self.batch) * max(1, len(part) // self.batch)
            for d, part in zip(self.devices, self.parts)])
        tau_u = np.full((periods, K), c.frame_up_s / K)
        tau_d = np.full((periods, K), c.frame_down_s / K)
        if self.upload:
            t_up = uplink_latency(self.payload_bits, tau_u, c.frame_up_s,
                                  rates_up)
            t_down = downlink_latency(self.payload_bits, tau_d,
                                      c.frame_down_s, rates_down)
            t_upd = np.array([d.update_latency() for d in self.devices])
            per_period = ((t_local + t_up).max(1)
                          + (t_down + t_upd).max(1))
        else:
            per_period = np.full(periods, t_local.max())
        times = np.cumsum(np.concatenate([[time_offset], per_period]))[1:]
        return DevHorizon(idx=idx, times=times,
                          tau_up=tau_u, tau_down=tau_d,
                          rates_up=rates_up, rates_down=rates_down)

"""Joint batchsize selection + communication resource allocation.

Implements the paper's optimal solution:

* Theorem 1 closed forms for ``B_k*`` and ``τ_k^U*`` (uplink subproblem 𝒫₂),
* Theorem 2 closed form for ``τ_k^D*`` (downlink subproblem 𝒫₃),
* Corollary 1 bounds on ``E^U*`` and Corollary 2 bounds on ``μ*``,
* Algorithm 1 two-dimensional bisection over ``(E^U*, μ*)``,
* the outer 1-D optimization over the global batchsize ``B``.

Unified affine latency ``t^L_k = a_k + b_k·B_k`` covers BOTH scenarios
(CPU: a=0, b=C^L/f; GPU compute-bound region per Lemma 2: a=t_ℓ−c·B_th,
b=c) — re-deriving the KKT system of Appendix A with the affine form gives

    λ_k* = ρ'_k/ΔL          with  ρ'_k = (1/b_k)/Σ_j(1/b_j)
    B_k*  = clip[(ΔL·E^U − a_k − sqrt(ΔL·s·T_f·μ/(ρ'_k·R_k))) / b_k]
    τ_k*  = (s/R_k) / (ΔL·E^U − a_k − b_k·B_k*) · T_f

which reduces exactly to the paper's Theorem 1 when a=0, b=1/V_k
(ρ' = ρ, the training-priority ratio).  This is the "similar structure"
claim of §V made executable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.latency import DeviceProfile, period_latency


@dataclass(frozen=True)
class UplinkSolution:
    batch: np.ndarray          # B_k*
    tau: np.ndarray            # τ_k^U*  (seconds of each frame)
    e_up: float                # E^U* = max_k (t^L+t^U)/ΔL  (reciprocal eff.)
    mu: float


@dataclass(frozen=True)
class DownlinkSolution:
    tau: np.ndarray
    e_down: float


@dataclass(frozen=True)
class PeriodSolution:
    global_batch: float
    batch: np.ndarray
    tau_up: np.ndarray
    tau_down: np.ndarray
    latency: float             # predicted T (s)
    efficiency: float          # predicted E = ΔL/T
    e_up: float
    e_down: float


def _affine(devices: Sequence[DeviceProfile]):
    ab = np.array([d.affine() for d in devices])
    return ab[:, 0], ab[:, 1]


def _rho_prime(b: np.ndarray) -> np.ndarray:
    inv = 1.0 / b
    return inv / inv.sum()


# ---------------------------------------------------------------------------
# Theorem 1 closed forms
# ---------------------------------------------------------------------------


def batch_closed_form(e_up, mu, devices, rates, s_bits, frame, dl,
                      b_max: int) -> np.ndarray:
    """Theorem 1, first line (affine-generalized)."""
    a, b = _affine(devices)
    rho = _rho_prime(b)
    lo = np.array([d.batch_lo() for d in devices], float)
    raw = (dl * e_up - a - np.sqrt(dl * s_bits * frame * mu / (rho * rates))) / b
    return np.clip(raw, lo, b_max)


def tau_closed_form(e_up, mu, devices, rates, s_bits, frame, dl,
                    b_max: int) -> np.ndarray:
    """Theorem 1, second line: slots making every device finish at ΔL·E^U."""
    a, b = _affine(devices)
    bt = batch_closed_form(e_up, mu, devices, rates, s_bits, frame, dl, b_max)
    denom = dl * e_up - a - b * bt
    return np.where(denom > 0,
                    s_bits / rates / np.maximum(denom, 1e-30) * frame,
                    np.inf)


# ---------------------------------------------------------------------------
# Corollary 1 / 2 bounds
# ---------------------------------------------------------------------------


def e_up_bounds(B, devices, rates, s_bits, frame, dl):
    """Corollary 1 (affine-generalized).

    Lower: infinite-memory KKT point.  Upper: equal-share allocation.
    """
    a, b = _affine(devices)
    K = len(devices)
    rho = _rho_prime(b)
    # lower bound: relax batch bounds; E = (Σ-weighted local + comm) / ΔL
    t_comp = (B / (1.0 / b).sum()) + float(np.dot(rho, a))
    t_comm = s_bits * (np.sqrt(rho / rates).sum()) ** 2
    lo = (t_comp + t_comm) / dl
    # upper bound: B_k = B/K, τ_k = T_f/K
    hi = np.max(a + b * (B / K) + K * s_bits / rates) / dl
    return max(lo, 1e-12), max(hi * 1.0000001, lo * 1.001)


def mu_bounds(e_up, devices, rates, s_bits, frame, dl, b_max):
    """Corollary 2 (affine-generalized)."""
    a, b = _affine(devices)
    rho = _rho_prime(b)
    lo_k = np.array([d.batch_lo() for d in devices], float)
    up = (dl * e_up - a - b * lo_k)
    dn = (dl * e_up - a - b * b_max)
    mu_hi = np.max(np.maximum(up, 0.0) ** 2 * rho * rates / (dl * s_bits * frame))
    mu_lo = np.min(np.maximum(dn, 0.0) ** 2 * rho * rates / (dl * s_bits * frame))
    return mu_lo, max(mu_hi, mu_lo + 1e-30)


# ---------------------------------------------------------------------------
# Algorithm 1: two-dimensional search
# ---------------------------------------------------------------------------


def solve_uplink(devices: Sequence[DeviceProfile], rates: np.ndarray,
                 s_bits: float, frame: float, B: float, dl: float,
                 b_max: int, tol: float = 1e-9,
                 iters: int = 200) -> UplinkSolution:
    """Subproblem 𝒫₂ for fixed global batch B (Algorithm 1).

    Inner bisection: μ ↦ ΣB_k(E,μ) is decreasing; find μ with ΣB_k = B.
    Outer bisection: E ↦ Στ_k(E, μ(E)) is decreasing; find E with Στ = T_f.
    """
    rates = np.asarray(rates, float)
    a, b = _affine(devices)

    def batches(e_up, mu):
        return batch_closed_form(e_up, mu, devices, rates, s_bits, frame, dl,
                                 b_max)

    def mu_for(e_up):
        m_lo, m_hi = mu_bounds(e_up, devices, rates, s_bits, frame, dl, b_max)
        m_lo = max(m_lo * 0.5, 0.0)
        m_hi = max(m_hi * 2.0, 1e-30)
        # ΣB_k decreasing in μ
        for _ in range(iters):
            m = 0.5 * (m_lo + m_hi)
            if batches(e_up, m).sum() > B:
                m_lo = m
            else:
                m_hi = m
            if m_hi - m_lo < tol * max(m_hi, 1.0):
                break
        return 0.5 * (m_lo + m_hi)

    def tau_sum(e_up):
        mu = mu_for(e_up)
        bt = batches(e_up, mu)
        denom = dl * e_up - a - b * bt
        tau = np.where(denom > 1e-30, s_bits / rates / denom * frame, np.inf)
        return tau.sum(), mu, bt, tau

    e_lo, e_hi = e_up_bounds(B, devices, rates, s_bits, frame, dl)
    # ensure bracketing: Στ(e_lo) >= T_f >= Στ(e_hi)
    for _ in range(60):
        if tau_sum(e_hi)[0] <= frame:
            break
        e_hi *= 2.0
    for _ in range(iters):
        e_m = 0.5 * (e_lo + e_hi)
        ts, mu, bt, tau = tau_sum(e_m)
        if ts >= frame:
            e_lo = e_m
        else:
            e_hi = e_m
        if (e_hi - e_lo) < tol * e_hi:
            break
    e_star = e_hi
    ts, mu, bt, tau = tau_sum(e_star)
    # normalize slots onto the frame (numerical slack)
    if np.isfinite(tau).all() and tau.sum() > 0:
        tau = tau * (frame / tau.sum())
    return UplinkSolution(batch=bt, tau=tau, e_up=float(e_star), mu=float(mu))


def solve_downlink(devices: Sequence[DeviceProfile], rates: np.ndarray,
                   s_bits: float, frame: float, dl: float,
                   tol: float = 1e-9, iters: int = 200) -> DownlinkSolution:
    """Subproblem 𝒫₃ / Theorem 2: τ_k^D = (s/R)/(ΔL·E^D − t^M) with Στ = T_f."""
    rates = np.asarray(rates, float)
    t_up = np.array([d.update_latency() for d in devices])

    def tau_sum(e_d):
        denom = dl * e_d - t_up
        tau = np.where(denom > 1e-30, s_bits / rates / denom * frame, np.inf)
        return tau, tau.sum()

    e_lo = float(np.max(t_up) / dl) * (1 + 1e-12)
    e_hi = float(np.max(t_up + len(devices) * s_bits / rates) / dl) + 1e-12
    while tau_sum(e_hi)[1] > frame:
        e_hi *= 2.0
    for _ in range(iters):
        e_m = 0.5 * (e_lo + e_hi)
        if tau_sum(e_m)[1] >= frame:
            e_lo = e_m
        else:
            e_hi = e_m
        if (e_hi - e_lo) < tol * e_hi:
            break
    tau, _ = tau_sum(e_hi)
    if np.isfinite(tau).all() and tau.sum() > 0:
        tau = tau * (frame / tau.sum())
    return DownlinkSolution(tau=tau, e_down=float(e_hi))


# ---------------------------------------------------------------------------
# Lockstep-vectorized solver over M independent periods ("rows")
#
# The scan-compiled trainer pre-plans whole horizons (scheduler.plan_horizon)
# and sweeps pre-plan many seeds; the per-period scalar bisections above then
# dominate wall-clock.  These _rows variants run the SAME Algorithm 1 /
# Theorem 2 bisections for M (rates, B) rows simultaneously as numpy array
# ops with fixed iteration counts — one period per row, no cross-row
# coupling, identical math up to bisection tolerance.
#
# Rows need not share a fleet: every ``devices`` argument below accepts
# either a plain ``DeviceProfile`` sequence (one fleet for all rows, all
# users active) or a :class:`FleetRows` — per-row padded device-parameter
# arrays plus an {0,1} active mask.  The bisections then run over active
# users only: padded columns get zero batchsize and zero slot (bandwidth)
# share and are excluded from every sum/max/min, so a masked row solves
# bit-identically to its compact K_m-user problem alone.  This is what
# lets ``plan_horizons_batch`` fuse Algorithm-1 planning across scenarios
# whose fleets differ in size or composition (the ragged-fleet bucket
# contract of ``repro.api``).
# ---------------------------------------------------------------------------


def _profile_cols(devices: Sequence[DeviceProfile]) -> np.ndarray:
    """(10, K) per-device parameter columns (see FleetRows field order)."""
    return np.array([[*d.affine(), d.batch_lo(), d.update_latency(),
                      1.0 if d.kind == "cpu" else 0.0,
                      d.cycles_per_sample, d.f_cpu,
                      d.gpu_t_low, d.gpu_slope, d.gpu_b_th]
                     for d in devices], float).T


@dataclass(frozen=True)
class FleetRows:
    """Per-row device-parameter arrays + active mask for the rows solver.

    Row ``m`` holds one period's fleet: its first ``k_m`` columns are the
    row's true devices; columns beyond are *padding* (cyclic copies of the
    row's own profiles, so every entry is a valid device) with ``mask``
    0.  Latency formulas are evaluated with exactly the arithmetic
    ``DeviceProfile`` uses (same operand order per element), and every
    reduction over the user axis is mask-aware, so a padded row's solution
    is bit-identical to solving its compact fleet alone, and an all-ones
    mask reproduces the shared-fleet solver verbatim (both test-enforced).
    """
    a: np.ndarray          # (M, K) affine intercepts  t^L = a + b·B
    b: np.ndarray          # (M, K) affine slopes
    lo: np.ndarray         # (M, K) batch lower bounds (1 / B_th)
    t_upd: np.ndarray      # (M, K) update latencies
    is_cpu: np.ndarray     # (M, K) bool — which latency branch applies
    cps: np.ndarray        # (M, K) CPU cycles per sample
    f_cpu: np.ndarray      # (M, K) CPU cycles/s
    g_t_low: np.ndarray    # (M, K) GPU t_l
    g_slope: np.ndarray    # (M, K) GPU c
    g_b_th: np.ndarray     # (M, K) GPU B_th
    mask: np.ndarray       # (M, K) {0,1} — 1 marks an active user row

    @classmethod
    def from_fleets(cls, fleets, k_pad: int | None = None) -> "FleetRows":
        """One row per fleet, padded (cyclic profiles, mask 0) to
        ``k_pad`` (default: the longest fleet)."""
        fleets = [tuple(f) for f in fleets]
        widest = max(len(f) for f in fleets)
        if k_pad is None:
            k_pad = widest
        elif k_pad < widest:
            raise ValueError(
                f"k_pad={k_pad} would truncate a {widest}-device fleet")
        mask = np.zeros((len(fleets), k_pad))
        cols = []
        for m, fleet in enumerate(fleets):
            padded = tuple(fleet[i % len(fleet)] for i in range(k_pad))
            cols.append(_profile_cols(padded))
            mask[m, :len(fleet)] = 1.0
        s = np.stack(cols)                        # (M, 10, K)
        return cls(a=s[:, 0], b=s[:, 1], lo=s[:, 2], t_upd=s[:, 3],
                   is_cpu=s[:, 4] > 0.5, cps=s[:, 5], f_cpu=s[:, 6],
                   g_t_low=s[:, 7], g_slope=s[:, 8], g_b_th=s[:, 9],
                   mask=mask)

    @classmethod
    def from_devices(cls, devices: Sequence[DeviceProfile],
                     m: int) -> "FleetRows":
        """One shared fleet broadcast to ``m`` rows, all users active."""
        c = _profile_cols(tuple(devices))
        bc = lambda r: np.broadcast_to(r, (m, c.shape[1]))       # noqa: E731
        return cls(a=bc(c[0]), b=bc(c[1]), lo=bc(c[2]), t_upd=bc(c[3]),
                   is_cpu=bc(c[4] > 0.5), cps=bc(c[5]), f_cpu=bc(c[6]),
                   g_t_low=bc(c[7]), g_slope=bc(c[8]), g_b_th=bc(c[9]),
                   mask=bc(np.ones(c.shape[1])))

    # ---- row bookkeeping --------------------------------------------------
    @property
    def rows(self) -> int:
        return self.a.shape[0]

    @property
    def active(self) -> np.ndarray:
        return self.mask > 0.5

    @property
    def k_active(self) -> np.ndarray:
        """(M,) active-user counts (float)."""
        return self.mask.sum(1)

    def _map(self, fn) -> "FleetRows":
        return FleetRows(**{f: fn(getattr(self, f)) for f in (
            "a", "b", "lo", "t_upd", "is_cpu", "cps", "f_cpu",
            "g_t_low", "g_slope", "g_b_th", "mask")})

    def repeat(self, c: int) -> "FleetRows":
        """Each row repeated ``c`` times consecutively (np.repeat)."""
        return self._map(lambda x: np.repeat(x, c, axis=0))

    def take(self, idx) -> "FleetRows":
        """Row subset (boolean or integer index along axis 0)."""
        return self._map(lambda x: np.asarray(x)[idx])

    def with_mask(self, mask: np.ndarray) -> "FleetRows":
        """Compose a further activity mask (per-round participation, cell
        membership) onto this one.  Multiplicative, so a participation
        mask can never resurrect a padded column, and an all-ones mask is
        a bitwise no-op (``mask * 1.0 == mask``)."""
        extra = np.broadcast_to(np.asarray(mask, float),
                                self.mask.shape)
        return FleetRows(**{f: getattr(self, f) for f in (
            "a", "b", "lo", "t_upd", "is_cpu", "cps", "f_cpu",
            "g_t_low", "g_slope", "g_b_th")}, mask=self.mask * extra)

    # ---- masked reductions / per-element latency --------------------------
    def mmax(self, x: np.ndarray) -> np.ndarray:
        return np.where(self.active, x, -np.inf).max(1)

    def mmin(self, x: np.ndarray) -> np.ndarray:
        return np.where(self.active, x, np.inf).min(1)

    def local_latency(self, batch_rows: np.ndarray) -> np.ndarray:
        """eq. (9) / (26) per element — bitwise the same arithmetic as
        ``DeviceProfile.local_grad_latency`` on each column."""
        batch = np.asarray(batch_rows, float)
        cpu = batch * self.cps / self.f_cpu
        gpu = np.where(batch <= self.g_b_th, self.g_t_low,
                       self.g_slope * (batch - self.g_b_th) + self.g_t_low)
        return np.where(self.is_cpu, cpu, gpu)


def as_fleet_rows(devices, m: int) -> FleetRows:
    """Normalize a ``devices`` argument: pass ``FleetRows`` through,
    broadcast a shared ``DeviceProfile`` sequence to ``m`` rows."""
    if isinstance(devices, FleetRows):
        if devices.rows != m:
            raise ValueError(
                f"FleetRows carries {devices.rows} rows, expected {m}")
        return devices
    return FleetRows.from_devices(devices, m)


def _ssum(x: np.ndarray) -> np.ndarray:
    """Strictly sequential row sum (cumsum), NOT ``np.sum``.

    numpy's pairwise summation changes its association at n = 8 (the
    8-accumulator unroll), so summing a zero-padded row would not be
    bit-equal to summing its compact prefix.  Sequential accumulation is
    invariant to trailing zeros (x + 0.0 == x), which is what makes the
    masked solver bit-identical to per-fleet compact solves — every row
    reduction feeding a bisection branch below must go through this."""
    return np.cumsum(x, axis=1)[:, -1]


def solve_uplink_rows(devices, rates: np.ndarray,
                      s_bits: float, frame: float, B: np.ndarray,
                      dl: np.ndarray, b_max: int, *, inner_iters: int = 42,
                      outer_iters: int = 42, expand_iters: int = 14):
    """Subproblem 𝒫₂ for M rows at once.  rates: (M,K); B, dl: (M,).

    ``devices``: a shared ``DeviceProfile`` sequence or per-row padded
    :class:`FleetRows` — masked columns get zero batchsize and zero slot
    share, and the bisection runs over active users only.

    Returns (batch (M,K), tau (M,K), e_up (M,), mu (M,)).
    """
    rates = np.asarray(rates, float)
    B = np.asarray(B, float)
    dl = np.asarray(dl, float)
    M, K = rates.shape
    fr = as_fleet_rows(devices, M)
    act = fr.active
    a, b, lo_k, ka = fr.a, fr.b, fr.lo, fr.k_active
    inv = np.where(act, 1.0 / b, 0.0)
    rho = inv / _ssum(inv)[:, None]
    # padded columns have rho = 0 exactly; guard their division
    rr = np.where(act, rho * rates, 1.0)
    dle = dl[:, None]

    def batches(e, mu):
        raw = (dle * e[:, None] - a
               - np.sqrt(dle * s_bits * frame * mu[:, None] / rr)) / b
        return np.where(act, np.clip(raw, lo_k, b_max), 0.0)

    def mu_for(e):
        # Corollary 2 bounds, then bisect ΣB_k(μ) = B (decreasing in μ)
        up = dle * e[:, None] - a - b * lo_k
        dn = dle * e[:, None] - a - b * b_max
        scale = rho * rates / (dle * s_bits * frame)
        m_hi = fr.mmax(np.maximum(up, 0.0) ** 2 * scale)
        m_lo = fr.mmin(np.maximum(dn, 0.0) ** 2 * scale)
        m_lo = np.maximum(m_lo * 0.5, 0.0)
        m_hi = np.maximum(m_hi * 2.0, 1e-30)
        for _ in range(inner_iters):
            m = 0.5 * (m_lo + m_hi)
            over = _ssum(batches(e, m)) > B
            m_lo = np.where(over, m, m_lo)
            m_hi = np.where(over, m_hi, m)
        return 0.5 * (m_lo + m_hi)

    def tau_sum(e):
        mu = mu_for(e)
        bt = batches(e, mu)
        denom = dle * e[:, None] - a - b * bt
        tau = np.where(denom > 1e-30,
                       s_bits / rates / np.maximum(denom, 1e-30) * frame,
                       np.inf)
        tau = np.where(act, tau, 0.0)
        return _ssum(tau), mu, bt, tau

    # Corollary 1 bounds + bracket expansion (active users only: the
    # rho/inv factors of padded columns are exactly zero)
    t_comp = B / _ssum(inv) + _ssum(rho * a)
    t_comm = s_bits * (_ssum(np.sqrt(np.where(act, rho / rates, 0.0)))) ** 2
    e_lo = np.maximum((t_comp + t_comm) / dl, 1e-12)
    hi = fr.mmax(a + b * (B[:, None] / ka[:, None])
                 + ka[:, None] * s_bits / rates) / dl
    e_hi = np.maximum(hi * 1.0000001, e_lo * 1.001)
    for _ in range(expand_iters):
        grow = tau_sum(e_hi)[0] > frame
        if not grow.any():
            break
        e_hi = np.where(grow, e_hi * 2.0, e_hi)
    # Στ(E) decreasing: find E with Στ = T_f
    for _ in range(outer_iters):
        e_m = 0.5 * (e_lo + e_hi)
        geq = tau_sum(e_m)[0] >= frame
        e_lo = np.where(geq, e_m, e_lo)
        e_hi = np.where(geq, e_hi, e_m)
    e_star = e_hi
    _, mu, bt, tau = tau_sum(e_star)
    tsum = _ssum(tau)[:, None]
    ok = np.isfinite(tau).all(1, keepdims=True) & (tsum > 0)
    tau = np.where(ok, tau * (frame / np.where(tsum > 0, tsum, 1.0)), tau)
    return bt, tau, e_star, mu


def solve_downlink_rows(devices, rates: np.ndarray,
                        s_bits: float, frame: float, dl: np.ndarray, *,
                        iters: int = 42, expand_iters: int = 14):
    """Theorem 2 for M rows at once (``devices`` as in
    :func:`solve_uplink_rows`).  Returns (tau (M,K), e_down (M,))."""
    rates = np.asarray(rates, float)
    dl = np.asarray(dl, float)
    M = rates.shape[0]
    fr = as_fleet_rows(devices, M)
    act, t_upd, ka = fr.active, fr.t_upd, fr.k_active

    def tau_of(e):
        denom = dl[:, None] * e[:, None] - t_upd
        tau = np.where(denom > 1e-30,
                       s_bits / rates / np.maximum(denom, 1e-30) * frame,
                       np.inf)
        return np.where(act, tau, 0.0)

    e_lo = fr.mmax(t_upd) / dl * (1 + 1e-12)
    e_hi = fr.mmax(t_upd + ka[:, None] * s_bits / rates) / dl + 1e-12
    for _ in range(expand_iters):
        grow = _ssum(tau_of(e_hi)) > frame
        if not grow.any():
            break
        e_hi = np.where(grow, e_hi * 2.0, e_hi)
    for _ in range(iters):
        e_m = 0.5 * (e_lo + e_hi)
        geq = _ssum(tau_of(e_m)) >= frame
        e_lo = np.where(geq, e_m, e_lo)
        e_hi = np.where(geq, e_hi, e_m)
    tau = tau_of(e_hi)
    tsum = _ssum(tau)[:, None]
    ok = np.isfinite(tau).all(1, keepdims=True) & (tsum > 0)
    tau = np.where(ok, tau * (frame / np.where(tsum > 0, tsum, 1.0)), tau)
    return tau, e_hi


def fixed_slot_rows(devices, batch_rows: np.ndarray,
                    rates_up: np.ndarray, rates_down: np.ndarray,
                    s_bits: float, frame_up: float, frame_down: float):
    """Vectorized equal-TDMA-slot policy evaluation for M rows at once.

    The allocation-unaware baselines (online / full / random batchsize) all
    share τ_k = T_f/K; this evaluates their per-period latency ledger for a
    whole horizon in one shot — the rows analog of
    ``baselines._fixed_batch_policy``, bit-identical per row.  ``devices``
    as in :func:`solve_uplink_rows`: with :class:`FleetRows`, K is the
    per-row active count, padded columns get zero slots and stay out of
    the latency barriers.  Returns (tau_up (M,K), tau_down (M,K),
    latency (M,)).
    """
    from repro.core.latency import downlink_latency, uplink_latency
    batch_rows = np.asarray(batch_rows, float)
    fr = as_fleet_rows(devices, batch_rows.shape[0])
    act, ka = fr.active, fr.k_active
    t_local = fr.local_latency(batch_rows)
    tau_u = np.where(act, frame_up / ka[:, None], 0.0)
    tau_d = np.where(act, frame_down / ka[:, None], 0.0)
    t_up = uplink_latency(s_bits, tau_u, frame_up, rates_up)
    t_down = downlink_latency(s_bits, tau_d, frame_down, rates_down)
    latency = fr.mmax(t_local + t_up) + fr.mmax(t_down + fr.t_upd)
    return tau_u, tau_d, latency


def solve_period_rows(devices,
                      rates_up: np.ndarray, rates_down: np.ndarray,
                      s_bits: float, frame_up: float, frame_down: float,
                      xi, B: np.ndarray, b_max: int) -> dict:
    """Vectorized 𝒫₁ inner evaluation: uplink + downlink solutions and the
    predicted eq. (14) latency for M independent periods with given B.

    ``xi`` may be a scalar or an (M,) array (per-row ξ — one row per
    scenario × period when horizons for many scenarios are planned in one
    lockstep call); ``devices`` as in :func:`solve_uplink_rows` — a
    :class:`FleetRows` makes every row's allocation a function of its own
    active users only (padded columns: zero batch, zero τ, outside the
    latency barriers)."""
    B = np.asarray(B, float)
    dl = np.asarray(xi, float) * np.sqrt(B)
    fr = as_fleet_rows(devices, rates_up.shape[0])
    bt, tau_u, e_up, _ = solve_uplink_rows(fr, rates_up, s_bits,
                                           frame_up, B, dl, b_max)
    tau_d, e_down = solve_downlink_rows(fr, rates_down, s_bits,
                                        frame_down, dl)
    t_local = fr.local_latency(bt)
    t_up = s_bits * frame_up / (np.maximum(tau_u, 1e-30) * rates_up)
    t_down = s_bits * frame_down / (np.maximum(tau_d, 1e-30) * rates_down)
    latency = fr.mmax(t_local + t_up) + fr.mmax(t_down + fr.t_upd)
    return {"batch": bt, "tau_up": tau_u, "tau_down": tau_d,
            "latency": latency, "e_total": e_up + e_down}


def optimize_batch_rows(devices,
                        rates_up: np.ndarray, rates_down: np.ndarray,
                        s_bits: float, frame_up: float, frame_down: float,
                        xi, b_max: int,
                        n_candidates: int = 97,
                        b_prev=None, dl_cap=None,
                        energy=None) -> np.ndarray:
    """Outer 𝒫₁ for M rows at once: integer-grid argmin of E^U*+E^D* over B
    (the golden-section's job, but every row and every candidate evaluated
    in one lockstep solve; B is rounded to an integer downstream anyway).

    ``xi``: scalar or (M,) per-row ξ (see :func:`solve_period_rows`).
    With per-row :class:`FleetRows` the candidate grid is per row (its lo
    and hi bounds scale with the row's active users); rows with narrower
    grids repeat their last candidate so the lockstep solve stays
    rectangular — a repeated candidate ties its original and argmin keeps
    the first, so padding never changes a row's argmin.

    ``b_prev`` (optional (M,) array, NaN = no hint) warm-starts a row's
    grid from a previous solution: the candidates span
    ``[b_prev/2, 2·b_prev]`` (clipped to the row's feasible range, falling
    back to the full range when the hint is stale/outside it) — chunked
    closed-loop re-planning pairs this with a reduced ``n_candidates``
    because B* moves slowly between consecutive chunks.

    ``dl_cap`` (optional (M,) array, NaN/inf = uncapped) caps the loss
    decay credited to a candidate: the selection objective becomes
    T_pred(B)/min(ξ√B, cap) instead of T_pred(B)/(ξ√B).  A scalar ξ
    cancels from the uncapped argmin (see
    :class:`repro.core.efficiency.XiEstimator`), so the cap is the term
    that makes closed-loop feedback decision-relevant: candidates whose
    √B extrapolation out-promises realized decay stop being credited and
    B* falls back to the knee (cap/ξ)².  Only the argmin changes — the
    per-B allocation (Theorem 1/2) is ΔL-scale-invariant and stays
    exactly the paper's.

    ``energy`` (optional, duck-typed ``budget_j``/``comp_w``/``tx_w`` —
    a :class:`repro.dynamics.EnergyBudget`) discounts candidates the
    fleet cannot afford: each candidate's allocation is clipped to the
    per-user affordable batch (the affine local-latency model inverted
    against the residual budget after the uplink spend) and the
    objective is re-denominated by √(ΣB/ΣB_affordable), so a candidate
    only gets √B credit for the batch its users can actually power.  An
    unbinding budget leaves every objective multiplied by exactly 1.0 —
    the static argmin is the bitwise special case."""
    M = rates_up.shape[0]
    fr = as_fleet_rows(devices, M)
    lo_rows = _ssum(np.where(fr.active, fr.lo, 0.0))
    hi_rows = fr.k_active * b_max
    if b_prev is not None:
        hint = np.broadcast_to(np.asarray(b_prev, float), (M,))
        ok = np.isfinite(hint) & (hint >= lo_rows) & (hint <= hi_rows)
        lo_rows = np.where(ok, np.maximum(lo_rows, hint / 2.0), lo_rows)
        hi_rows = np.where(ok, np.minimum(hi_rows, hint * 2.0), hi_rows)
    per_row = [np.unique(np.round(np.linspace(lo_rows[m], hi_rows[m],
                                              n_candidates)))
               for m in range(M)]
    C = max(len(c) for c in per_row)
    cand = np.stack([np.concatenate([c, np.full(C - len(c), c[-1])])
                     for c in per_row])           # (M, C)
    xi_rows = np.broadcast_to(np.asarray(xi, float), (M,))
    rup_c = np.repeat(rates_up, C, axis=0)
    frc = fr.repeat(C)
    sol = solve_period_rows(
        frc, rup_c,
        np.repeat(rates_down, C, axis=0), s_bits, frame_up, frame_down,
        np.repeat(xi_rows, C), cand.reshape(-1), b_max)
    obj = sol["e_total"].reshape(M, C)
    if energy is not None:
        t_up = s_bits * frame_up / (np.maximum(sol["tau_up"], 1e-30)
                                    * rup_c)
        residual = (energy.budget_j - energy.tx_w * t_up
                    - energy.comp_w * frc.a)
        with np.errstate(divide="ignore", invalid="ignore"):
            cap = np.where(energy.comp_w * frc.b > 0,
                           residual / np.maximum(energy.comp_w * frc.b,
                                                 1e-30),
                           np.where(residual >= 0, np.inf, -np.inf))
        cap = np.clip(cap, 0.0, float(b_max))
        b_all = _ssum(np.where(frc.active, sol["batch"], 0.0))
        b_aff = _ssum(np.where(frc.active,
                               np.minimum(sol["batch"], cap), 0.0))
        factor = np.sqrt(b_all / np.maximum(b_aff, 1e-30))
        obj = obj * factor.reshape(M, C)
    if dl_cap is not None:
        cap = np.broadcast_to(np.asarray(dl_cap, float), (M,))[:, None]
        cap = np.where(np.isfinite(cap) & (cap > 0), cap, np.inf)
        # e_total = T_pred/ΔL with ΔL = ξ√B; re-denominate by the capped
        # decay so over-promising candidates stop looking efficient
        dl = xi_rows[:, None] * np.sqrt(cand)
        obj = obj * dl / np.minimum(dl, cap)
    best = np.argmin(obj, axis=1)
    return cand[np.arange(M), best]


# ---------------------------------------------------------------------------
# Outer problem: optimize the global batchsize B (𝒫₁)
# ---------------------------------------------------------------------------


def solve_period(devices: Sequence[DeviceProfile],
                 rates_up: np.ndarray, rates_down: np.ndarray,
                 s_bits: float, frame_up: float, frame_down: float,
                 xi: float, b_max: int,
                 B: Optional[float] = None) -> PeriodSolution:
    """Full 𝒫₁: golden-section over B of  E^U*(B) + E^D*(B)  (= T/ΔL)."""
    K = len(devices)

    def objective(Bv):
        dl = xi * np.sqrt(Bv)
        up = solve_uplink(devices, rates_up, s_bits, frame_up, Bv, dl, b_max)
        down = solve_downlink(devices, rates_down, s_bits, frame_down, dl)
        return up.e_up + down.e_down, up, down

    if B is None:
        lo = float(sum(d.batch_lo() for d in devices))
        hi = float(K * b_max)
        phi = (np.sqrt(5) - 1) / 2
        x1 = hi - phi * (hi - lo)
        x2 = lo + phi * (hi - lo)
        f1, f2 = objective(x1)[0], objective(x2)[0]
        for _ in range(60):
            if f1 <= f2:
                hi, x2, f2 = x2, x1, f1
                x1 = hi - phi * (hi - lo)
                f1 = objective(x1)[0]
            else:
                lo, x1, f1 = x1, x2, f2
                x2 = lo + phi * (hi - lo)
                f2 = objective(x2)[0]
            if hi - lo < 1.0:
                break
        B = round(0.5 * (lo + hi))

    total, up, down = objective(float(B))
    dl = xi * np.sqrt(B)
    # predicted wall latency: both subperiods at their equalized finish times
    t_local = np.array([d.local_grad_latency(bk) for d, bk
                        in zip(devices, up.batch)])
    t_up = s_bits * frame_up / (np.maximum(up.tau, 1e-30) * rates_up)
    t_upd = np.array([d.update_latency() for d in devices])
    t_down = s_bits * frame_down / (np.maximum(down.tau, 1e-30) * rates_down)
    T = period_latency(t_local, t_up, t_down, t_upd)
    return PeriodSolution(
        global_batch=float(B), batch=up.batch, tau_up=up.tau,
        tau_down=down.tau, latency=T,
        efficiency=float(dl / T) if T > 0 else 0.0,
        e_up=up.e_up, e_down=down.e_down)

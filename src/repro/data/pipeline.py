"""Federated data pipeline (paper §VI-A partitioning, synthetic sources).

Two synthetic sources (CIFAR-10 is unavailable offline — DESIGN.md §9):
  * ``ClassificationData`` — 10 Gaussian class clusters in 3072-dim space
    (32x32x3 stand-in) for the paper-scale FEEL experiments.
  * ``TokenData`` — teacher-bigram token streams for transformer training.

Partitioning:
  * IID: shuffle, split into K equal parts.
  * non-IID (pathological, paper §VI-A): sort by label, cut into 2K shards,
    give each device 2 shards (most devices see only 2 classes).

``FederatedBatcher`` realizes the paper's per-device batchsize B_k under
SPMD static shapes: each device group owns ``slot`` examples of the global
batch; a plan with B_k < slot masks the surplus via per-example weights
(eq. (1) weighting is exactly reproduced — test-covered).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass
class ClassificationData:
    x: np.ndarray          # (N, D) float32
    y: np.ndarray          # (N,) int32

    @classmethod
    def synthetic(cls, n: int = 12_000, dim: int = 3072, classes: int = 10,
                  seed: int = 0, spread: float = 4.0):
        rng = np.random.default_rng(seed)
        centers = rng.normal(size=(classes, dim)).astype(np.float32) * spread / np.sqrt(dim)
        y = rng.integers(0, classes, size=n).astype(np.int32)
        x = centers[y] + rng.normal(size=(n, dim)).astype(np.float32)
        return cls(x=x, y=y)

    def split(self, n_test: int):
        """Held-out split sharing the same class centers."""
        tr = ClassificationData(self.x[:-n_test], self.y[:-n_test])
        te = ClassificationData(self.x[-n_test:], self.y[-n_test:])
        return tr, te


@dataclass
class TokenData:
    tokens: np.ndarray     # (N, S+1) int32 — input/target windows

    @classmethod
    def synthetic(cls, n: int = 4096, seq: int = 64, vocab: int = 512,
                  seed: int = 0):
        """Markov-chain text: learnable structure, nontrivial loss floor."""
        rng = np.random.default_rng(seed)
        # sparse row-stochastic transition matrix
        trans = rng.dirichlet(np.ones(32), size=vocab)
        nxt = rng.integers(0, vocab, size=(vocab, 32))
        t = np.empty((n, seq + 1), np.int64)
        t[:, 0] = rng.integers(0, vocab, size=n)
        for s in range(seq):
            choice = np.array([rng.choice(32, p=trans[v]) for v in t[:, s]])
            t[:, s + 1] = nxt[t[:, s], choice]
        return cls(tokens=t.astype(np.int32))


def partition_iid(n: int, k: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(idx, k)]


def partition_noniid(labels: np.ndarray, k: int, shards_per_device: int = 2,
                     seed: int = 0) -> List[np.ndarray]:
    """Paper §VI-A: sort by label, 2K shards, 2 shards per device."""
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, k * shards_per_device)
    assign = rng.permutation(k * shards_per_device)
    return [np.sort(np.concatenate([shards[assign[i * shards_per_device + j]]
                                    for j in range(shards_per_device)]))
            for i in range(k)]


@dataclass
class FederatedBatcher:
    """Fixed-slot batches with per-example weights realizing B_k."""
    parts: List[np.ndarray]       # per-device index sets
    slot: int                     # max examples per device per period (B^max)
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    @property
    def k(self) -> int:
        return len(self.parts)

    def sample(self, batch_per_device: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (indices (K, slot), weights (K, slot)).

        weights[k, i] = 1 for i < B_k else 0; weighted-mean with these
        weights over the flattened batch equals eq. (1)'s
        (1/ΣB_k)·Σ_k B_k·mean-grad_k.
        """
        idx = np.zeros((self.k, self.slot), np.int64)
        w = np.zeros((self.k, self.slot), np.float32)
        for k, part in enumerate(self.parts):
            bk = int(min(batch_per_device[k], self.slot))
            take = self.rng.choice(part, size=self.slot,
                                   replace=len(part) < self.slot)
            idx[k] = take
            w[k, :bk] = 1.0
        return idx, w

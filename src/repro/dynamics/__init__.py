"""Scenario dynamics: the time-varying world (PR 9).

Everything before this package assumed the paper's static premise — one
channel realization, one fleet, one τ for the whole horizon — which made
the closed-loop ``replan=R`` machinery provably decision-invariant
(PR 5's ξ-scale-invariance).  The four processes here break that premise
one axis at a time, each as an optional frozen ``ScenarioSpec`` field:

* :class:`Fading` / :class:`FadingProcess` — a seeded block-fading
  Markov chain over a per-user gain ladder that *drifts* the average
  rates between chunks, so re-planning finally changes allocations;
* :class:`Faults` / :class:`FaultProcess` — straggler slowdowns (scale
  per-user computation latency in the ledger) and mid-horizon dropout
  (another time-varying participation mask, composed multiplicatively
  with PR-8 sampling through the same ``active`` machinery);
* :class:`EnergyBudget` — per-user per-period energy caps folded into
  the Algorithm-1 batch search (infeasible users shed load or drop) and
  a realized energy-spend ledger column;
* :class:`TauAdapt` — local steps τ as a re-planned knob next to
  batchsize (Wang et al. 1804.05271's adaptive-τ view).

Stream discipline: fading and faults own dedicated rng streams derived
from ``(scenario_seed, spec.seed, tag)`` with tags ``0xFAD1`` / ``0xFA17``
— disjoint from the channel Monte-Carlo (``Cell.make(seed)``), scheduler
(``seed + 1``), batcher (``seed``) and participation (``0x5A17``)
streams — and consume a FIXED number of variates per planned period, so
(a) adding dynamics never perturbs any pre-existing draw and (b) chunked
planning equals monolithic planning stream-for-stream.  The static world
stays the bitwise special case: identity parameters (``spread=0``, zero
fault probabilities, an unreachable budget) multiply by exactly 1.0 /
clip at +inf and reproduce pre-dynamics runs bit-for-bit (test-enforced).
"""
from repro.dynamics.energy import EnergyBudget, energy_spend, uplink_airtime
from repro.dynamics.fading import Fading, FadingProcess
from repro.dynamics.faults import Faults, FaultProcess
from repro.dynamics.tau import TauAdapt

__all__ = [
    "EnergyBudget", "Fading", "FadingProcess", "Faults", "FaultProcess",
    "TauAdapt", "energy_spend", "uplink_airtime",
]

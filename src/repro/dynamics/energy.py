"""Per-user energy budgets (Mo & Xu, 2003.00199's joint comm+comp model).

A period costs user k

    E_k = comp_w · t_local(B_k) + tx_w · t_up(τ_k)            [J]

— compute power against the affine local-latency model plus radio power
against the uplink airtime.  ``EnergyBudget`` caps E_k per period:

* the Algorithm-1 batch search discounts candidate global batchsizes
  whose per-user shares the fleet cannot afford
  (``optimize_batch_rows(energy=...)``);
* after the per-period solve, users are clipped to their affordable
  batch (``B <= cap``); a user that cannot afford even its minimum
  batch **drops** for the period — one more participation mask through
  the same active machinery as sampling/dropout.  If every active user
  would drop, nobody does (the budget degrades to a soft floor at the
  minimum batch for that period — starving the round entirely would
  divide by zero in the aggregation, and a zero-progress period helps
  no one);
* realized spend (at realized rates and straggler slowdowns) lands in
  the ``energy`` ledger column next to latency.

An unreachable budget (the default ``inf``) is the bitwise identity:
caps are +inf, ``min(B, inf) == B``, no one drops, and the candidate
discount is exactly 1.0.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["EnergyBudget", "batch_caps", "energy_spend", "uplink_airtime"]


@dataclass(frozen=True)
class EnergyBudget:
    """Frozen spec-side value (``ScenarioSpec.energy``).  Value-only for
    bucketing: budgeted and unbudgeted scenarios share one program (the
    budget reaches the device only through schedule values and masks)."""
    budget_j: float = float("inf")   # per-user per-period budget (J)
    comp_w: float = 1.0              # compute power draw (W)
    tx_w: float = 1.0                # radio power draw (W)

    def __post_init__(self):
        if not self.budget_j > 0.0:
            raise ValueError(
                f"budget_j must be positive, got {self.budget_j!r}")
        if not (self.comp_w >= 0.0 and self.tx_w >= 0.0):
            raise ValueError(
                f"power draws must be >= 0, got comp_w={self.comp_w!r} "
                f"tx_w={self.tx_w!r}")
        if self.comp_w == 0.0 and self.tx_w == 0.0:
            raise ValueError("at least one of comp_w/tx_w must be positive")

    def __str__(self) -> str:  # readable grid-axis coordinate
        return f"E{self.budget_j:g}J@{self.comp_w:g}/{self.tx_w:g}"


def uplink_airtime(tau_up, rates_up, s_bits: float, frame_up: float):
    """Per-user uplink airtime s·T_f^U / (τ·R) — the solver's pricing,
    shared here so planning, capping and the realized ledger all use one
    formula (bitwise: identical operand order)."""
    return s_bits * frame_up / (np.maximum(tau_up, 1e-30) * rates_up)


def batch_caps(energy: EnergyBudget, fr, tau_up, rates_up,
               s_bits: float, frame_up: float) -> np.ndarray:
    """Largest affordable batch per user-period under ``energy``.

    Inverts the affine local-latency model against the residual budget
    after the (planned) uplink spend: B_cap = (E − tx·t_up − comp·a) /
    (comp·b).  ``fr`` is a ``FleetRows`` (duck-typed: only the affine
    coefficient arrays ``a``/``b`` are read, so this module never
    imports the solver).  Rows with ``comp_w == 0`` are uncapped by
    compute (+inf unless the radio alone busts the budget)."""
    t_up = uplink_airtime(tau_up, rates_up, s_bits, frame_up)
    residual = energy.budget_j - energy.tx_w * t_up - energy.comp_w * fr.a
    denom = energy.comp_w * fr.b
    with np.errstate(divide="ignore", invalid="ignore"):
        cap = np.where(denom > 0, residual / np.maximum(denom, 1e-30),
                       np.where(residual >= 0, np.inf, -np.inf))
    return cap


def energy_spend(energy: EnergyBudget, t_local, t_up) -> np.ndarray:
    """Realized per-user-period spend (the ledger column): compute power
    against the (slowdown-scaled) local latency plus radio power against
    the realized uplink airtime."""
    return energy.comp_w * np.asarray(t_local) + \
        energy.tx_w * np.asarray(t_up)

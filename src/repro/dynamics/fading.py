"""Block-fading Markov channel drift.

The paper's planner consumes *average* rates (eqs. 5-6) — a single
Monte-Carlo expectation per period over fast Rayleigh fading.  Real
channels also drift on a slower timescale (shadowing, mobility): this
module models that as a per-user Markov chain over a discrete gain
ladder, block-constant within a period, multiplying the rates returned
by ``Cell.avg_rate_updown_rows``.  The Monte-Carlo stream itself is
never touched — drift composes *on top of* the fast-fading expectation,
so a ``Fading`` spec leaves every existing channel draw bit-identical.

Planner belief vs realized state
--------------------------------
``FadingProcess.draw`` realizes the per-period gains; what the planner
is *allowed to know* depends on the loop:

* open loop plans every period with the horizon's FIRST realized gain
  (``g0``) — the paper's static assumption, stale from period 2 on (and
  independent of chunking, which keeps open-loop chunked == monolithic
  bit-identical);
* closed loop (``replan=R``) re-reads the chain at each chunk start
  (``latest0``), so re-planned allocations track the drift.  On the
  first chunk ``latest0 == g0`` — open and closed loop agree until
  feedback exists, and divergence is purely the re-plan's doing.

Realized per-period gains always drive the *ledger*: after the solve,
the scheduler re-prices each period's uplink/downlink at the realized
rates, so stale open-loop allocations pay their true latency.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Fading", "FadingProcess"]

# rng stream tag: disjoint from sampling (0x5A17) and faults (0xFA17)
_STREAM_TAG = 0xFAD1


@dataclass(frozen=True)
class Fading:
    """Frozen spec-side value (``ScenarioSpec.fading``).

    ``states`` is structural (``bucket_key``): it fixes the gain-ladder
    resolution the chain walks — scenarios with different ladders are
    different program families for the auditor even though the gains
    only reach the device program as schedule *values*.  ``spread`` sets
    the ladder's log-amplitude (``spread=0`` is the bitwise identity:
    every gain is exactly 1.0), ``stickiness`` the per-period
    probability of holding the current state."""
    states: int = 3
    spread: float = 0.6
    stickiness: float = 0.9
    seed: int = 0

    def __post_init__(self):
        if not isinstance(self.states, int) or isinstance(self.states, bool) \
                or self.states < 1:
            raise ValueError(
                f"fading states must be a positive int, got {self.states!r}")
        if not self.spread >= 0.0:
            raise ValueError(
                f"fading spread must be >= 0, got {self.spread!r}")
        if not 0.0 <= self.stickiness < 1.0:
            raise ValueError(
                "fading stickiness must be in [0, 1) (a chain that never "
                f"moves is the static world), got {self.stickiness!r}")

    def gain_ladder(self) -> np.ndarray:
        """Symmetric log-space ladder centered on gain 1.0.

        ``exp(spread * z)`` with ``z`` uniform on [-1, 1]; a one-state
        ladder (or ``spread=0``) is exactly ``1.0`` everywhere, which is
        what makes the static world the bitwise special case
        (``rate * 1.0`` is the identity in IEEE-754)."""
        if self.states == 1:
            z = np.zeros(1)
        else:
            z = np.linspace(-1.0, 1.0, self.states)
        return np.exp(self.spread * z)

    def __str__(self) -> str:  # readable grid-axis coordinate
        return (f"F{self.states}x{self.spread:g}"
                f"p{self.stickiness:g}@{self.seed}")


class FadingProcess:
    """Seeded per-user Markov gain stream for one scenario row.

    ``draw(periods)`` consumes exactly one ``(K,)`` uniform block per
    period — the same count whatever the chain does — so the stream
    position depends only on how many periods were planned: chunked
    horizons realize the same gains as monolithic ones, and the stream
    is disjoint-by-construction from every other draw in the repo."""

    def __init__(self, fading: Fading, k: int, seed: int):
        self.fading = fading
        self.k = k
        self.rng = np.random.default_rng((seed, fading.seed, _STREAM_TAG))
        self._ladder = fading.gain_ladder()
        self._state = None      # (K,) current chain state
        self._g0 = None         # first-ever period's gains (open-loop belief)
        self._latest0 = None    # first period of the latest draw (closed loop)

    def draw(self, periods: int) -> np.ndarray:
        """Realize ``(periods, K)`` multiplicative gains, advancing the
        chain; consecutive calls continue where the last one stopped."""
        n = self.fading.states
        stick = self.fading.stickiness
        states = np.zeros((periods, self.k), np.int64)
        for p in range(periods):
            u = self.rng.uniform(size=self.k)   # ONE block per period
            if self._state is None:
                # initial state from the same uniform block
                s = np.minimum((u * n).astype(np.int64), n - 1)
            else:
                # sticky chain: hold w.p. `stickiness`, else step +/-1
                # (reflecting at the ladder ends); the move direction
                # re-uses the residual uniform mass so the consumption
                # stays one block per period
                v = (u - stick) / (1.0 - stick)
                step = np.where(v < 0.5, -1, 1)
                s = np.where(u < stick, self._state,
                             np.clip(self._state + step, 0, n - 1))
            self._state = s
            states[p] = s
        gains = self._ladder[states]
        if self._g0 is None:
            self._g0 = gains[0].copy()
        self._latest0 = gains[0].copy()
        return gains

    def planning_gain(self, closed_loop: bool) -> np.ndarray:
        """The (K,) belief the planner may price rates with — ``g0``
        open loop, the current chunk's first realized gain closed loop.
        Only valid after :meth:`draw`."""
        assert self._latest0 is not None, "planning_gain before draw"
        return self._latest0 if closed_loop else self._g0

"""Straggler and dropout fault injection (Prakash et al., 2111.00637).

Two per-user-per-period Bernoulli processes on one dedicated stream:

* **stragglers** — with ``slow_prob`` a user's computation runs
  ``slow_factor`` times slower that period.  Slowdowns are a *ledger*
  effect: they scale the per-user local-computation latency that prices
  the period (and the (τ-1)-step compute add in ``build_schedule``),
  exactly where a delayed device hurts a synchronous round;
* **dropout** — with ``drop_prob`` a user vanishes for the period.
  Dropout is deliberately NOT new machinery: it is one more {0,1}
  participation mask composed multiplicatively with PR-8 sampling
  through the same time-varying ``active`` path (mask ∧ mask), so the
  engine, the masked rows solver and the auditor's mask-domination
  proofs all apply unchanged.

The draw consumes exactly ``2K`` uniforms per period whatever the
probabilities realize — zero-probability faults are the bitwise
identity (slowdown 1.0, keep-mask all ones) and chunked draws equal
monolithic ones.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

__all__ = ["Faults", "FaultProcess"]

# rng stream tag: disjoint from sampling (0x5A17) and fading (0xFAD1)
_STREAM_TAG = 0xFA17


@dataclass(frozen=True)
class Faults:
    """Frozen spec-side value (``ScenarioSpec.faults``).  Value-only for
    bucketing: faulty and clean scenarios share one compiled program
    (faults arrive as schedule values and mask data)."""
    slow_prob: float = 0.0
    slow_factor: float = 4.0
    drop_prob: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.slow_prob <= 1.0:
            raise ValueError(
                f"slow_prob must be in [0, 1], got {self.slow_prob!r}")
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(
                "drop_prob must be in [0, 1) (a fleet that always drops "
                f"cannot train), got {self.drop_prob!r}")
        if not self.slow_factor >= 1.0:
            raise ValueError(
                f"slow_factor must be >= 1, got {self.slow_factor!r}")

    @property
    def keep_prob(self) -> float:
        """Per-period survival probability (importance-weighted sampling
        folds this into the inclusion probability)."""
        return 1.0 - self.drop_prob

    def __str__(self) -> str:  # readable grid-axis coordinate
        return (f"slow{self.slow_prob:g}x{self.slow_factor:g}"
                f"drop{self.drop_prob:g}@{self.seed}")


class FaultProcess:
    """Seeded straggler/dropout stream for one scenario row."""

    def __init__(self, faults: Faults, k: int, seed: int):
        self.faults = faults
        self.k = k
        self.rng = np.random.default_rng((seed, faults.seed, _STREAM_TAG))

    def draw(self, periods: int) -> Tuple[np.ndarray, np.ndarray]:
        """Realize ``(slowdown, keep)`` for ``periods`` periods.

        ``slowdown`` is ``(P, K)`` float (1.0 or ``slow_factor``);
        ``keep`` is ``(P, K)`` float {0,1}.  One ``(2, K)`` uniform
        block per period, C-order, so chunked == monolithic."""
        u = self.rng.uniform(size=(periods, 2, self.k))
        slowdown = np.where(u[:, 0] < self.faults.slow_prob,
                            self.faults.slow_factor, 1.0)
        keep = (u[:, 1] >= self.faults.drop_prob).astype(np.float64)
        return slowdown, keep

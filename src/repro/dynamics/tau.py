"""Adaptive local steps τ (Wang et al., 1804.05271).

``local_steps`` has been a static structural knob since PR 1: τ gradient
steps per upload, costing (τ-1) extra compute rounds per period and
crediting a τ·B̄ effective batch.  ``TauAdapt`` makes it a *re-planned*
knob next to batchsize: at every closed-loop chunk boundary the
scheduler scores each candidate τ with the same learning-efficiency
criterion Algorithm 1 optimizes —

    E(τ) = min(ξ·√(τ·B̄), decay_cap) / (t_comm + τ·t_comp)

using the last chunk's realized communication/computation split and the
row's live ξ estimator — and the bucket executes its next chunk at the
(conservative, bucket-consensus MIN) best choice.

τ is structural (it shapes the scan body), so ``choices`` joins
``bucket_key`` and each realized τ compiles its own program variant —
which is also why the serving layer rejects adaptive specs: its
program-cache key must be decidable at admission time, before any chunk
has realized a τ.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

__all__ = ["TauAdapt"]


@dataclass(frozen=True)
class TauAdapt:
    """Frozen spec-side value (``ScenarioSpec.adapt_tau``): the candidate
    local-step counts the closed loop may re-plan between.  The spec's
    ``local_steps`` is the starting point and must be a member."""
    choices: Tuple[int, ...] = (1, 2, 4)

    def __post_init__(self):
        object.__setattr__(self, "choices", tuple(self.choices))
        if not self.choices:
            raise ValueError("adapt_tau needs at least one choice")
        for c in self.choices:
            if not isinstance(c, int) or isinstance(c, bool) or c < 1:
                raise ValueError(
                    f"adapt_tau choices must be positive ints, got {c!r}")
        if len(set(self.choices)) != len(self.choices):
            raise ValueError(
                f"adapt_tau choices must be distinct, got {self.choices!r}")

    def __str__(self) -> str:  # readable grid-axis coordinate
        return "tau" + "/".join(str(c) for c in self.choices)

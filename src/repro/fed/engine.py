"""Device-resident FEEL trajectory engine.

The seed trainer executed one Python iteration per period with ``float()``
host syncs on every step, so even the tier-1 benchmarks crawled.  This
module compiles the whole trajectory into ONE jitted program:

  * host side (cheap numpy, done once up front): the scheduler plans the
    full horizon (``FeelScheduler.plan_horizon``), the batcher pre-samples
    every period's indices/masks, and the latency ledger is cumsum'd into
    a time axis — that is the :class:`Schedule`;
  * device side: ``jax.lax.scan`` over periods runs gather → per-device
    grads → SBC compression with error-feedback residuals → eq. (1)
    aggregation → SGD update → test metrics, with zero per-period host
    transfers;
  * ``vmap`` over the leading seed axis turns the same program into a
    batched multi-seed sweep (see ``repro.fed.sweep``).

ξ feedback becomes open-loop within a horizon (the paper's known-constant
treatment of ξ) and is applied post-hoc from the realized decay series, so
the trajectory is a pure function of the pre-generated schedule — which is
exactly what makes it scan-compilable and vmap-able.

The scan is *resumable*: the carry is an explicit :class:`EngineState`
(params + SBC residuals for the FEEL family, per-device params for the
dev family) that every ``run_*`` function accepts in and hands back out,
so a horizon may run as N chunked scans — each consuming one slice of the
schedule — bit-identical to one monolithic scan (the per-period step is a
pure function of carry and inputs, and ``lax.scan`` never re-associates
across steps; test-enforced).  That is what lets ``api.lowering`` plan
chunk *c+1* while chunk *c* executes, and re-plan with a ξ estimate
updated from chunk *c*'s realized decays (closed-loop Algorithm 1).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from functools import lru_cache, partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.sbc import compress_dense
from repro.fed import feel_model

tree_map = jax.tree_util.tree_map

# Incremented inside the traced bodies below, i.e. exactly once per jit
# trace.  ``api.Experiment`` buckets assert on this: a whole grid of
# shape-compatible scenarios must cost ONE trace, not one per cell.
# ``events`` is the structured ledger behind the count: one TraceEvent per
# trace, carrying the program-cache key and the abstract argument
# signature, so ``analysis.compile_audit`` can prove not just *how many*
# traces happened but that no (key, signature) pair ever traced twice —
# a duplicate is a retrace the jit cache should have absorbed.
_TRACES = {"n": 0, "events": [], "suspended": 0}


class TraceEvent(NamedTuple):
    """One jit trace of a trajectory program.

    ``kind`` names the program family (``feel`` / ``dev``); ``key`` is the
    ``lru_cache`` key that selects the compiled program (static config);
    ``signature`` is the flattened (shape, dtype) tuple of the traced
    arguments.  Two events with identical (kind, key, signature) mean the
    same program traced twice for the same abstract inputs — a retrace.
    """
    kind: str
    key: tuple
    signature: tuple


def trace_count() -> int:
    """Total number of trajectory-program traces so far in this process."""
    return _TRACES["n"]


def trace_events() -> tuple:
    """The structured trace ledger (one :class:`TraceEvent` per trace)."""
    return tuple(_TRACES["events"])


@contextlib.contextmanager
def suspend_trace_count():
    """Hide traces from the ledger while the context is active.

    The audit probes (``api.lowering.trace_bucket``) call ``jax.make_jaxpr``
    on the very programs whose trace discipline the ledger certifies;
    tracing for *inspection* must not look like a retrace, so probes run
    under this context.
    """
    _TRACES["suspended"] += 1
    try:
        yield
    finally:
        _TRACES["suspended"] -= 1


def _record_trace(kind: str, key: tuple, args) -> None:
    """Called from INSIDE traced bodies, i.e. exactly once per jit trace."""
    if _TRACES["suspended"]:
        return
    _TRACES["n"] += 1
    sig = tuple((tuple(a.shape), str(a.dtype))
                for a in jax.tree_util.tree_leaves(args))
    _TRACES["events"].append(TraceEvent(kind=kind, key=key, signature=sig))


# ---------------------------------------------------------------------------
# host -> device dtype boundary
# ---------------------------------------------------------------------------
#
# Host planners (core/scheduler.py, channels/model.py) deliberately work in
# numpy float64 — the latency ledgers are cumulative sums where 32-bit
# drift would change simulated-time results — but device programs are
# strictly 32-bit.  ``host_to_device`` below is the ONE sanctioned
# crossing: every jitted trajectory entry point funnels its array inputs
# through it, and ``assert_device_safe`` (also called by the
# compile-hygiene pass on lowered jaxprs) enforces that nothing 64-bit
# leaks past it.  ``times``/``global_batch`` never cross: they are
# host-side ledgers joined to device series only after collection.

_DEVICE_DTYPES = {"f": jnp.float32, "i": jnp.int32, "u": jnp.uint32,
                  "b": jnp.bool_, "c": jnp.complex64}


def host_to_device(tree):
    """Cast a pytree of host (numpy) arrays to device-safe dtypes.

    Floats → float32, ints → int32, bools pass through.  This is the
    single documented host↔device boundary; planners stay float64 on the
    host side and nothing 64-bit crosses it.
    """
    def cast(a):
        a = jnp.asarray(a)
        kind = np.dtype(a.dtype).kind
        target = _DEVICE_DTYPES.get(kind)
        if target is not None and a.dtype != target:
            a = a.astype(target)
        return a
    return tree_map(cast, tree)


def assert_device_safe(tree, where: str = "jit boundary"):
    """Raise if any leaf about to enter a jitted program is 64-bit."""
    for leaf in jax.tree_util.tree_leaves(tree):
        dtype = np.dtype(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        if dtype.itemsize == 8 and dtype.kind in "fiuc":
            raise TypeError(
                f"64-bit array ({dtype}) reached {where}; host planners "
                "must cross through engine.host_to_device first")
    return tree


def _shard_batch_args(mesh, batched_args, replicated_args):
    """Lay a bucket out on a device mesh: leading (scenario × seed) batch
    axis sharded, datasets replicated.  Single-device meshes degenerate to
    plain device placement, so this is safe as a CPU fallback."""
    from repro.launch.mesh import batch_sharding, replicated_sharding
    batched_args = jax.device_put(batched_args, batch_sharding(mesh))
    replicated_args = jax.device_put(replicated_args,
                                     replicated_sharding(mesh))
    return batched_args, replicated_args


@dataclass(frozen=True)
class Schedule:
    """Everything host-generated that one trajectory consumes."""
    idx: np.ndarray           # (P, K, slot) int32 — per-device sample indices
    weight: np.ndarray        # (P, K, slot) f32 — eq. (1) masks realizing B_k
    batch: np.ndarray         # (P, K) f32 — B_k (aggregation weights)
    lr: np.ndarray            # (P,) f32 — η per period
    times: np.ndarray         # (P,) f64 — cumulative simulated seconds
    global_batch: np.ndarray  # (P,) int
    # (P,) f32 fixed aggregation denominator, or None.  Horvitz-Thompson
    # weighted sampling plans batchsizes for the FULL fleet and divides
    # each cohort's eq. (1) sum by p·Σ_all b̄_k instead of the realized
    # Σ_cohort b_k; zero entries (the None default) fall back to the
    # realized sum inside the step, so unweighted schedules are bitwise
    # unchanged.
    aggden: Optional[np.ndarray] = None

    @property
    def periods(self) -> int:
        return self.idx.shape[0]

    def stacked_xs(self):
        """The per-period scan inputs, crossed through the device boundary.

        The scheduler plans in float64 (host precision); this is where the
        plan becomes device data — one cast, via :func:`host_to_device`.
        ``times``/``global_batch`` stay host-side and never cross.
        ``aggden`` always crosses (zeros when unset) so weighted and
        unweighted schedules share one program signature.
        """
        aggden = (np.zeros(self.idx.shape[0], np.float32)
                  if self.aggden is None else self.aggden)
        return host_to_device({
            "idx": self.idx,
            "weight": self.weight,
            "batch": self.batch,
            "lr": self.lr,
            "aggden": aggden,
        })


def slice_schedule(schedule: Schedule, lo: int, hi: int) -> Schedule:
    """The ``[lo, hi)`` period window of a schedule (chunked execution).

    ``times`` keeps its absolute cumulative values — a sliced schedule's
    ledger is the matching window of the monolithic ledger, so chunked
    results concatenate back bit-identically.
    """
    return Schedule(idx=schedule.idx[lo:hi], weight=schedule.weight[lo:hi],
                    batch=schedule.batch[lo:hi], lr=schedule.lr[lo:hi],
                    times=schedule.times[lo:hi],
                    global_batch=schedule.global_batch[lo:hi],
                    aggden=None if schedule.aggden is None
                    else schedule.aggden[lo:hi])


@dataclass
class EngineState:
    """Explicit scan carry, in and out of every trajectory function.

    ``params`` are the global model parameters (FEEL family) or the
    per-device parameter stacks (dev family, where ``residual`` stays
    ``None``); ``residual`` is the SBC error-feedback state.  Leaves are
    (possibly batched, possibly sharded) device arrays and may still be
    in flight — resuming a scan from an uncollected state is exactly how
    chunked dispatch pipelines without host round-trips.

    Because the carry is explicit, a *suspended* trajectory is nothing
    but a parked ``EngineState`` (plus the host planner's rng/offset
    state): the serving layer (``repro.serve``) preempts a long horizon
    at a chunk boundary by simply holding onto this state and resumes it
    later bit-identically.  :meth:`block_until_ready` is the park
    operation — it fences the in-flight device work so a suspended run
    holds finished buffers rather than a growing dispatch queue while
    other requests use the device.
    """
    params: object
    residual: object = None

    def block_until_ready(self) -> "EngineState":
        """Fence the carry: block until every in-flight leaf has been
        computed (the parked-state lifecycle used when a run is
        preempted).  Values are unchanged — parking is purely a
        synchronization point, never a semantic one."""
        jax.block_until_ready((self.params, self.residual))
        return self

    @property
    def is_ready(self) -> bool:
        """Whether every leaf has finished computing (best-effort: hosts
        arrays without an ``is_ready`` probe count as ready)."""
        return all(bool(leaf.is_ready()) if hasattr(leaf, "is_ready")
                   else True
                   for leaf in jax.tree_util.tree_leaves(
                       (self.params, self.residual)))


def build_schedule(scheduler, batcher, devices, periods: int,
                   local_steps: int = 1, horizon=None,
                   time_offset: float = 0.0) -> Schedule:
    """Pre-generate one run's plans, sample indices and time axis.

    Consumes the scheduler/batcher rng streams in the same per-period order
    as the seed's interleaved loop (the two streams are independent), so a
    fresh simulation reproduces the seed's sampling sequence exactly.
    ``horizon`` short-circuits planning when the caller already planned it
    (e.g. ``core.scheduler.plan_horizons_batch`` across a whole bucket).
    ``time_offset`` seeds the cumulative time axis for chunked horizons:
    the cumsum accumulates *from* the offset (not adds it afterwards —
    float addition is non-associative, and only the seeded form is
    bit-identical to the monolithic ledger; offset 0.0 degenerates to the
    plain cumsum bitwise since ``0.0 + x == x``).

    A sampled horizon (``horizon.participation`` set) masks the
    ``local_steps > 1`` compute-latency max to the period's participants —
    a sampled-out straggler cannot stretch a round it does not join.
    """
    if horizon is None:
        horizon = scheduler.plan_horizon(periods)
    idx = np.empty((periods, batcher.k, batcher.slot), np.int32)
    w = np.empty((periods, batcher.k, batcher.slot), np.float32)
    for p in range(periods):
        i_p, w_p = batcher.sample(horizon.batch[p])
        idx[p] = i_p
        w[p] = w_p
    per_period = horizon.latency.copy()
    if local_steps > 1:
        # tau local steps multiply the local-compute subperiod (paper §VII)
        part = getattr(horizon, "participation", None)
        slow = getattr(horizon, "slowdown", None)
        if slow is None:
            slow = np.ones_like(np.asarray(horizon.batch, np.float64))
        if part is None:
            per_period += (local_steps - 1) * np.array(
                [max(float(sl) * float(d.local_grad_latency(b))
                     for d, b, sl in zip(devices, bp, sp))
                 for bp, sp in zip(horizon.batch, slow)])
        else:
            # sampled horizon: only the period's participants compete in
            # the straggler max (a GPU's b=0 floor latency is nonzero, so
            # an unmasked max would charge absent users' idle floors)
            per_period += (local_steps - 1) * np.array(
                [max(float(sl) * float(d.local_grad_latency(b))
                     for d, b, m, sl in zip(devices, bp, mp, sp) if m > 0.5)
                 for bp, mp, sp in zip(horizon.batch, part, slow)])
    times = np.cumsum(np.concatenate([[time_offset], per_period]))[1:]
    aggden = getattr(horizon, "aggden", None)
    return Schedule(idx=idx, weight=w,
                    batch=horizon.batch.astype(np.float32),
                    lr=horizon.lr.astype(np.float32),
                    times=times,
                    global_batch=horizon.global_batch,
                    aggden=None if aggden is None
                    else aggden.astype(np.float32))


def zero_residual(params, k: int):
    """Fresh SBC error-feedback state: one residual per device per leaf."""
    return tree_map(lambda p: jnp.zeros((k,) + p.shape, p.dtype), params)


def pad_schedule(schedule: Schedule, k: int) -> Schedule:
    """Zero-pad a schedule's user axis to ``k`` rows (the ragged-fleet
    bucket contract): padded users get index 0, weight 0 and batch 0, so
    they gather real samples but contribute exactly nothing to any
    weighted loss, gradient, or eq. (1) aggregation.  Host ledgers
    (times/lr/global_batch) are per-period and untouched."""
    kk = schedule.idx.shape[1]
    if kk == k:
        return schedule
    pad3 = ((0, 0), (0, k - kk), (0, 0))
    return Schedule(idx=np.pad(schedule.idx, pad3),
                    weight=np.pad(schedule.weight, pad3),
                    batch=np.pad(schedule.batch, ((0, 0), (0, k - kk))),
                    lr=schedule.lr, times=schedule.times,
                    global_batch=schedule.global_batch,
                    aggden=schedule.aggden)


# ---------------------------------------------------------------------------
# the scanned period step (Steps 1-5 of the paper's §II-A loop, pure jnp)
# ---------------------------------------------------------------------------


def _period_step(data_x, data_y, test_x, test_y, local_steps,
                 compress, ratio, carry, xs):
    params, residual = carry
    idx, w, bk, lr = xs["idx"], xs["weight"], xs["batch"], xs["lr"]
    # active: (K,) f32 {0,1} — THIS period's user mask, a per-step scan
    # input (time-varying per-round participation; the PR-4 static padded
    # mask is the constant special case).  The schedule already carries
    # zero weights/batch for inactive users; multiplying keeps that
    # invariant even for hand-built schedules (x * 1.0 == x bitwise, so
    # fully-active rows are unchanged).
    active = xs["active"]
    w = w * active[:, None]
    bk = bk * active
    x = data_x[idx]                              # (K, slot, D)
    y = data_y[idx]
    xf = x.reshape(-1, x.shape[-1])
    yf = y.reshape(-1)
    wf = w.reshape(-1)
    loss_before = feel_model.loss_fn(params, xf, yf, wf)

    if local_steps == 1:
        grads = jax.vmap(jax.grad(feel_model.loss_fn),
                         in_axes=(None, 0, 0, 0))(params, x, y, w)
    else:
        # tau>1: per-device local SGD; upload the cumulative update
        # (parameter delta) as the "gradient" (paper §VII extension)
        dev_params = tree_map(
            lambda a: jnp.broadcast_to(a, (x.shape[0],) + a.shape), params)
        for _ in range(local_steps):
            g = jax.vmap(jax.grad(feel_model.loss_fn))(dev_params, x, y, w)
            dev_params = tree_map(lambda p, gg: p - lr * gg, dev_params, g)
        grads = tree_map(lambda p0, pk: (p0[None] - pk) / lr,
                         params, dev_params)

    if compress:
        # per-device SBC: every device sparsifies its OWN upload (the
        # paper's per-device uplink compression), which also makes the
        # top-k fraction a function of the device payload alone — a padded
        # (all-zero-gradient) user row compresses to exact zeros and the
        # active rows compress identically at any fleet padding.
        grads, residual = jax.vmap(
            lambda g, r: compress_dense(g, ratio, r))(grads, residual)
    # eq. (1): weighted average by B_k (padded rows carry B_k = 0).  A
    # positive ``aggden`` fixes the denominator (Horvitz-Thompson
    # weighted sampling: p·Σ_all b̄_k); zero falls back to the realized
    # cohort sum, which is the classic (biased-under-sampling) estimator
    # and bitwise identical to the pre-aggden step.
    den = xs["aggden"]
    wk = bk / jnp.where(den > 0, den, jnp.sum(bk))
    agg = tree_map(lambda g: jnp.tensordot(wk, g, axes=1), grads)
    params = tree_map(lambda p, g: p - lr * g, params, agg)

    loss_after = feel_model.loss_fn(params, xf, yf, wf)
    acc = feel_model.accuracy(params, test_x, test_y)
    return (params, residual), (loss_after, acc, loss_before - loss_after)


@lru_cache(maxsize=None)
def _trajectory_fn(local_steps: int, compress: bool, ratio: float,
                   batched: bool):
    key = (local_steps, compress, ratio, batched)

    def run(params0, residual0, active, xs, data_x, data_y, test_x, test_y):
        # active (P, K) rides the scan next to the schedule arrays
        step = partial(_period_step, data_x, data_y, test_x, test_y,
                       local_steps, compress, ratio)
        (params, residual), series = jax.lax.scan(
            step, (params0, residual0), dict(xs, active=active))
        return params, residual, series

    if batched:
        run = jax.vmap(run, in_axes=(0, 0, 0, 0, None, None, None, None))

    def traced(params0, residual0, active, xs, *data):
        # host side effect at trace time: ledger entry (exactly one/trace).
        # Must sit OUTSIDE the vmap so the signature keeps the batch axis
        # (inside, distinct-N programs would collide into one triple).
        _record_trace("feel", key, (params0, residual0, active, xs, *data))
        return run(params0, residual0, active, xs, *data)

    return jax.jit(traced)


def trajectory_program(local_steps: int = 1, compress: bool = True,
                       ratio: float = 0.005, batched: bool = True):
    """The (cached) jitted FEEL trajectory program for a static config.

    Public accessor for introspection — ``analysis``' probes call
    ``jax.make_jaxpr`` on this under :func:`suspend_trace_count`.
    """
    return _trajectory_fn(local_steps, compress, float(ratio), batched)


def dev_trajectory_program(average: bool, batched: bool = True):
    """The (cached) jitted dev-family program (see
    :func:`trajectory_program`)."""
    return _dev_trajectory_fn(bool(average), batched)


def run_trajectory(params0, residual0, schedule: Schedule, data, test, *,
                   local_steps: int = 1, compress: bool = True,
                   ratio: float = 0.005, active=None):
    """One trajectory as a single jitted ``lax.scan``.

    ``active``: optional f32 {0,1} user mask (default all-active) — either
    static ``(K,)`` (broadcast to every period: ragged-fleet padding) or
    time-varying ``(P, K)`` (per-round participation).  Zero entries
    contribute nothing to that period.  Returns (final params, final
    residuals, (losses, accs, decays)) where the series are per-period
    device arrays of length ``schedule.periods``.
    """
    if active is None:
        active = jnp.ones((schedule.periods, schedule.idx.shape[1]),
                          jnp.float32)
    else:
        active = jnp.asarray(active)
        if active.ndim == 1:
            active = jnp.broadcast_to(
                active[None, :], (schedule.periods, active.shape[0]))
    fn = _trajectory_fn(local_steps, compress, float(ratio), False)
    args = (params0, residual0, host_to_device(active),
            schedule.stacked_xs(), *host_to_device(
                (data.x, data.y, test.x, test.y)))
    return fn(*assert_device_safe(args, "run_trajectory"))


def stack_schedules(schedules: Sequence[Schedule]):
    """Stack per-scenario schedules along a leading batch axis → scan xs."""
    per_seed = [s.stacked_xs() for s in schedules]
    return {k: jnp.stack([p[k] for p in per_seed])
            for k in ("idx", "weight", "batch", "lr", "aggden")}


def _normalize_active_batch(active, n: int, periods: int, k: int):
    """Normalize a batched ``active`` argument to the (N, P, K) the scan
    consumes: ``None`` → all ones; a static (N, K) mask broadcasts across
    periods (the PR-4 ragged-padding case — value-identical, since the
    per-period multiply reuses the same {0,1} row every step)."""
    if active is None:
        return jnp.ones((n, periods, k), jnp.float32)
    active = jnp.asarray(active)
    if active.ndim == 2:
        active = jnp.broadcast_to(active[:, None, :], (n, periods, k))
    return host_to_device(active)


def run_trajectory_batch(params0, residual0, schedules: Sequence[Schedule],
                         data, test, *, local_steps: int = 1,
                         compress: bool = True, ratio: float = 0.005,
                         mesh=None, active=None):
    """Batched sweep: one compiled program advances every (scenario, seed).

    ``params0``/``residual0`` carry a leading batch axis (stack pytrees with
    ``jax.tree_util.tree_map(lambda *a: jnp.stack(a), *per_entry)``);
    ``schedules`` is one pre-generated :class:`Schedule` per batch entry —
    the axis may flatten an arbitrary (scenario × seed) grid, not just
    seeds.  Entries need not share a fleet size: pad each schedule to the
    common K (:func:`pad_schedule`) and pass ``active`` — an (N, K)
    static or (N, P, K) time-varying f32 {0,1} per-row user mask (default
    all-active) whose zero entries are padded / sampled-out users
    contributing nothing to any reduction.  With ``mesh``
    (a 1-D "batch" mesh from ``launch.mesh.make_batch_mesh``) the batch
    axis is sharded across its devices (batch size must divide evenly;
    pad upstream) and the datasets are replicated; ``mesh=None`` keeps the
    single-device layout.
    """
    xs = stack_schedules(schedules)
    active = _normalize_active_batch(active, len(schedules),
                                     schedules[0].periods,
                                     schedules[0].idx.shape[1])
    data_args = host_to_device((data.x, data.y, test.x, test.y))
    if mesh is not None:
        (params0, residual0, active, xs), data_args = _shard_batch_args(
            mesh, (params0, residual0, active, xs), data_args)
    fn = _trajectory_fn(local_steps, compress, float(ratio), True)
    assert_device_safe((params0, residual0, active, xs, data_args),
                       "run_trajectory_batch")
    return fn(params0, residual0, active, xs, *data_args)


# ---------------------------------------------------------------------------
# per-device-parameter schemes (individual / model_fl) — same engine idea
# ---------------------------------------------------------------------------


def _dev_step(data_x, data_y, test_x, test_y, lr, average,
              dev_params, xs):
    # active: (K,) f32 {0,1} — THIS period's user mask (time-varying, a
    # scan input alongside the indices).  The update itself is masked, so
    # a sampled-out user's parameters hold still until it participates
    # again; for the always-active case g * 1.0 == g keeps the trained
    # rows bitwise unchanged.
    idx, active = xs
    x = data_x[idx]
    y = data_y[idx]
    g = jax.vmap(jax.grad(feel_model.loss_fn))(dev_params, x, y)
    dev_params = tree_map(
        lambda p, gg: p - lr * (gg * active.reshape(
            (-1,) + (1,) * (gg.ndim - 1))), dev_params, g)
    # masked device mean: padded / sampled-out user rows (active 0) must
    # never enter a parameter average — denominator is the active count
    # (for an all-active mask this is sum(a)/K == mean bitwise)
    n_active = jnp.sum(active)

    def masked_mean(a):
        m = active.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.sum(a * m, axis=0) / n_active

    if average:
        # FedAvg: replace every device copy with the parameter mean
        dev_params = tree_map(
            lambda a: jnp.broadcast_to(masked_mean(a), a.shape), dev_params)
    avg = tree_map(masked_mean, dev_params)
    loss = feel_model.loss_fn(avg, test_x, test_y)
    acc = feel_model.accuracy(avg, test_x, test_y)
    return dev_params, (loss, acc)


@lru_cache(maxsize=None)
def _dev_trajectory_fn(average: bool, batched: bool = False):
    key = (average, batched)

    def run(dev_params0, idx, lr, active, data_x, data_y, test_x, test_y):
        # active (P, K) rides the scan next to the period indices
        step = partial(_dev_step, data_x, data_y, test_x, test_y, lr,
                       average)
        return jax.lax.scan(step, dev_params0, (idx, active))

    if batched:
        run = jax.vmap(run, in_axes=(0, 0, 0, 0, None, None, None, None))

    def traced(dev_params0, idx, lr, active, *data):
        # trace-time ledger entry — outside the vmap, see _trajectory_fn
        _record_trace("dev", key, (dev_params0, idx, lr, active, *data))
        return run(dev_params0, idx, lr, active, *data)

    return jax.jit(traced)


def run_dev_trajectory(dev_params0, idx: np.ndarray, lr: float, data, test,
                       *, average: bool, active=None):
    """scan-compiled individual / model_fl (``average=True``) trajectory.

    ``idx``: (P, K, batch) pre-sampled indices; ``active``: optional (K,)
    static or (P, K) time-varying f32 {0,1} user mask (default
    all-active).  Returns (final per-device params, (test losses, test
    accs)) per period.
    """
    idx = np.asarray(idx)
    if active is None:
        active = jnp.ones(idx.shape[:2], jnp.float32)
    else:
        active = jnp.asarray(active)
        if active.ndim == 1:
            active = jnp.broadcast_to(active[None, :], idx.shape[:2])
    fn = _dev_trajectory_fn(bool(average))
    args = (dev_params0, *host_to_device((np.asarray(idx),
                                          np.float32(lr), active,
                                          data.x, data.y, test.x, test.y)))
    return fn(*assert_device_safe(args, "run_dev_trajectory"))


def resume_trajectory_batch(state: EngineState, schedules: Sequence[Schedule],
                            data, test, *, local_steps: int = 1,
                            compress: bool = True, ratio: float = 0.005,
                            mesh=None, active=None):
    """Advance a batched FEEL trajectory by one schedule chunk.

    ``state`` is the carry from the previous chunk (or a fresh
    :class:`EngineState` of stacked init params + ``zero_residual``-style
    residuals).  Returns ``(EngineState, (losses, accs, decays))`` — a
    horizon run as N chunked calls is bit-identical to one monolithic
    :func:`run_trajectory_batch` (test-enforced).  The returned state's
    leaves may be in flight: resuming from them pipelines chunk *c+1*
    behind chunk *c* without blocking.
    """
    params, residual, series = run_trajectory_batch(
        state.params, state.residual, schedules, data, test,
        local_steps=local_steps, compress=compress, ratio=ratio,
        mesh=mesh, active=active)
    return EngineState(params=params, residual=residual), series


def run_dev_trajectory_batch(dev_params0, idx: np.ndarray, lr: np.ndarray,
                             data, test, *, average: bool, mesh=None,
                             active=None):
    """Batched individual / model_fl: one program for a whole bucket.

    ``dev_params0`` leaves are (N, K, ...), ``idx`` is (N, P, K, batch),
    ``lr`` is (N,) — N the flattened (scenario × seed) axis; ``active`` is
    an optional (N, K) static or (N, P, K) time-varying f32 {0,1} per-row
    user mask (zero entries = padded / sampled-out users, excluded from
    every parameter average).  ``mesh`` shards N across devices as in
    :func:`run_trajectory_batch`.
    """
    idx = host_to_device(np.asarray(idx))
    active = _normalize_active_batch(active, idx.shape[0], idx.shape[1],
                                     idx.shape[2])
    batched = (dev_params0, idx, *host_to_device((np.asarray(lr), active)))
    data_args = host_to_device((data.x, data.y, test.x, test.y))
    if mesh is not None:
        batched, data_args = _shard_batch_args(mesh, batched, data_args)
    fn = _dev_trajectory_fn(bool(average), batched=True)
    assert_device_safe((batched, data_args), "run_dev_trajectory_batch")
    return fn(*batched, *data_args)


def resume_dev_trajectory_batch(state: EngineState, idx: np.ndarray,
                                lr: np.ndarray, data, test, *,
                                average: bool, mesh=None, active=None):
    """Advance a batched dev-family trajectory by one index chunk.

    The dev carry is the per-device parameter stack alone (``residual``
    stays ``None``).  Returns ``(EngineState, (losses, accs))``; chunked
    calls are bit-identical to one monolithic
    :func:`run_dev_trajectory_batch` (test-enforced).
    """
    dev_params, series = run_dev_trajectory_batch(
        state.params, idx, lr, data, test, average=average, mesh=mesh,
        active=active)
    return EngineState(params=dev_params), series


# ---------------------------------------------------------------------------
# hierarchical FEEL (cell → edge-server → cloud, repro.topology.Topology)
# ---------------------------------------------------------------------------
#
# The flat FEEL scan keeps ONE global model; the hierarchical scan keeps
# one model replica PER EDGE SERVER (leaves grow a leading E axis) and the
# ``member`` one-hot (E, K) matrix routes users to replicas.  Every period
# each edge aggregates its own users' (compressed) gradients eq.-(1)-style
# into its replica; on cloud rounds (``xs["cloud"]`` = 1, cadence
# ``Topology.agg_every``) the replicas merge into the batch-weighted
# global average.  Reported metrics always evaluate that global average,
# so the series join the same Results surface as the flat family.
# Padded users are all-zero ``member`` columns AND active-mask zeros, so
# both the routing contraction and the weight normalization see the
# monoid identity — the PR-4 padded-row contract carries over unchanged.


def _hier_period_step(data_x, data_y, test_x, test_y, member, local_steps,
                      compress, ratio, carry, xs):
    params_e, residual = carry                    # leaves (E, ...) / (K, ...)
    idx, w, bk, lr = xs["idx"], xs["weight"], xs["batch"], xs["lr"]
    active, cloud = xs["active"], xs["cloud"]
    w = w * active[:, None]
    bk = bk * active
    # edge bookkeeping: s_e — per-edge batch mass; wk — per-edge eq. (1)
    # weights (a participant-free edge gets all-zero weights and a guard
    # denominator, so its replica simply holds still this period); beta —
    # batch share per edge, the cloud-merge and evaluation weights
    s_e = jnp.tensordot(member, bk, axes=1)                       # (E,)
    wk = member * bk[None, :] / jnp.where(s_e > 0, s_e, 1.0)[:, None]
    beta = s_e / jnp.sum(s_e)                                     # (E,)

    def cloud_view(tree):
        return tree_map(lambda a: jnp.tensordot(beta, a, axes=1), tree)

    # each user trains from ITS edge's replica (one-hot gather)
    user_params = tree_map(
        lambda a: jnp.tensordot(member, a, axes=((0,), (0,))), params_e)
    x = data_x[idx]                                # (K, slot, D)
    y = data_y[idx]
    xf = x.reshape(-1, x.shape[-1])
    yf = y.reshape(-1)
    wf = w.reshape(-1)
    global_before = cloud_view(params_e)
    loss_before = feel_model.loss_fn(global_before, xf, yf, wf)

    if local_steps == 1:
        grads = jax.vmap(jax.grad(feel_model.loss_fn))(user_params, x, y, w)
    else:
        dev_params = user_params
        for _ in range(local_steps):
            g = jax.vmap(jax.grad(feel_model.loss_fn))(dev_params, x, y, w)
            dev_params = tree_map(lambda p, gg: p - lr * gg, dev_params, g)
        grads = tree_map(lambda p0, pk: (p0 - pk) / lr,
                         user_params, dev_params)

    if compress:
        grads, residual = jax.vmap(
            lambda g, r: compress_dense(g, ratio, r))(grads, residual)
    # per-edge eq. (1) aggregation and SGD step on each replica
    agg = tree_map(lambda g: jnp.tensordot(wk, g, axes=1), grads)  # (E, ...)
    params_e = tree_map(lambda p, g: p - lr * g, params_e, agg)
    # cloud round: replicas -> batch-weighted global average, broadcast back
    params_e = tree_map(
        lambda a: jnp.where(cloud > 0.5,
                            jnp.broadcast_to(jnp.tensordot(beta, a, axes=1),
                                             a.shape), a), params_e)
    global_after = cloud_view(params_e)
    loss_after = feel_model.loss_fn(global_after, xf, yf, wf)
    acc = feel_model.accuracy(global_after, test_x, test_y)
    return (params_e, residual), (loss_after, acc, loss_before - loss_after)


@lru_cache(maxsize=None)
def _hier_trajectory_fn(local_steps: int, compress: bool, ratio: float,
                        n_edges: int, batched: bool):
    key = (local_steps, compress, ratio, n_edges, batched)

    def run(params_e0, residual0, member, active, cloud, xs,
            data_x, data_y, test_x, test_y):
        # member (E, K) is scan-invariant; active (P, K) and cloud (P,)
        # ride the scan with the schedule arrays
        step = partial(_hier_period_step, data_x, data_y, test_x, test_y,
                       member, local_steps, compress, ratio)
        (params_e, residual), series = jax.lax.scan(
            step, (params_e0, residual0),
            dict(xs, active=active, cloud=cloud))
        return params_e, residual, series

    if batched:
        run = jax.vmap(run, in_axes=(0, 0, 0, 0, 0, 0,
                                     None, None, None, None))

    def traced(params_e0, residual0, member, active, cloud, xs, *data):
        # trace-time ledger entry — outside the vmap, see _trajectory_fn
        _record_trace("hier", key,
                      (params_e0, residual0, member, active, cloud, xs,
                       *data))
        return run(params_e0, residual0, member, active, cloud, xs, *data)

    return jax.jit(traced)


def hier_trajectory_program(local_steps: int = 1, compress: bool = True,
                            ratio: float = 0.005, n_edges: int = 1,
                            batched: bool = True):
    """The (cached) jitted hierarchical trajectory program (see
    :func:`trajectory_program`)."""
    return _hier_trajectory_fn(local_steps, compress, float(ratio),
                               int(n_edges), batched)


def run_hier_trajectory_batch(params0, residual0, member, cloud,
                              schedules: Sequence[Schedule], data, test, *,
                              local_steps: int = 1, compress: bool = True,
                              ratio: float = 0.005, mesh=None, active=None):
    """Batched hierarchical sweep (cell→edge→cloud; see module section).

    ``params0`` leaves carry (N, E, ...) — one model replica per edge
    server per row; ``member`` is (N, E, K) user→edge one-hot (padded
    users: all-zero columns); ``cloud`` is (N, P) f32 {0,1} cloud-round
    flags (``Topology.cloud_rounds``); ``active`` as in
    :func:`run_trajectory_batch`.
    """
    xs = stack_schedules(schedules)
    active = _normalize_active_batch(active, len(schedules),
                                     schedules[0].periods,
                                     schedules[0].idx.shape[1])
    member = host_to_device(np.asarray(member))
    cloud = host_to_device(np.asarray(cloud))
    data_args = host_to_device((data.x, data.y, test.x, test.y))
    if mesh is not None:
        (params0, residual0, member, active, cloud, xs), data_args = \
            _shard_batch_args(
                mesh, (params0, residual0, member, active, cloud, xs),
                data_args)
    fn = _hier_trajectory_fn(local_steps, compress, float(ratio),
                             int(member.shape[1]), True)
    assert_device_safe((params0, residual0, member, active, cloud, xs,
                        data_args), "run_hier_trajectory_batch")
    return fn(params0, residual0, member, active, cloud, xs, *data_args)


def resume_hier_trajectory_batch(state: EngineState, member, cloud,
                                 schedules: Sequence[Schedule], data, test,
                                 *, local_steps: int = 1,
                                 compress: bool = True, ratio: float = 0.005,
                                 mesh=None, active=None):
    """Advance a batched hierarchical trajectory by one schedule chunk
    (the per-edge replicas + SBC residuals are the carry; chunked calls
    are bit-identical to one monolithic
    :func:`run_hier_trajectory_batch`)."""
    params_e, residual, series = run_hier_trajectory_batch(
        state.params, state.residual, member, cloud, schedules, data, test,
        local_steps=local_steps, compress=compress, ratio=ratio,
        mesh=mesh, active=active)
    return EngineState(params=params_e, residual=residual), series

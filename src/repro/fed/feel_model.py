"""The paper-scale classifier (feel-mlp config): a compact MLP trained with
the FEEL loop on synthetic 3072-dim / 10-class data (CIFAR-10 stand-in)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.feel_mlp import INPUT_DIM


def init(key, hidden: int = 256, classes: int = 10, depth: int = 3,
         input_dim: int = INPUT_DIM):
    dims = [input_dim] + [hidden] * (depth - 1) + [classes]
    keys = jax.random.split(key, len(dims) - 1)
    return [{
        "w": jax.random.normal(k, (i, o), jnp.float32) * jnp.sqrt(2.0 / i),
        "b": jnp.zeros((o,), jnp.float32),
    } for k, i, o in zip(keys, dims[:-1], dims[1:])]


def apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, x, y, w=None):
    """Weighted cross-entropy; w: per-example weights (eq. (1) masking)."""
    logits = apply(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    if w is None:
        return jnp.mean(nll)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1e-9)


def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(apply(params, x), -1) == y)

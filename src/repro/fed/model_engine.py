"""Big-model FEEL engine: transformer / Mamba-2 per-device train steps.

This is the ``model_family`` counterpart of :mod:`repro.fed.engine`'s
``feel_mlp`` scan.  A spec with ``model_family="transformer"`` or
``"mamba2"`` lowers to one jitted ``vmap(lax.scan)`` per bucket whose
scanned body is the *big-model* FEEL period: per-device gradients of the
``fed.train_step`` weighted-CE loss (the same loss ``make_train_step`` /
``make_multi_train_step`` scan — with ``compress=False`` the trajectory is
test-pinned equal to driving ``make_multi_train_step`` over the gathered
schedule batches), per-device SBC uploads through
:func:`repro.compression.sbc.sbc_uplink` (the pallas ``kernels/sbc.py``
composition on TPU, bitwise ``compress_dense`` on CPU), the eq. (1)
``B_k``-weighted aggregation, and the ``optim.sgd`` update applied through
the ``TrainState``/``apply_updates`` machinery.

Kernel dispatch follows the repo rule end to end: the runtime pins
``attn_impl="pallas"`` so attention runs ``kernels/flash_attention.py`` on
TPU and the test-covered jnp oracle on CPU, and ``mamba2_forward`` routes
its SSD scan through ``kernels.ops.ssd`` (pallas ``ssd_scan`` on TPU,
``ssd_reference`` on CPU).

The classification workload rides along unchanged: features are
deterministically quantized to token sequences (:func:`tokenize`), the
class label becomes the final next-token target, and test accuracy reads
the last position's argmax over the class-id slice of the vocab.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.sbc import sbc_uplink
from repro.configs.base import ArchConfig, SSMConfig
from repro.fed.engine import (EngineState, _normalize_active_batch,
                              _record_trace, _shard_batch_args,
                              assert_device_safe, host_to_device,
                              stack_schedules)
from repro.fed.train_step import TrainState, make_loss_fn
from repro.models.model import Runtime, forward
from repro.models.model import init as model_init
from repro.optim import apply_updates, sgd

tree_map = jax.tree_util.tree_map

# tokenization constants: VOCAB feature bins (class ids live in the first
# N_CLASSES slots of the same vocab), sequences capped at SEQ_CAP tokens
SEQ_CAP = 16
VOCAB = 32
N_CLASSES = 10

# the kernel-dispatch runtime: "pallas" attention routes through
# kernels.ops.flash_attention, which falls back to the jnp ref on CPU
KERNEL_RT = Runtime(dtype=jnp.float32, attn_impl="pallas")


# ---------------------------------------------------------------------------
# spec (hidden, depth) -> ArchConfig per family
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def family_arch(model_family: str, hidden: int, depth: int) -> ArchConfig:
    """Derive the per-family architecture from the spec's (hidden, depth).

    ``hidden`` must be divisible by 4 (spec-validated): the transformer
    uses 4 query heads over ``hidden``, the SSM uses 8-wide state heads
    over ``2 * hidden`` inner channels.
    """
    if model_family == "transformer":
        return ArchConfig(
            name=f"feel-transformer-h{hidden}-d{depth}", family="dense",
            n_layers=depth, d_model=hidden, n_heads=4, n_kv_heads=2,
            d_ff=2 * hidden, vocab=VOCAB)
    if model_family == "mamba2":
        return ArchConfig(
            name=f"feel-mamba2-h{hidden}-d{depth}", family="ssm",
            n_layers=depth, d_model=hidden, n_heads=0, n_kv_heads=0,
            d_ff=0, vocab=VOCAB, attn_kind="none",
            ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=8,
                          n_groups=1, chunk=4))
    raise ValueError(f"unknown big-model family {model_family!r}")


@lru_cache(maxsize=None)
def family_n_params(model_family: str, hidden: int, depth: int) -> int:
    """Cached true parameter count (prices the planner's uplink payload)."""
    return family_arch(model_family, hidden, depth).param_count()


def tokenize(data, seq_cap: int = SEQ_CAP, vocab: int = VOCAB):
    """Deterministic host-side feature quantization → (tokens, labels).

    Each example's first ``min(seq_cap, D)`` features (rounded down to a
    multiple of 4, so the SSD chunk size always divides the sequence) are
    squashed with tanh and binned into ``vocab`` ids (fixed affine map —
    no data-dependent statistics, so chunked re-tokenization is trivially
    bit-stable).  Labels are the next-token sequence with the class id as
    the final target, which is what makes last-position accuracy the
    classification metric.
    """
    x = np.asarray(data.x, np.float64)
    y = np.asarray(data.y)
    S = max(4, (min(seq_cap, x.shape[1]) // 4) * 4)
    if x.shape[1] < S:  # tiny feature dims: tile columns up to one chunk
        x = np.tile(x, (1, -(-S // x.shape[1])))
    bins = np.floor((np.tanh(x[:, :S] / 4.0) + 1.0) * 0.5 * vocab)
    tokens = np.clip(bins, 0, vocab - 1).astype(np.int64)
    labels = np.concatenate([tokens[:, 1:], y[:, None]], axis=1)
    return tokens, labels.astype(np.int64)


# ---------------------------------------------------------------------------
# the scanned period step (Steps 1-5 on the big-model train step)
# ---------------------------------------------------------------------------


def _model_period_step(cfg, rt, loss_fn, opt, compress, ratio,
                       tok, lab, test_tok, test_y, carry, xs):
    state, residual = carry
    idx, w, bk, lr = xs["idx"], xs["weight"], xs["batch"], xs["lr"]
    # same active-mask invariant as engine._period_step: the schedule
    # already zeroes inactive users; multiplying keeps it for hand-built
    # schedules and is bitwise free for fully-active rows
    active = xs["active"]
    w = w * active[:, None]
    bk = bk * active
    t = tok[idx]                                  # (K, slot, S)
    l_ = lab[idx]
    wt = jnp.broadcast_to(w[..., None], l_.shape).astype(jnp.float32)
    flat = {"tokens": t.reshape(-1, t.shape[-1]),
            "labels": l_.reshape(-1, l_.shape[-1]),
            "weights": wt.reshape(-1, wt.shape[-1])}
    loss_before = loss_fn(state.params, flat)[1]

    # Step 1-2: per-device gradients of the weighted-CE train-step loss on
    # each device's own slot batch (surplus slots carry zero weight)
    def dev_grad_loss(p, tk, lk, wk):
        return loss_fn(p, {"tokens": tk, "labels": lk, "weights": wk})[0]

    grads = jax.vmap(jax.grad(dev_grad_loss),
                     in_axes=(None, 0, 0, 0))(state.params, t, l_, wt)
    if compress:
        # per-device SBC with per-device error feedback — the kernel path
        # on accelerators, bitwise compress_dense on CPU (sbc_uplink)
        grads, residual = jax.vmap(
            lambda g, r: sbc_uplink(g, ratio, r))(grads, residual)
    # eq. (1): weighted average by B_k (padded rows carry B_k = 0); a
    # positive aggden fixes the denominator as in the MLP engine
    den = xs["aggden"]
    wk = bk / jnp.where(den > 0, den, jnp.sum(bk))
    agg = tree_map(lambda g: jnp.tensordot(wk, g, axes=1), grads)
    updates, new_opt = opt.update(agg, state.opt, state.params, lr)
    params = apply_updates(state.params, updates)
    state = TrainState(params, new_opt, state.step + 1)

    loss_after = loss_fn(params, flat)[1]
    logits, _ = forward(cfg, params, test_tok, rt=rt)
    acc = jnp.mean((jnp.argmax(logits[:, -1, :N_CLASSES], axis=-1)
                    == test_y).astype(jnp.float32))
    return (state, residual), (loss_after, acc, loss_before - loss_after)


@lru_cache(maxsize=None)
def _model_trajectory_fn(model_family: str, hidden: int, depth: int,
                         compress: bool, ratio: float, batched: bool):
    key = (model_family, hidden, depth, compress, ratio, batched)
    cfg = family_arch(model_family, hidden, depth)
    rt = KERNEL_RT
    loss_fn = make_loss_fn(cfg, rt)
    opt = sgd()

    def run(params0, residual0, active, xs, tok, lab, test_tok, test_y):
        state0 = TrainState(params0, opt.init(params0),
                            jnp.zeros((), jnp.int32))
        step = partial(_model_period_step, cfg, rt, loss_fn, opt,
                       compress, ratio, tok, lab, test_tok, test_y)
        (state, residual), series = jax.lax.scan(
            step, (state0, residual0), dict(xs, active=active))
        return state.params, residual, series

    if batched:
        run = jax.vmap(run, in_axes=(0, 0, 0, 0, None, None, None, None))

    def traced(params0, residual0, active, xs, *data):
        # ledger entry OUTSIDE the vmap (same rationale as engine)
        _record_trace("model", key, (params0, residual0, active, xs, *data))
        return run(params0, residual0, active, xs, *data)

    return jax.jit(traced)


def model_trajectory_program(model_family: str, hidden: int, depth: int,
                             compress: bool = True, ratio: float = 0.005,
                             batched: bool = True):
    """The (cached) jitted big-model FEEL trajectory program.

    Public accessor for introspection — ``analysis``' probes call
    ``jax.make_jaxpr`` on this under ``suspend_trace_count``.
    """
    return _model_trajectory_fn(model_family, int(hidden), int(depth),
                                bool(compress), float(ratio), batched)


# ---------------------------------------------------------------------------
# batched drivers (mirror engine.run/resume_trajectory_batch)
# ---------------------------------------------------------------------------


def init_params_batch(model_family: str, hidden: int, depth: int, keys):
    """Stacked per-row model params: vmap of ``models.model.init`` over a
    (N, 2) uint32 key batch."""
    cfg = family_arch(model_family, hidden, depth)
    return jax.vmap(lambda k: model_init(cfg, k))(keys)


def run_model_trajectory_batch(params0, residual0,
                               schedules: Sequence, data, test, *,
                               model_family: str, hidden: int, depth: int,
                               compress: bool = True, ratio: float = 0.005,
                               mesh=None, active=None):
    """Batched big-model sweep: one program advances every (scenario, seed).

    Same contract as :func:`repro.fed.engine.run_trajectory_batch` —
    ``params0``/``residual0`` carry a leading batch axis, padded user rows
    ride the ``active`` mask — except the datasets enter as quantized
    token/label arrays (:func:`tokenize`).
    """
    xs = stack_schedules(schedules)
    active = _normalize_active_batch(active, len(schedules),
                                     schedules[0].periods,
                                     schedules[0].idx.shape[1])
    tok, lab = tokenize(data)
    test_tok, _ = tokenize(test)
    data_args = host_to_device((tok, lab, test_tok, np.asarray(test.y)))
    if mesh is not None:
        (params0, residual0, active, xs), data_args = _shard_batch_args(
            mesh, (params0, residual0, active, xs), data_args)
    fn = _model_trajectory_fn(model_family, int(hidden), int(depth),
                              bool(compress), float(ratio), True)
    assert_device_safe((params0, residual0, active, xs, data_args),
                       "run_model_trajectory_batch")
    return fn(params0, residual0, active, xs, *data_args)


def resume_model_trajectory_batch(state: EngineState,
                                  schedules: Sequence, data, test, *,
                                  model_family: str, hidden: int, depth: int,
                                  compress: bool = True, ratio: float = 0.005,
                                  mesh=None, active=None):
    """Advance a batched big-model trajectory by one schedule chunk
    (chunked-horizon counterpart of ``engine.resume_trajectory_batch``)."""
    params, residual, series = run_model_trajectory_batch(
        state.params, state.residual, schedules, data, test,
        model_family=model_family, hidden=hidden, depth=depth,
        compress=compress, ratio=ratio, mesh=mesh, active=active)
    return EngineState(params=params, residual=residual), series

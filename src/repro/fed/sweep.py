"""Batched FEEL scenario sweeps: policies × partitions × device fleets,
vmapped over seeds.

Every grid cell (one policy on one partition of one fleet) becomes a single
compiled program: per-seed schedules are pre-generated on the host, initial
params/residuals are stacked along a leading seed axis, and
``engine.run_trajectory_batch`` advances all seeds in one
``vmap(lax.scan)`` call.  Adding a scenario is a config entry, not a new
Python loop.

    fleets = {"cpu6": [DeviceProfile(kind="cpu", f_cpu=f*1e9) for f in ...]}
    results = run_sweep(fleets, data, test,
                        policies=("proposed", "online", "full"),
                        partitions=("iid", "noniid"), seeds=range(8),
                        periods=100)
    results["cpu6/iid/proposed"].speed(0.6)   # (n_seeds,) time-to-accuracy
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.fed.trainer import FeelSimulation, RunResult, _eval_points


@dataclass(frozen=True)
class SweepCell:
    """Full per-seed trajectories of one (fleet, partition, policy) cell."""
    name: str                  # "<fleet>/<partition>/<policy>"
    fleet: str
    partition: str
    policy: str
    seeds: Sequence[int]
    losses: np.ndarray         # (n_seeds, periods)
    accs: np.ndarray           # (n_seeds, periods)
    times: np.ndarray          # (n_seeds, periods) cumulative sim seconds
    global_batch: np.ndarray   # (n_seeds, periods)

    def speed(self, target_acc: float) -> np.ndarray:
        """(n_seeds,) simulated time to reach target accuracy (inf never)."""
        t = np.where(self.accs >= target_acc, self.times, np.inf)
        return t.min(axis=1)

    @property
    def final_acc(self) -> np.ndarray:
        return self.accs[:, -1]

    def run_result(self, seed_i: int = 0, eval_every: int = 10) -> RunResult:
        """Down-convert one seed to the legacy RunResult shape."""
        periods = self.losses.shape[1]
        res = RunResult(scheme=f"feel/{self.policy}")
        for p in _eval_points(periods, eval_every):
            res.losses.append(float(self.losses[seed_i, p]))
            res.accs.append(float(self.accs[seed_i, p]))
            res.times.append(float(self.times[seed_i, p]))
            res.global_batches.append(int(self.global_batch[seed_i, p]))
        return res


def run_seed_batch(sims: Sequence[FeelSimulation], periods: int):
    """vmap one compiled trajectory over a batch of same-shape simulations.

    All sims must share fleet size, ``b_max``, ``local_steps``,
    ``compress`` and data — exactly what varying only the seed gives you.
    Returns (losses, accs, times, global_batch) arrays, seed axis leading.
    """
    schedules = [sim.plan_run(periods) for sim in sims]
    params0 = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[sim.params for sim in sims])
    residual0 = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[sim.initial_residual() for sim in sims])
    s0 = sims[0]
    params, residuals, (losses, accs, decays) = engine.run_trajectory_batch(
        params0, residual0, schedules, s0.data, s0.test,
        local_steps=s0.local_steps, compress=s0.compress,
        ratio=s0.scheduler.compression)
    decays = np.asarray(decays)
    for i, sim in enumerate(sims):
        sim.params = jax.tree_util.tree_map(lambda a, i=i: a[i], params)
        sim.residuals = jax.tree_util.tree_map(
            lambda a, i=i: a[i], residuals)
        sim.scheduler.observe_series(decays[i], schedules[i].global_batch)
    times = np.stack([s.times for s in schedules])
    gb = np.stack([s.global_batch for s in schedules])
    return np.asarray(losses), np.asarray(accs), times, gb


def run_sweep(fleets: Mapping[str, Sequence[DeviceProfile]],
              data: ClassificationData, test: ClassificationData,
              policies: Sequence[str] = ("proposed",),
              partitions: Sequence[str] = ("noniid",),
              seeds: Sequence[int] = (0,), periods: int = 100,
              b_max: int = 128, base_lr: float = 0.05,
              compress: bool = True,
              local_steps: int = 1) -> Dict[str, SweepCell]:
    """Grid driver: one vmapped scan per (fleet, partition, policy) cell."""
    results: Dict[str, SweepCell] = {}
    seeds = list(seeds)
    for fleet_name, devices in fleets.items():
        for partition in partitions:
            for policy in policies:
                sims = [FeelSimulation(
                    devices, data, test, partition=partition, policy=policy,
                    compress=compress, b_max=b_max, base_lr=base_lr,
                    seed=s, local_steps=local_steps) for s in seeds]
                losses, accs, times, gb = run_seed_batch(sims, periods)
                name = f"{fleet_name}/{partition}/{policy}"
                results[name] = SweepCell(
                    name=name, fleet=fleet_name, partition=partition,
                    policy=policy, seeds=tuple(seeds), losses=losses,
                    accs=accs, times=times, global_batch=gb)
    return results

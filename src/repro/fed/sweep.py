"""Legacy sweep surface: ``SweepCell`` containers, the vmap-over-seeds
``run_seed_batch`` building block, and the DEPRECATED ``run_sweep`` grid
driver (now a thin shim over ``repro.api.Experiment`` with unchanged
return values).

New code should declare ``ScenarioSpec`` values and run an
``Experiment`` — the declarative path lowers the WHOLE grid into one
compiled program per shape bucket and shards the flattened
(cell × seed) axis across devices; see the README migration table.

    fleets = {"cpu6": [DeviceProfile(kind="cpu", f_cpu=f*1e9) for f in ...]}
    results = run_sweep(fleets, data, test,            # deprecated shim
                        policies=("proposed", "online", "full"),
                        partitions=("iid", "noniid"), seeds=range(8),
                        periods=100)
    results["cpu6/iid/proposed"].speed(0.6)   # (n_seeds,) time-to-accuracy
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.results import time_to_target
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.fed.trainer import FeelSimulation, RunResult, _eval_points


@dataclass(frozen=True)
class SweepCell:
    """Full per-seed trajectories of one (fleet, partition, policy) cell."""
    name: str                  # "<fleet>/<partition>/<policy>"
    fleet: str
    partition: str
    policy: str
    seeds: Sequence[int]
    losses: np.ndarray         # (n_seeds, periods)
    accs: np.ndarray           # (n_seeds, periods)
    times: np.ndarray          # (n_seeds, periods) cumulative sim seconds
    global_batch: np.ndarray   # (n_seeds, periods)

    def speed(self, target_acc: float) -> np.ndarray:
        """(n_seeds,) simulated time to reach target accuracy (inf never).

        NaN accuracies ("not evaluated this period" — the python engine
        leaves them at non-eval periods) are masked out explicitly before
        the compare, never silently treated as below-target values."""
        return time_to_target(self.accs, self.times, target_acc)

    @property
    def final_acc(self) -> np.ndarray:
        return self.accs[:, -1]

    def run_result(self, seed_i: int = 0, eval_every: int = 10) -> RunResult:
        """Down-convert one seed to the legacy RunResult shape."""
        periods = self.losses.shape[1]
        res = RunResult(scheme=f"feel/{self.policy}")
        for p in _eval_points(periods, eval_every):
            res.losses.append(float(self.losses[seed_i, p]))
            res.accs.append(float(self.accs[seed_i, p]))
            res.times.append(float(self.times[seed_i, p]))
            res.global_batches.append(int(self.global_batch[seed_i, p]))
        return res


def run_seed_batch(sims: Sequence[FeelSimulation], periods: int):
    """vmap one compiled trajectory over a batch of same-shape simulations.

    All sims must share fleet size, ``b_max``, ``local_steps``,
    ``compress`` and data — exactly what varying only the seed gives you.
    Returns (losses, accs, times, global_batch) arrays, seed axis leading.
    """
    schedules = [sim.plan_run(periods) for sim in sims]
    params0 = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[sim.params for sim in sims])
    residual0 = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *[sim.initial_residual() for sim in sims])
    s0 = sims[0]
    params, residuals, (losses, accs, decays) = engine.run_trajectory_batch(
        params0, residual0, schedules, s0.data, s0.test,
        local_steps=s0.local_steps, compress=s0.compress,
        ratio=s0.scheduler.compression)
    decays = np.asarray(decays)
    for i, sim in enumerate(sims):
        sim.params = jax.tree_util.tree_map(lambda a, i=i: a[i], params)
        sim.residuals = jax.tree_util.tree_map(
            lambda a, i=i: a[i], residuals)
        sim.scheduler.observe_series(decays[i], schedules[i].global_batch)
    times = np.stack([s.times for s in schedules])
    gb = np.stack([s.global_batch for s in schedules])
    return np.asarray(losses), np.asarray(accs), times, gb


def run_sweep(fleets: Mapping[str, Sequence[DeviceProfile]],
              data: ClassificationData, test: ClassificationData,
              policies: Sequence[str] = ("proposed",),
              partitions: Sequence[str] = ("noniid",),
              seeds: Sequence[int] = (0,), periods: int = 100,
              b_max: int = 128, base_lr: float = 0.05,
              compress: bool = True,
              local_steps: int = 1) -> Dict[str, SweepCell]:
    """DEPRECATED grid driver — thin shim over ``repro.api.Experiment``.

    Prefer building ``ScenarioSpec`` values and running an ``Experiment``:
    the declarative path lowers the WHOLE grid into one compiled program
    per shape bucket (this shim's grid is always a single bucket) instead
    of one program invocation per cell.  Returns the same
    ``{"fleet/partition/policy": SweepCell}`` mapping as PR 1.
    """
    warnings.warn(
        "run_sweep is deprecated; use repro.api.Experiment with "
        "ScenarioSpec values (see README migration table)",
        DeprecationWarning, stacklevel=2)
    from repro.api import Experiment, ScenarioSpec
    seeds = tuple(seeds)
    specs = [
        ScenarioSpec(fleet=tuple(devices), name=fleet_name, scheme="feel",
                     partition=partition, policy=policy, compress=compress,
                     b_max=b_max, base_lr=base_lr, local_steps=local_steps,
                     seeds=seeds)
        for fleet_name, devices in fleets.items()
        for partition in partitions
        for policy in policies]
    res = Experiment(data, test, specs).run(periods)
    results: Dict[str, SweepCell] = {}
    for spec in specs:
        cell = res.sel(fleet=spec.name, partition=spec.partition,
                       policy=spec.effective_policy)
        name = f"{spec.name}/{spec.partition}/{spec.policy}"
        results[name] = SweepCell(
            name=name, fleet=spec.name, partition=spec.partition,
            policy=spec.policy, seeds=seeds, losses=cell.losses,
            accs=cell.accs, times=cell.times, global_batch=cell.global_batch)
    return results

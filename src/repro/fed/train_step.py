"""Training / serving step builders for the big-model configs.

``make_train_step`` realizes the FEEL aggregation (eq. 1) under SPMD:
per-example weights (the federated B_k masks from the scheduler plan)
enter the weighted CE loss; the cross-device gradient mean that jit/GSPMD
emits over the data axis IS the paper's Step-3 aggregation.  Optional
``compress_uplink`` applies SBC to the gradients *before* the optimizer —
the in-graph counterpart of the paper's Step-2 compression — with the
error-feedback residual (Sattler et al.) threaded through
``TrainState.residual`` so sparsification preserves convergence.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.compression.sbc import sbc_uplink
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.layers import padded_vocab
from repro.models.model import Runtime, forward, decode_step, init_cache
from repro.optim import Optimizer, apply_updates


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: jnp.ndarray
    residual: Any = None   # SBC error-feedback accumulator (compress_uplink)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step, s.residual), None),
    lambda _, ch: TrainState(*ch))


def zero_residual(params):
    """A zeroed error-feedback accumulator matching ``params``' structure."""
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def weighted_ce(cfg: ArchConfig, logits, labels, weights):
    """Weighted next-token CE.

    logits: (B,S,V) or (B,S,ncb,V); labels alike; weights: (B,S) —
    product of the federated per-example mask and any token mask.
    eq. (1): Σ_k B_k·ḡ_k / Σ B_k  ==  Σ_i w_i·g_i / Σ w_i  (test-covered).
    """
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if cfg.n_codebooks > 1:
        nll = nll.sum(-1)                       # sum codebook losses
    denom = jnp.maximum(jnp.sum(weights), 1e-6)
    return jnp.sum(nll * weights) / denom


def make_loss_fn(cfg: ArchConfig, rt: Runtime):
    def loss_fn(params, batch):
        logits, aux = forward(cfg, params, batch["tokens"],
                              prefix_embeds=batch.get("prefix"), rt=rt)
        loss = weighted_ce(cfg, logits, batch["labels"], batch["weights"])
        return loss + aux.astype(jnp.float32), loss

    return loss_fn


def make_train_step(cfg: ArchConfig, rt: Runtime, opt: Optimizer,
                    compress_uplink: bool = False,
                    compress_ratio: float = 0.005):
    loss_fn = make_loss_fn(cfg, rt)

    def train_step(state: TrainState, batch, lr):
        (total, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)
        if compress_uplink:
            # Step 2: per-device SBC before the (implicit) all-reduce, with
            # the error-feedback residual — sparsification without it breaks
            # the compress_dense convergence contract.
            grads, new_res = sbc_uplink(grads, compress_ratio, state.residual)
        else:
            new_res = state.residual
        updates, new_opt = opt.update(grads, state.opt, state.params, lr)
        new_params = apply_updates(state.params, updates)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        metrics = {"loss": ce, "total_loss": total, "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1, new_res), metrics

    return train_step


def make_multi_train_step(cfg: ArchConfig, rt: Runtime, opt: Optimizer,
                          compress_uplink: bool = False,
                          compress_ratio: float = 0.005):
    """Device-resident multi-period trainer: ``lax.scan`` of ``train_step``
    over stacked batches + per-period learning rates (the scheduler plan's
    η series), so T periods compile to one program with no host sync
    inside the loop — the big-model counterpart of ``fed.engine``.

    Call as ``many(state, batches, lrs)`` where every leaf of ``batches``
    has a leading T axis and ``lrs`` is (T,).  Returns the final state and
    per-period stacked metrics.
    """
    step = make_train_step(cfg, rt, opt, compress_uplink, compress_ratio)

    def many(state: TrainState, batches, lrs):
        if compress_uplink and state.residual is None:
            # materialize the error-feedback accumulator before tracing the
            # scan — the carry structure must be stable across periods
            state = TrainState(state.params, state.opt, state.step,
                               zero_residual(state.params))

        def body(s, xs):
            b, lr = xs
            return step(s, b, lr)

        return jax.lax.scan(body, state, (batches, lrs))

    return many


def make_prefill_step(cfg: ArchConfig, rt: Runtime):
    def prefill(params, batch):
        logits, _ = forward(cfg, params, batch["tokens"],
                            prefix_embeds=batch.get("prefix"), rt=rt)
        return logits

    return prefill


def make_serve_step(cfg: ArchConfig, rt: Runtime):
    def serve(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens, rt=rt)

    return serve


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — the dry-run contract)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime):
    """Abstract inputs for every model input of the given (arch, shape).

    Train/prefill: token batch (+ labels/weights for train, prefix embeds
    for the VLM stub).  Decode: one new token per sequence + the KV/SSM
    cache of ``seq_len`` context.
    """
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    i32 = jnp.int32

    if shape.mode in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
        if cfg.vlm_prefix:
            P = min(cfg.vlm_prefix, S // 2)
            batch["prefix"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                   rt.dtype)
        if shape.mode == "train":
            batch["labels"] = jax.ShapeDtypeStruct(tok_shape, i32)
            batch["weights"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
        return batch

    # decode: cache allocated at min(seq_len, window) context — the
    # documented init_cache contract; a sliding-window arch's decode_step
    # only ever addresses ``window`` ring-buffer slots
    win = rt.win(cfg)
    ctx = min(S, win) if win else S
    cache = jax.eval_shape(partial(init_cache, cfg, B, ctx, rt))
    tok1 = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
    return {"cache": cache, "tokens": jax.ShapeDtypeStruct(tok1, i32)}

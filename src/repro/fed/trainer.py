"""FEEL training loop (paper §II-A five steps) + the Table-II scheme zoo.

Simulated wall-clock comes from the core latency models (the container has
no radio or edge devices); learning is real JAX compute on synthetic data.

Schemes:
  feel        — the paper's proposal: scheduler-planned B_k/τ_k, compressed
                gradient aggregation (eq. (1)), η ∝ √B.
  gradient_fl — [40]: full-slot batches, equal TDMA slots, compressed grads.
  model_fl    — FedAvg [19]: one local epoch, parameter upload
                (uncompressed payload d·p).
  individual  — no collaboration; models averaged once at the end.

Execution engines (``FeelSimulation.engine``):
  scan   — device-resident (default): the whole trajectory is pre-planned
           into an ``engine.Schedule`` and compiled to a single jitted
           ``jax.lax.scan`` with zero per-period host transfers.
  python — the seed's one-Python-iteration-per-period reference loop with
           ``float()`` syncs; consumes the SAME pre-generated schedule, so
           scan-vs-python is a pure numerics regression check (test-covered)
           and the speed baseline for ``benchmarks/sweep_speed.py``.

Both engines are open-loop in ξ within a run (the paper's known-constant
treatment); realized decays feed the ξ estimator post-hoc so it still
adapts across successive ``run`` calls.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels.model import Cell, CellConfig
from repro.compression.sbc import compress_dense
from repro.core import DeviceProfile, FeelScheduler
from repro.core.scheduler import DevScheduler
from repro.data.pipeline import (ClassificationData, FederatedBatcher,
                                 partition_iid, partition_noniid)
from repro.fed import engine, feel_model
from repro.fed.engine import Schedule, build_schedule


@dataclass
class RunResult:
    scheme: str
    losses: List[float] = field(default_factory=list)
    accs: List[float] = field(default_factory=list)
    times: List[float] = field(default_factory=list)       # cumulative (s)
    global_batches: List[int] = field(default_factory=list)

    def speed(self, target_acc: float) -> float:
        """Time to reach target accuracy (inf if never).

        NaN accuracies mean "not evaluated at this point" (the python
        engine's non-eval periods) and are skipped explicitly — they can
        neither reach the target nor count against it."""
        for a, t in zip(self.accs, self.times):
            if not math.isnan(a) and a >= target_acc:
                return t
        return float("inf")


def _eval_points(periods: int, eval_every: int) -> List[int]:
    return [p for p in range(periods)
            if p % eval_every == 0 or p == periods - 1]


@dataclass
class FeelSimulation:
    devices: Sequence[DeviceProfile]
    data: ClassificationData
    test: ClassificationData
    partition: str = "noniid"            # iid | noniid
    policy: str = "proposed"             # core.baselines key
    compress: bool = True
    b_max: int = 128
    base_lr: float = 0.05
    seed: int = 0
    hidden: int = 256
    depth: int = 3
    local_steps: int = 1                 # paper §VII future work: multiple
                                         # local updates per period (tau>1
                                         # FedAvg-style); latency scales the
                                         # local-compute term accordingly
    engine: str = "scan"                 # scan | python (reference loop)
    cell_cfg: CellConfig = field(default_factory=CellConfig)

    def __post_init__(self):
        k = len(self.devices)
        if self.partition == "iid":
            self.parts = partition_iid(len(self.data.y), k, self.seed)
        else:
            self.parts = partition_noniid(self.data.y, k, seed=self.seed)
        self.batcher = FederatedBatcher(self.parts, self.b_max, self.seed)
        self.params = feel_model.init(jax.random.key(self.seed), self.hidden,
                                      depth=self.depth,
                                      input_dim=self.data.x.shape[1])
        self.n_params = sum(int(np.prod(np.shape(l)))
                            for l in jax.tree_util.tree_leaves(self.params))
        self.scheduler = FeelScheduler(
            devices=self.devices, n_params=self.n_params, policy=self.policy,
            b_max=self.b_max, base_lr=self.base_lr, seed=self.seed,
            cell_cfg=self.cell_cfg)
        self.residuals = None
        self._grad_fn = jax.jit(jax.vmap(
            jax.grad(feel_model.loss_fn), in_axes=(None, 0, 0, 0)))
        self._loss_fn = jax.jit(feel_model.loss_fn)
        self._acc_fn = jax.jit(feel_model.accuracy)

    # ---- schedule + initial carry (shared by both engines and sweep) -----
    def plan_run(self, periods: int) -> Schedule:
        return build_schedule(self.scheduler, self.batcher, self.devices,
                              periods, self.local_steps)

    def initial_residual(self):
        if self.residuals is not None:
            return self.residuals
        return engine.zero_residual(self.params, self.batcher.k)

    def run(self, periods: int, eval_every: int = 10) -> RunResult:
        sched = self.plan_run(periods)
        evals = _eval_points(periods, eval_every)
        if self.engine == "python":
            losses, accs, decays = self._run_python(sched, evals)
        else:
            self.params, self.residuals, (losses, accs, decays) = \
                engine.run_trajectory(
                    self.params, self.initial_residual(), sched,
                    self.data, self.test, local_steps=self.local_steps,
                    compress=self.compress,
                    ratio=self.scheduler.compression)
            losses = np.asarray(losses)
            accs = np.asarray(accs)
            decays = np.asarray(decays)
        self.scheduler.observe_series(decays, sched.global_batch)
        res = RunResult(scheme=f"feel/{self.policy}")
        for p in evals:
            res.losses.append(float(losses[p]))
            res.accs.append(float(accs[p]))
            res.times.append(float(sched.times[p]))
            res.global_batches.append(int(sched.global_batch[p]))
        return res

    # ---- seed reference path: one FEEL period (Steps 1-5) per Python
    # iteration, float() host syncs each step --------------------------------
    def _run_python(self, sched: Schedule, evals: Sequence[int]):
        periods = sched.periods
        losses = np.zeros(periods)
        accs = np.full(periods, np.nan)
        decays = np.zeros(periods)
        evals = set(evals)
        for p in range(periods):
            loss_before, loss_after = self._python_period(
                sched.idx[p], sched.weight[p], sched.batch[p],
                float(sched.lr[p]))
            losses[p] = loss_after
            decays[p] = loss_before - loss_after
            if p in evals:
                accs[p] = float(self._acc_fn(self.params,
                                             jnp.asarray(self.test.x),
                                             jnp.asarray(self.test.y)))
        return losses, accs, decays

    def _python_period(self, idx, w, bk, lr):
        x = jnp.asarray(self.data.x[idx])            # (K, slot, D)
        y = jnp.asarray(self.data.y[idx])
        wj = jnp.asarray(w)

        loss_before = float(self._loss_fn(
            self.params, x.reshape(-1, x.shape[-1]), y.reshape(-1),
            wj.reshape(-1)))

        if self.local_steps == 1:
            grads = self._grad_fn(self.params, x, y, wj)  # leading K axis
        else:
            dev_params = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.batcher.k,) + a.shape),
                self.params)
            for _ in range(self.local_steps):
                g = jax.vmap(jax.grad(feel_model.loss_fn))(
                    dev_params, x, y, wj)
                dev_params = jax.tree_util.tree_map(
                    lambda p, gg: p - lr * gg, dev_params, g)
            grads = jax.tree_util.tree_map(
                lambda p0, pk: (p0[None] - pk) / lr,
                self.params, dev_params)
        if self.compress:
            # per-device SBC (each device sparsifies its own upload) —
            # must mirror engine._period_step exactly for the scan-vs-
            # python equivalence contract
            if self.residuals is None:
                self.residuals = jax.tree_util.tree_map(jnp.zeros_like,
                                                        grads)
            grads, self.residuals = jax.vmap(
                lambda g, r: compress_dense(
                    g, self.scheduler.compression, r))(grads, self.residuals)
        # eq. (1): weighted average by B_k
        bkj = jnp.asarray(bk, jnp.float32)
        wk = bkj / jnp.sum(bkj)
        agg = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(wk, g, axes=1), grads)
        self.params = jax.tree_util.tree_map(
            lambda p_, g: p_ - lr * g, self.params, agg)

        loss_after = float(self._loss_fn(
            self.params, x.reshape(-1, x.shape[-1]), y.reshape(-1),
            wj.reshape(-1)))
        return loss_before, loss_after


# ---------------------------------------------------------------------------
# Table-II scheme comparison (DEPRECATED shim — prefer repro.api.Experiment)
# ---------------------------------------------------------------------------


def run_scheme(scheme: str, devices, data: ClassificationData,
               test: ClassificationData, partition: str, periods: int,
               seed: int = 0, b_max: int = 128, base_lr: float = 0.05,
               eval_every: int = 10) -> RunResult:
    """DEPRECATED: run one Table-II scheme and return its trajectory.

    Thin shim kept for existing callers — ``repro.api.Experiment`` runs
    whole scheme grids as bucketed compiled programs.  Return values are
    unchanged from PR 1: the ``individual``/``model_fl`` ledger now comes
    from ``core.scheduler.DevScheduler`` (vectorized, downlink routed
    through the planner's ``rates_down``/``tau_down`` path) which is
    bit-identical to the old hand-rolled per-period loop (test-covered).
    """
    warnings.warn(
        "run_scheme is deprecated; use repro.api.Experiment with "
        "ScenarioSpec(scheme=...) (see README migration table)",
        DeprecationWarning, stacklevel=2)
    if scheme in ("feel", "proposed"):
        sim = FeelSimulation(devices, data, test, partition=partition,
                             policy="proposed", compress=True, b_max=b_max,
                             base_lr=base_lr, seed=seed)
        return sim.run(periods, eval_every)
    if scheme == "gradient_fl":
        sim = FeelSimulation(devices, data, test, partition=partition,
                             policy="full", compress=True, b_max=b_max,
                             base_lr=base_lr, seed=seed)
        r = sim.run(periods, eval_every)
        r.scheme = "gradient_fl"
        return r

    # individual / model_fl: per-device parameter copies.  The planner
    # pre-generates indices + the latency ledger (same rng order as the
    # seed's interleaved loop), device side is one lax.scan.
    k = len(devices)
    parts = (partition_iid(len(data.y), k, seed) if partition == "iid"
             else partition_noniid(data.y, k, seed=seed))
    key = jax.random.key(seed)
    p0 = feel_model.init(key, input_dim=data.x.shape[1])
    dev_params = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (k,) + a.shape).copy(), p0)
    n_params = sum(int(np.prod(np.shape(l)))
                   for l in jax.tree_util.tree_leaves(p0))
    batch = min(b_max, 64)
    sched = DevScheduler(
        devices=devices, parts=parts, batch=batch,
        # payload: parameters, uncompressed (model-based FL uploads the model)
        payload_bits=32.0 * n_params, upload=(scheme == "model_fl"),
        seed=seed, cell=Cell.make(seed))
    horizon = sched.plan_horizon(periods)

    _, (losses, accs) = engine.run_dev_trajectory(
        dev_params, horizon.idx, base_lr, data, test,
        average=(scheme == "model_fl"))
    losses = np.asarray(losses)
    accs = np.asarray(accs)

    res = RunResult(scheme=scheme)
    for period in _eval_points(periods, eval_every):
        res.losses.append(float(losses[period]))
        res.accs.append(float(accs[period]))
        res.times.append(float(horizon.times[period]))
        res.global_batches.append(batch * k)
    return res

"""FEEL training loop (paper §II-A five steps) + the Table-II scheme zoo.

Simulated wall-clock comes from the core latency models (the container has
no radio or edge devices); learning is real JAX compute on synthetic data.

Schemes:
  feel        — the paper's proposal: scheduler-planned B_k/τ_k, compressed
                gradient aggregation (eq. (1)), η ∝ √B.
  gradient_fl — [40]: full-slot batches, equal TDMA slots, compressed grads.
  model_fl    — FedAvg [19]: one local epoch, parameter upload
                (uncompressed payload d·p).
  individual  — no collaboration; models averaged once at the end.

Execution engines (``FeelSimulation.engine``):
  scan   — device-resident (default): the whole trajectory is pre-planned
           into an ``engine.Schedule`` and compiled to a single jitted
           ``jax.lax.scan`` with zero per-period host transfers.
  python — the seed's one-Python-iteration-per-period reference loop with
           ``float()`` syncs; consumes the SAME pre-generated schedule, so
           scan-vs-python is a pure numerics regression check (test-covered)
           and the speed baseline for ``benchmarks/sweep_speed.py``.

Both engines are open-loop in ξ within a run (the paper's known-constant
treatment); realized decays feed the ξ estimator post-hoc so it still
adapts across successive ``run`` calls.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.sbc import compress_dense
from repro.core import DeviceProfile, FeelScheduler
from repro.core.latency import period_latency, uplink_latency
from repro.data.pipeline import (ClassificationData, FederatedBatcher,
                                 partition_iid, partition_noniid)
from repro.fed import engine, feel_model
from repro.fed.engine import Schedule, build_schedule


@dataclass
class RunResult:
    scheme: str
    losses: List[float] = field(default_factory=list)
    accs: List[float] = field(default_factory=list)
    times: List[float] = field(default_factory=list)       # cumulative (s)
    global_batches: List[int] = field(default_factory=list)

    def speed(self, target_acc: float) -> float:
        """Time to reach target accuracy (inf if never)."""
        for a, t in zip(self.accs, self.times):
            if a >= target_acc:
                return t
        return float("inf")


def _eval_points(periods: int, eval_every: int) -> List[int]:
    return [p for p in range(periods)
            if p % eval_every == 0 or p == periods - 1]


@dataclass
class FeelSimulation:
    devices: Sequence[DeviceProfile]
    data: ClassificationData
    test: ClassificationData
    partition: str = "noniid"            # iid | noniid
    policy: str = "proposed"             # core.baselines key
    compress: bool = True
    b_max: int = 128
    base_lr: float = 0.05
    seed: int = 0
    hidden: int = 256
    depth: int = 3
    local_steps: int = 1                 # paper §VII future work: multiple
                                         # local updates per period (tau>1
                                         # FedAvg-style); latency scales the
                                         # local-compute term accordingly
    engine: str = "scan"                 # scan | python (reference loop)

    def __post_init__(self):
        k = len(self.devices)
        if self.partition == "iid":
            self.parts = partition_iid(len(self.data.y), k, self.seed)
        else:
            self.parts = partition_noniid(self.data.y, k, seed=self.seed)
        self.batcher = FederatedBatcher(self.parts, self.b_max, self.seed)
        self.params = feel_model.init(jax.random.key(self.seed), self.hidden,
                                      depth=self.depth,
                                      input_dim=self.data.x.shape[1])
        self.n_params = sum(int(np.prod(np.shape(l)))
                            for l in jax.tree_util.tree_leaves(self.params))
        self.scheduler = FeelScheduler(
            devices=self.devices, n_params=self.n_params, policy=self.policy,
            b_max=self.b_max, base_lr=self.base_lr, seed=self.seed)
        self.residuals = None
        self._grad_fn = jax.jit(jax.vmap(
            jax.grad(feel_model.loss_fn), in_axes=(None, 0, 0, 0)))
        self._loss_fn = jax.jit(feel_model.loss_fn)
        self._acc_fn = jax.jit(feel_model.accuracy)

    # ---- schedule + initial carry (shared by both engines and sweep) -----
    def plan_run(self, periods: int) -> Schedule:
        return build_schedule(self.scheduler, self.batcher, self.devices,
                              periods, self.local_steps)

    def initial_residual(self):
        if self.residuals is not None:
            return self.residuals
        return engine.zero_residual(self.params, self.batcher.k)

    def run(self, periods: int, eval_every: int = 10) -> RunResult:
        sched = self.plan_run(periods)
        evals = _eval_points(periods, eval_every)
        if self.engine == "python":
            losses, accs, decays = self._run_python(sched, evals)
        else:
            self.params, self.residuals, (losses, accs, decays) = \
                engine.run_trajectory(
                    self.params, self.initial_residual(), sched,
                    self.data, self.test, local_steps=self.local_steps,
                    compress=self.compress,
                    ratio=self.scheduler.compression)
            losses = np.asarray(losses)
            accs = np.asarray(accs)
            decays = np.asarray(decays)
        self.scheduler.observe_series(decays, sched.global_batch)
        res = RunResult(scheme=f"feel/{self.policy}")
        for p in evals:
            res.losses.append(float(losses[p]))
            res.accs.append(float(accs[p]))
            res.times.append(float(sched.times[p]))
            res.global_batches.append(int(sched.global_batch[p]))
        return res

    # ---- seed reference path: one FEEL period (Steps 1-5) per Python
    # iteration, float() host syncs each step --------------------------------
    def _run_python(self, sched: Schedule, evals: Sequence[int]):
        periods = sched.periods
        losses = np.zeros(periods)
        accs = np.full(periods, np.nan)
        decays = np.zeros(periods)
        evals = set(evals)
        for p in range(periods):
            loss_before, loss_after = self._python_period(
                sched.idx[p], sched.weight[p], sched.batch[p],
                float(sched.lr[p]))
            losses[p] = loss_after
            decays[p] = loss_before - loss_after
            if p in evals:
                accs[p] = float(self._acc_fn(self.params,
                                             jnp.asarray(self.test.x),
                                             jnp.asarray(self.test.y)))
        return losses, accs, decays

    def _python_period(self, idx, w, bk, lr):
        x = jnp.asarray(self.data.x[idx])            # (K, slot, D)
        y = jnp.asarray(self.data.y[idx])
        wj = jnp.asarray(w)

        loss_before = float(self._loss_fn(
            self.params, x.reshape(-1, x.shape[-1]), y.reshape(-1),
            wj.reshape(-1)))

        if self.local_steps == 1:
            grads = self._grad_fn(self.params, x, y, wj)  # leading K axis
        else:
            dev_params = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.batcher.k,) + a.shape),
                self.params)
            for _ in range(self.local_steps):
                g = jax.vmap(jax.grad(feel_model.loss_fn))(
                    dev_params, x, y, wj)
                dev_params = jax.tree_util.tree_map(
                    lambda p, gg: p - lr * gg, dev_params, g)
            grads = jax.tree_util.tree_map(
                lambda p0, pk: (p0[None] - pk) / lr,
                self.params, dev_params)
        if self.compress:
            grads, self.residuals = compress_dense(
                grads, self.scheduler.compression, self.residuals)
        # eq. (1): weighted average by B_k
        bkj = jnp.asarray(bk, jnp.float32)
        wk = bkj / jnp.sum(bkj)
        agg = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(wk, g, axes=1), grads)
        self.params = jax.tree_util.tree_map(
            lambda p_, g: p_ - lr * g, self.params, agg)

        loss_after = float(self._loss_fn(
            self.params, x.reshape(-1, x.shape[-1]), y.reshape(-1),
            wj.reshape(-1)))
        return loss_before, loss_after


# ---------------------------------------------------------------------------
# Table-II scheme comparison
# ---------------------------------------------------------------------------


def _epoch_latency(devices, parts, batch, rates_up, rates_down, s_bits,
                   frame_up, frame_down, upload: bool) -> float:
    """Latency of one local epoch (+ optional sync upload/download)."""
    t_local = np.array([
        d.local_grad_latency(batch) * max(1, len(p) // batch)
        for d, p in zip(devices, parts)])
    if not upload:
        return float(np.max(t_local))
    K = len(devices)
    tau_u = np.full(K, frame_up / K)
    tau_d = np.full(K, frame_down / K)
    t_up = uplink_latency(s_bits, tau_u, frame_up, rates_up)
    t_down = uplink_latency(s_bits, tau_d, frame_down, rates_down)
    t_upd = np.array([d.update_latency() for d in devices])
    return period_latency(t_local, t_up, t_down, t_upd)


def run_scheme(scheme: str, devices, data: ClassificationData,
               test: ClassificationData, partition: str, periods: int,
               seed: int = 0, b_max: int = 128, base_lr: float = 0.05,
               eval_every: int = 10) -> RunResult:
    """Run one Table-II scheme end-to-end and return its trajectory."""
    if scheme in ("feel", "proposed"):
        sim = FeelSimulation(devices, data, test, partition=partition,
                             policy="proposed", compress=True, b_max=b_max,
                             base_lr=base_lr, seed=seed)
        return sim.run(periods, eval_every)
    if scheme == "gradient_fl":
        sim = FeelSimulation(devices, data, test, partition=partition,
                             policy="full", compress=True, b_max=b_max,
                             base_lr=base_lr, seed=seed)
        r = sim.run(periods, eval_every)
        r.scheme = "gradient_fl"
        return r

    # individual / model_fl: per-device parameter copies, scan-compiled.
    # Host side pre-generates indices + the latency ledger (same rng order
    # as the seed's interleaved loop), device side is one lax.scan.
    k = len(devices)
    parts = (partition_iid(len(data.y), k, seed) if partition == "iid"
             else partition_noniid(data.y, k, seed=seed))
    key = jax.random.key(seed)
    p0 = feel_model.init(key, input_dim=data.x.shape[1])
    dev_params = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (k,) + a.shape).copy(), p0)
    n_params = sum(int(np.prod(np.shape(l)))
                   for l in jax.tree_util.tree_leaves(p0))
    from repro.channels.model import Cell
    cell = Cell.make(seed)
    dist = cell.drop_users(k)
    rng = np.random.default_rng(seed)
    batch = min(b_max, 64)
    # payload: parameters, uncompressed (model-based FL uploads the model)
    s_bits = 32.0 * n_params

    idx = np.empty((periods, k, batch), np.int64)
    times = np.empty(periods)
    t = 0.0
    for period in range(periods):
        idx[period] = np.stack(
            [rng.choice(p, size=batch, replace=len(p) < batch)
             for p in parts])
        rates_up = cell.avg_rate(dist)
        rates_down = cell.avg_rate(dist)
        t += _epoch_latency(devices, parts, batch, rates_up, rates_down,
                            s_bits, cell.cfg.frame_up_s,
                            cell.cfg.frame_down_s,
                            upload=(scheme == "model_fl"))
        times[period] = t

    _, (losses, accs) = engine.run_dev_trajectory(
        dev_params, idx, base_lr, data, test,
        average=(scheme == "model_fl"))
    losses = np.asarray(losses)
    accs = np.asarray(accs)

    res = RunResult(scheme=scheme)
    for period in _eval_points(periods, eval_every):
        res.losses.append(float(losses[period]))
        res.accs.append(float(accs[period]))
        res.times.append(float(times[period]))
        res.global_batches.append(batch * k)
    return res

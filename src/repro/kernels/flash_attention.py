"""Flash attention forward kernel (TPU Pallas).

Online-softmax tiling: grid (batch·heads, q_blocks, kv_blocks) with the
kv axis innermost ("arbitrary" semantics) carrying (acc, m, l) scratch in
VMEM.  Causal + sliding-window masking by absolute positions.  Block
shapes are MXU-aligned (block_q × head_dim and block_k × head_dim tiles);
VMEM working set ≈ (2·block_k + block_q)·hd + block_q·block_k floats.

Validated against kernels.ref.attention_ref in interpret mode on CPU
(tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                 # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    pos_q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    pos_k = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    l_new = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q, k, v: (BH, S, hd) — same head count (caller expands GQA groups)."""
    BH, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    from repro.kernels import pallas_compat as pc

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pc.VMEM((block_q, hd), jnp.float32),   # acc
            pc.VMEM((block_q, 1), jnp.float32),    # running max m
            pc.VMEM((block_q, 1), jnp.float32),    # running denom l
        ],
        compiler_params=pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)

"""Flash-decode kernel (TPU Pallas): one query token per sequence against
a long KV cache — the decode_32k / long_500k hot spot.

Grid (batch·heads, ctx_blocks) with the ctx axis innermost ("arbitrary"),
carrying (acc, m, l) online-softmax state in VMEM; invalid cache slots
(beyond ``pos``, or outside the sliding window for ring buffers) are
masked by absolute position.  VMEM per step ≈ 2·block_s·hd + hd floats.

Oracle: kernels/ref.py::decode_attention_ref (tests sweep ctx/block/hd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, block_s: int, ns: int,
                   window: Optional[int]):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32)                 # (1, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bs, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # absolute positions of this cache block's slots
    idx = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    if window is not None:
        # ring buffer: slot i holds the latest position ≡ i (mod ctx)
        ctx = ns * block_s
        key_pos = pos - ((pos - idx) % ctx)
        valid = (key_pos >= 0) & (key_pos <= pos) & (key_pos > pos - window)
    else:
        valid = idx <= pos
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_bhd(q, k, v, pos, *, window: Optional[int] = None,
                     block_s: int = 512, interpret: bool = False):
    """q: (BH, 1, hd); k/v: (BH, ctx, hd); pos: scalar int32.

    Returns (BH, 1, hd).  ``window`` set => the cache is a ring buffer of
    size ctx (== window allocation) and masking follows absolute order.
    """
    BH, ctx, hd = k.shape
    block_s = min(block_s, ctx)
    assert ctx % block_s == 0
    ns = ctx // block_s
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                               ns=ns, window=window)
    return pl.pallas_call(
        kernel,
        grid=(BH, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pc.SMEM),
            pl.BlockSpec((1, 1, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        scratch_shapes=[
            pc.VMEM((1, hd), jnp.float32),
            pc.VMEM((1, 1), jnp.float32),
            pc.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=pc.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32)[None], q, k, v)

"""jit'd public wrappers for the Pallas kernels.

Backend selection: on TPU the compiled Pallas kernel runs; elsewhere the
wrapper falls back to the jnp oracle (CPU dry-runs lower pure-XLA HLO) or,
when ``interpret=True`` is forced, executes the kernel body in Python —
that is how tests validate the kernels on CPU.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import sbc as _sbc
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, pos_q=None, pos_k=None, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """q: (B,S,Hq,hd); k/v: (B,S,Hkv,hd) — GQA groups expanded internally.

    Positions are assumed contiguous from 0 (full-sequence train/prefill).
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    use_interp = (not _on_tpu()) if interpret is None else interpret
    if interpret is None and not _on_tpu():
        out = _ref.attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = _fa.flash_attention_bhsd(
            qf, kf, vf, causal=causal, window=window,
            block_q=min(block_q, S), block_k=min(block_k, S),
            interpret=use_interp)
    return out.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# flash decode (one token vs long cache)
# ---------------------------------------------------------------------------


def flash_decode(q, k, v, pos, *, window=None, block_s: int = 512,
                 interpret: Optional[bool] = None):
    """q: (B,1,Hq,hd); k/v caches: (B,ctx,Hkv,hd); pos: scalar."""
    from repro.kernels import flash_decode as _fd
    B, _, Hq, hd = q.shape
    ctx, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, 1, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, ctx, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, ctx, hd)
    if interpret is None and not _on_tpu():
        out = _ref.decode_attention_ref(qf, kf, vf, pos, window=window)
    else:
        out = _fd.flash_decode_bhd(
            qf, kf, vf, pos, window=window, block_s=min(block_s, ctx),
            interpret=bool(interpret) if interpret is not None else False)
    return out.reshape(B, Hq, 1, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256,
        interpret: Optional[bool] = None):
    if interpret is None and not _on_tpu():
        return _ref.ssd_ref(x, dt, A, Bm, Cm, chunk)
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=min(chunk, x.shape[1]),
                         interpret=bool(interpret) if interpret is not None
                         else False)


# ---------------------------------------------------------------------------
# SBC compression
# ---------------------------------------------------------------------------


def sbc_compress(x, ratio: float = 0.005, *, block: int = 65536,
                 interpret: Optional[bool] = None):
    """Dense SBC approximation of one tensor via the kernel pipeline."""
    if interpret is None and not _on_tpu():
        return _ref.sbc_ref(x, ratio)
    interp = bool(interpret) if interpret is not None else False
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    block = min(block, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    fp = jnp.pad(flat, (0, pad))
    k = max(1, int(round(n * ratio)))
    thr = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    stats = _sbc.sbc_stats(fp, thr[None], block=block, interpret=interp)[0]
    pos_sum, neg_sum, pos_cnt, neg_cnt = stats
    use_pos = pos_sum >= neg_sum
    mean_mag = jnp.where(use_pos,
                         pos_sum / jnp.maximum(pos_cnt, 1.0),
                         neg_sum / jnp.maximum(neg_cnt, 1.0))
    scalars = jnp.stack([thr,
                         jnp.where(use_pos, mean_mag, 0.0),
                         jnp.where(use_pos, 0.0, -mean_mag)])
    out = _sbc.sbc_apply(fp, scalars, block=block, interpret=interp)
    return out[:n].reshape(x.shape).astype(x.dtype)

"""Version-compat aliases for the Pallas TPU API.

jax renamed ``pltpu.TPUMemorySpace`` -> ``pltpu.MemorySpace`` and
``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` across releases.
The kernels import the names from here so both API generations work.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

MemorySpace = getattr(pltpu, "MemorySpace", None) \
    or getattr(pltpu, "TPUMemorySpace")
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
SMEM = MemorySpace.SMEM
ANY = MemorySpace.ANY
VMEM = pltpu.VMEM

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compression.sbc import sbc_tensor as sbc_ref          # noqa: F401
from repro.models.mamba2 import ssd_reference                     # noqa: F401


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None):
    """q,k,v: (BH, S, hd) -> (BH, S, hd); plain softmax attention."""
    BH, S, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, chunk: int = 256):
    """Returns y only (kernel contract)."""
    y, _ = ssd_reference(x, dt, A, Bm, Cm, min(chunk, x.shape[1]))
    return y


def decode_attention_ref(q, k, v, pos, *, window: Optional[int] = None):
    """q: (BH,1,hd); k/v: (BH,ctx,hd); pos scalar — one-token attention
    over valid cache slots (ring-buffer aware when ``window``)."""
    BH, ctx, hd = k.shape
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    idx = jnp.arange(ctx)
    if window is not None:
        key_pos = pos - ((pos - idx) % ctx)
        valid = (key_pos >= 0) & (key_pos <= pos) & (key_pos > pos - window)
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)

"""Sparse-binary-compression kernels (TPU Pallas): the paper's uplink
compression hot-spot (Step 2, [24]) as a two-kernel pipeline.

  * ``sbc_stats``   — tiled reduction: per-block partial sums/counts of
    positive/negative magnitudes above a threshold (grid over 1-D blocks,
    scratch accumulators, flushed on the last block).
  * ``sbc_apply``   — tiled map: binarize survivors to ±mean-magnitude.

The global top-k threshold itself stays in XLA (jax.lax.top_k): a sort is
not a Pallas-shaped problem on TPU — the *bandwidth-bound streaming passes*
are, which is exactly what these kernels tile.  Composition + oracle:
kernels/ops.py vs compression.sbc.sbc_tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc


def _stats_kernel(x_ref, thr_ref, o_ref, acc_ref, *, nb: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    thr = thr_ref[0]
    mag = jnp.abs(x)
    keep = mag >= thr
    pos = keep & (x > 0)
    neg = keep & (x < 0)
    acc_ref[0, 0] += jnp.sum(jnp.where(pos, mag, 0.0))
    acc_ref[0, 1] += jnp.sum(jnp.where(neg, mag, 0.0))
    acc_ref[0, 2] += jnp.sum(pos.astype(jnp.float32))
    acc_ref[0, 3] += jnp.sum(neg.astype(jnp.float32))

    @pl.when(bi == nb - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def sbc_stats(x_flat, thr, *, block: int = 65536, interpret: bool = False):
    """x_flat: (n,) padded to block multiple; returns (1,4) f32
    [pos_sum, neg_sum, pos_cnt, neg_cnt]."""
    n = x_flat.shape[0]
    assert n % block == 0
    nb = n // block
    return pl.pallas_call(
        functools.partial(_stats_kernel, nb=nb),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec(memory_space=pc.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 4), jnp.float32),
        scratch_shapes=[pc.VMEM((1, 4), jnp.float32)],
        compiler_params=pc.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x_flat, thr)


def _apply_kernel(x_ref, sc_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    thr, val_pos, val_neg = sc_ref[0], sc_ref[1], sc_ref[2]
    mag = jnp.abs(x)
    keep = mag >= thr
    out = jnp.where(keep & (x > 0), val_pos,
                    jnp.where(keep & (x < 0), val_neg, 0.0))
    o_ref[...] = out.astype(o_ref.dtype)


def sbc_apply(x_flat, scalars, *, block: int = 65536,
              interpret: bool = False):
    """scalars: (3,) f32 [thr, val_pos, val_neg] (val for dropped group = 0)."""
    n = x_flat.shape[0]
    assert n % block == 0
    nb = n // block
    return pl.pallas_call(
        _apply_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec(memory_space=pc.SMEM),
        ],
        out_specs=pl.BlockSpec((block,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((n,), x_flat.dtype),
        compiler_params=pc.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x_flat, scalars)

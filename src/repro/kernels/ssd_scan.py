"""Mamba2 SSD chunked-scan kernel (TPU Pallas).

One grid step processes one (batch, head, chunk) tile: the intra-chunk
quadratic block (chunk × chunk, MXU-friendly) plus the inter-chunk state
recurrence carried in a VMEM scratch (P × N floats per (b,h) — the chunk
axis is innermost/"arbitrary" so the scratch persists across chunks).

VMEM working set per step ≈ chunk·(P + 2N) + chunk² + P·N floats
(chunk=256, P=64, N=128: ~0.4 MB) — far under the ~16 MiB budget, leaving
room for double buffering.

Oracle: repro.models.mamba2.ssd_reference (tests sweep shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc


def _segsum(a):
    """(L,) -> (L, L) lower-tri sum_{j<k<=i} a[k]; -inf above diagonal."""
    L = a.shape[0]
    cs = jnp.cumsum(a)
    out = cs[:, None] - cs[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (l, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (l,)
    A = a_ref[0].astype(jnp.float32)              # scalar
    Bm = b_ref[0, :, 0].astype(jnp.float32)       # (l, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)       # (l, N)

    dA = dt * A                                   # (l,)
    dA_cum = jnp.cumsum(dA)
    Lmat = jnp.exp(_segsum(dA))                   # (l, l)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]                         # (l, P)
    y_diag = jax.lax.dot_general(scores * Lmat, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_ref[...]                        # (P, N)
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(dA_cum)[:, None]      # (l, P)

    decay_out = jnp.exp(dA_cum[-1] - dA_cum)      # (l,)
    upd = jax.lax.dot_general(xdt, Bm * decay_out[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = state * jnp.exp(dA_cum[-1]) + upd

    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = False):
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,G,N) -> y (B,S,H,P).

    Returns only y (the final state is re-derivable; the train path does
    not need it).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pc.VMEM((P, N), jnp.float32)],
        compiler_params=pc.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and
extract roofline terms.  MUST set the placeholder device count before any
other import — jax locks the device count on first init."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
from functools import partial  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED, get_arch, get_shape, SHAPES  # noqa: E402
from repro.fed.train_step import (TrainState, input_specs,       # noqa: E402
                                  make_prefill_step, make_serve_step,
                                  make_train_step)
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch import sharding as shd                         # noqa: E402
from repro.launch import hlo_cost                                # noqa: E402
from repro.models.model import Runtime, param_spec               # noqa: E402
from repro.optim import momentum                                 # noqa: E402

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

# long-context policy (DESIGN.md §5): full-attention GQA archs use the
# sliding-window variant at 500k; MLA/SSM/hybrid run natively.
LONG_CTX_WINDOW = 8192

def runtime_for(cfg, shape, multi_pod: bool = False):
    window = None
    if (shape.name == "long_500k" and cfg.attn_kind == "gqa"
            and cfg.n_heads and cfg.family not in ("ssm",)):
        window = LONG_CTX_WINDOW
    return Runtime(dtype=jnp.bfloat16, attn_impl="blockwise", block_q=512,
                   window=window, remat=(shape.mode == "train"),
                   moe_shard_axes=(("pod", "data") if multi_pod
                                   else ("data",)))


# ---------------------------------------------------------------------------
# model-flops accounting
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: one token


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------


def lower_pair(arch: str, shape_name: str, multi_pod: bool, rt=None,
               opt=None, zero1: bool = False):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    rt = rt or runtime_for(cfg, shape, multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape, rt)
    pspec = param_spec(cfg, rt.dtype)

    with mesh:
        if shape.mode == "train":
            opt = opt or momentum(0.9)
            state_spec = jax.eval_shape(
                lambda: TrainState(pspec, opt.init(pspec),
                                   jnp.zeros((), jnp.int32)))
            step = make_train_step(cfg, rt, opt)
            st_sh = (shd.state_shardings_zero1(mesh, state_spec) if zero1
                     else shd.state_shardings(mesh, state_spec))
            in_sh = (st_sh, shd.batch_shardings(mesh, specs), None)
            out_sh = (st_sh, None)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh, donate_argnums=(0,)
                              ).lower(state_spec, specs, 1e-2)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, rt)
            nd = 4 if cfg.n_codebooks > 1 else 3
            from repro.models.layers import padded_vocab
            lsh = shd.logits_sharding(mesh, nd, shape.global_batch,
                                      padded_vocab(cfg.vocab))
            in_sh = (shd.params_shardings(mesh, pspec),
                     shd.batch_shardings(mesh, specs))
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=lsh).lower(pspec, specs)
        else:  # decode
            step = make_serve_step(cfg, rt)
            nd = 4 if cfg.n_codebooks > 1 else 3
            from repro.models.layers import padded_vocab
            lsh = shd.logits_sharding(mesh, nd, shape.global_batch,
                                      padded_vocab(cfg.vocab))
            cache_sh = shd.cache_shardings(mesh, specs["cache"])
            tok_sh = shd.decode_input_shardings(mesh, specs)["tokens"]
            in_sh = (shd.params_shardings(mesh, pspec), cache_sh, tok_sh)
            out_sh = (lsh, cache_sh)
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=(1,)
                              ).lower(pspec, specs["cache"], specs["tokens"])
    return cfg, shape, mesh, lowered


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             collect: bool = True, rt=None, opt=None,
             zero1: bool = False) -> dict:
    t0 = time.time()
    cfg, shape, mesh, lowered = lower_pair(arch, shape_name, multi_pod,
                                           rt=rt, opt=opt, zero1=zero1)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    chips = 512 if multi_pod else 256
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:                                    # noqa: BLE001
        mem_info = {"error": str(e)}

    # structural cost with while-loop trip counts (hlo_cost.py)
    totals = hlo_cost.analyze(compiled.as_text())
    flops = totals.flops
    bytes_acc = totals.bytes
    coll_bytes, coll_by_op = totals.collective_bytes, totals.collective_by_op

    mf = model_flops(cfg, shape)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "mode": shape.mode,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll_bytes,
        "collective_by_op": coll_by_op,
        "memory": mem_info,
        **terms,
        "dominant": dominant,
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / (chips * flops)) if flops else None,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    results = []
    for a in archs:
        for s in shapes:
            try:
                r = run_pair(a, s, args.multi_pod)
                print(f"[dryrun] {a} x {s} x {r['mesh']}: OK "
                      f"dominant={r['dominant']} "
                      f"compute={r['compute_s']:.3e}s "
                      f"memory={r['memory_s']:.3e}s "
                      f"collective={r['collective_s']:.3e}s "
                      f"(compile {r['compile_s']}s)", flush=True)
            except Exception as e:                            # noqa: BLE001
                r = {"arch": a, "shape": s,
                     "mesh": "2x16x16" if args.multi_pod else "16x16",
                     "error": f"{type(e).__name__}: {e}"}
                print(f"[dryrun] {a} x {s}: FAIL {r['error']}",
                      flush=True)
            results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
    fails = [r for r in results if "error" in r]
    print(f"[dryrun] {len(results) - len(fails)}/{len(results)} OK")
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()

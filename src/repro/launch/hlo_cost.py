"""Structural cost model over optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop (lax.scan) body ONCE —
useless for scanned layer stacks.  This parser rebuilds the three roofline
inputs with loop trip counts applied:

  * FLOPs   — 2·M·N·K for every ``dot`` (contracting dims from the HLO
    attributes), + 1/elem for arithmetic elementwise/reduce ops.
  * HBM bytes — anchor-op fusion model: only ops that force HBM
    round-trips on TPU count traffic (dot/conv, reduce, dynamic-(update-)
    slice, gather/scatter, copy/concatenate/sort, collectives) — result +
    operand bytes each.  Elementwise / broadcast / convert / select chains
    are treated as fused into their anchors (zero traffic), matching what
    the TPU backend actually emits; the CPU backend we parse materializes
    them, so counting them would overstate the memory term ~10×.
  * Collective bytes — result bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute ops.

While-loop trip counts come from XLA's ``known_trip_count`` backend config
(always present for lax.scan loops).  Validated against
``cost_analysis()`` on loop-free modules in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e4m3": 1, "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4,
                "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARITH = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
          "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "negate",
          "abs", "floor", "ceil", "sign", "cosine", "sine", "logistic",
          "expm1", "log1p", "atan2", "remainder"}

# ops whose operands+results are real HBM traffic on TPU (everything else
# is assumed fused into one of these anchors); `fusion` counts its RESULT
# only — operand reads are attributed to the producing op's write.
# Collectives are accounted in the collective term, not HBM bytes.
_BYTE_ANCHORS = {"dot", "convolution", "reduce", "reduce-window",
                 "dynamic-slice", "dynamic-update-slice", "gather",
                 "scatter", "copy", "concatenate", "sort", "fusion",
                 "custom-call", "rng-bit-generator", "pad"}
_RESULT_ONLY = {"fusion"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        nb = _DTYPE_BYTES.get(m.group(1))
        if nb is None:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * nb
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


def _dims_of(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


# tuple shapes may carry /*index=N*/ comments — allow anything but parens
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:\S+))\s+"
    r"([\w\-]+)\(")
# fallback for nested-tuple shapes (e.g. while carries holding pytrees):
# non-greedy shape up to the op token — accepted only for known ops
_INSTR_FALLBACK_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_FALLBACK_OPS = {"while", "fusion", "call", "conditional", "custom-call",
                 "dot", "copy", "tuple", "get-tuple-element", "dynamic-slice",
                 "dynamic-update-slice", "all-reduce", "all-gather",
                 "reduce-scatter", "all-to-all", "collective-permute",
                 "all-reduce-start", "all-gather-start", "optimization-barrier"}
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            cur = Computation(cm.group(2))
            comps[cur.name] = cur
            if cm.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            fm = _INSTR_FALLBACK_RE.match(line)
            if fm and fm.group(3) in _FALLBACK_OPS:
                im = fm
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), line)
            cur.instrs.append(ins)
            cur.shapes["%" + ins.name] = ins.shape
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res = _dims_of(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    operands = _operands(ins)
    lhs = operands[0] if operands else None
    lhs_shape = comp.shapes.get(lhs, "")
    if not lhs_shape:
        # older dumps inline the operand shape: dot(f32[M,K]{..} %a, ...);
        # _dims_of picks the first (lhs) shape in the operand text
        pos = ins.line.find(f" {ins.op}(")
        om = re.search(r"\(([^)]*)\)", ins.line[pos:]) if pos >= 0 else None
        if om:
            lhs_shape = om.group(1)
    lhs_dims = _dims_of(lhs_shape)
    k = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                k *= lhs_dims[di]
    out = 1
    for d in res:
        out *= d
    return 2.0 * out * k


def _operands(ins: Instr):
    """Operand names of an instruction.

    Handles both HLO text generations: ``op(%a, %b)`` and the older dumps
    that inline operand shapes — ``op(f32[8,16]{1,0} %a, ...)`` — by
    keeping only the trailing ``%name`` token of each operand.
    """
    pos = ins.line.find(f" {ins.op}(")
    om = re.search(r"\(([^)]*)\)", ins.line[pos:]) if pos >= 0 else None
    if not om:
        return []
    names = re.findall(r"%[\w\.\-]+", om.group(1))
    if names:
        return names
    return [o.strip() for o in om.group(1).split(",") if o.strip()]


def _instr_bytes(ins: Instr, comp: Computation, comps) -> float:
    """HBM bytes of one anchor instruction.

    dynamic-update-slice writes only the slice (the buffer is aliased), so
    it costs 2×update — the same applies to a fusion whose root is a DUS
    (the lax.scan stacking pattern: counting the whole stacked buffer per
    iteration would overstate traffic by the layer count).
    """
    base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
    ops_ = _operands(ins)
    if base == "dynamic-update-slice":
        upd = comp.shapes.get(ops_[1], "") if len(ops_) > 1 else ""
        return 2.0 * shape_bytes(upd)
    if base == "fusion":
        fm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        callee = comps.get(fm.group(1)) if fm else None
        if callee and callee.instrs:
            root = callee.instrs[-1]
            # a DUS anywhere in the fused computation whose result shape
            # matches the fusion result = in-place stack update (the
            # lax.scan remat-stash pattern, possibly behind a bitcast)
            for ei in callee.instrs:
                if (ei.op == "dynamic-update-slice"
                        and _SHAPE_RE.search(ei.shape)
                        and ei.shape.split("{")[0] ==
                        ins.shape.split("{")[0]):
                    eops = _operands(ei)
                    upd = (callee.shapes.get(eops[1], "")
                           if len(eops) > 1 else "")
                    if upd:
                        return 2.0 * shape_bytes(upd)
            if root.op == "dynamic-update-slice":
                rops = _operands(root)
                upd = callee.shapes.get(rops[1], "") if len(rops) > 1 else ""
                if upd:
                    return 2.0 * shape_bytes(upd)
            if root.op == "tuple":
                # per-element: DUS elements cost 2x their update slice
                by_name = {i.name: i for i in callee.instrs}
                b = 0.0
                for o in _operands(root):
                    ei = by_name.get(o.lstrip("%"))
                    if ei is not None and ei.op == "dynamic-update-slice":
                        eops = _operands(ei)
                        upd = (callee.shapes.get(eops[1], "")
                               if len(eops) > 1 else "")
                        b += 2.0 * shape_bytes(upd)
                    elif ei is not None:
                        b += shape_bytes(ei.shape)
                    else:
                        b += shape_bytes(callee.shapes.get(o, ""))
                return b
        return shape_bytes(ins.shape)            # result only
    b = shape_bytes(ins.shape)
    for o in ops_:
        if o.startswith("%"):
            b += shape_bytes(comp.shapes.get(o, ""))
    return b


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = field(default_factory=dict)

    def scaled(self, f: float) -> "CostTotals":
        return CostTotals(self.flops * f, self.bytes * f,
                          self.collective_bytes * f,
                          {k: v * f for k, v in self.collective_by_op.items()})

    def add(self, o: "CostTotals"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_by_op.items():
            self.collective_by_op[k] = self.collective_by_op.get(k, 0) + v


def analyze(text: str) -> CostTotals:
    comps, entry = parse_module(text)
    if entry is None:
        return CostTotals()
    memo: Dict[Tuple[str, bool], CostTotals] = {}

    def visit(name: str, fused: bool, stack) -> CostTotals:
        key = (name, fused)
        if key in memo:
            return memo[key]
        if name not in comps or name in stack:
            return CostTotals()
        comp = comps[name]
        tot = CostTotals()
        for ins in comp.instrs:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            # flops
            if base == "dot":
                tot.flops += _dot_flops(ins, comp)
            elif base in _ARITH:
                tot.flops += shape_elems(ins.shape)
            elif base == "reduce":
                # approx: one op per input element
                om = re.search(r"reduce\(([^)]*)\)", ins.line)
                if om:
                    first = om.group(1).split(",")[0].strip()
                    tot.flops += shape_elems(comp.shapes.get(first, ""))
            # collectives.  The CPU backend's AllReducePromotion pass
            # upcasts bf16 all-reduces to f32 (to_apply=%..._promoted);
            # TPUs reduce in bf16 natively, so promoted ARs are counted
            # at their un-promoted width.
            if base in _COLLECTIVES:
                b = shape_bytes(ins.shape)
                if "promoted" in ins.line and "f32" in ins.shape:
                    b /= 2.0
                tot.collective_bytes += b
                tot.collective_by_op[base] = \
                    tot.collective_by_op.get(base, 0.0) + b
            # bytes (top level, anchor ops only — see module docstring)
            if not fused and base in _BYTE_ANCHORS:
                b = _instr_bytes(ins, comp, comps)
                tot.bytes += b
            elif not fused and base in _ARITH:
                # backends that don't fuse (CPU dumps): a top-level
                # elementwise op is its own fusion root — count the write
                tot.bytes += shape_bytes(ins.shape)
            # recursion
            if base == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if fm:
                    sub = visit(fm.group(1), True, stack | {name})
                    tot.flops += sub.flops
                    tot.collective_bytes += sub.collective_bytes
                    for k, v in sub.collective_by_op.items():
                        tot.collective_by_op[k] = \
                            tot.collective_by_op.get(k, 0) + v
            elif base == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                tm = re.search(r'known_trip_count.\s*:\s*.\s*"n"\s*:\s*"?(\d+)',
                               ins.line)
                trips = int(tm.group(1)) if tm else 1
                if bm:
                    sub = visit(bm.group(1), False, stack | {name})
                    tot.add(sub.scaled(trips))
            elif base in ("call", "conditional", "async-start"):
                for fm in re.finditer(
                        r"(?:calls|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-, %]+)",
                        ins.line):
                    for cn in re.findall(r"[\w\.\-]+", fm.group(1)):
                        sub = visit(cn, fused, stack | {name})
                        tot.add(sub)
        memo[key] = tot
        return tot

    return visit(entry, False, frozenset())

"""Production mesh builders (function, not module constant — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Every axis that carries the batch (all but 'model')."""
    return tuple(n for n in mesh.axis_names if n != "model")


def data_size(mesh) -> int:
    out = 1
    for n in data_axes(mesh):
        out *= mesh.shape[n]
    return out


# ---------------------------------------------------------------------------
# sweep-batch mesh: a flat "batch" axis over every available accelerator,
# used by api.Experiment to shard the flattened (scenario × seed) axis of a
# bucket.  One device (CPU CI) degenerates to plain placement — the same
# code path is the single-device fallback.
# ---------------------------------------------------------------------------


def make_batch_mesh(max_devices: int | None = None) -> Mesh:
    """1-D mesh over (up to ``max_devices``) available devices."""
    devs = jax.devices()
    if max_devices is not None:
        devs = devs[:max_devices]
    return Mesh(np.array(devs), ("batch",))


def batch_sharding(mesh) -> NamedSharding:
    """Leading axis split over "batch", remaining dims replicated."""
    return NamedSharding(mesh, P("batch"))


def replicated_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def pad_batch(n: int, mesh) -> int:
    """Rows to append so a length-``n`` batch axis divides the mesh."""
    return (-n) % mesh.devices.size


def ensure_batch_mesh(mesh) -> Mesh:
    """Validate a sweep mesh: the executors shard the flattened
    (scenario × seed) axis over a ``"batch"`` axis, so a mesh without one
    (e.g. the 2-D production meshes above) fails fast here instead of
    deep inside ``device_put``."""
    if "batch" not in getattr(mesh, "axis_names", ()):
        raise ValueError(
            f"expected a 1-D sweep mesh with a 'batch' axis "
            f"(launch.mesh.make_batch_mesh); got axes "
            f"{getattr(mesh, 'axis_names', ())!r}")
    return mesh

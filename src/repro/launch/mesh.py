"""Production mesh builders (function, not module constant — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Every axis that carries the batch (all but 'model')."""
    return tuple(n for n in mesh.axis_names if n != "model")


def data_size(mesh) -> int:
    out = 1
    for n in data_axes(mesh):
        out *= mesh.shape[n]
    return out

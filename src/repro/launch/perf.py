"""§Perf hillclimb driver: re-lower a (arch × shape) pair under candidate
optimizations and report the roofline-term deltas (EXPERIMENTS.md §Perf).

Usage:
  python -m repro.launch.perf --arch qwen1.5-4b --shape train_4k \
      --variants baseline,flashjnp,seq_parallel,flashjnp+seq_parallel
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.launch.dryrun import run_pair, runtime_for  # noqa: E402
from repro.configs import get_arch, get_shape          # noqa: E402
from repro.optim import momentum                       # noqa: E402


def build(variant: str, cfg, shape, multi_pod: bool = False):
    """variant: '+'-joined knobs -> (rt, opt, zero1)."""
    rt = runtime_for(cfg, shape, multi_pod)
    opt = None
    zero1 = False
    for knob in variant.split("+"):
        if knob in ("baseline", ""):
            continue
        elif knob == "flashjnp":
            rt = dataclasses.replace(rt, attn_impl="flashjnp")
        elif knob == "blockwise":
            rt = dataclasses.replace(rt, attn_impl="blockwise")
        elif knob == "seq_parallel":
            rt = dataclasses.replace(rt, seq_parallel=True)
        elif knob == "no_remat":
            rt = dataclasses.replace(rt, remat=False)
        elif knob == "remat_attn":
            rt = dataclasses.replace(rt, remat_attn=True)
        elif knob == "opt_bf16":
            opt = momentum(0.9, state_dtype=jnp.bfloat16)
        elif knob == "zero1":
            zero1 = True
        elif knob == "cap1.0":
            rt = dataclasses.replace(rt, capacity_factor=1.0)
        elif knob == "expert_choice":
            rt = dataclasses.replace(rt, moe_impl="expert_choice")
        elif knob == "gqa_expand":
            rt = dataclasses.replace(rt, gqa_expand=True)
        elif knob.startswith("window"):
            rt = dataclasses.replace(rt, window=int(knob[6:]))
        elif knob.startswith("blockq"):
            rt = dataclasses.replace(rt, block_q=int(knob[6:]))
        else:
            raise ValueError(f"unknown knob {knob!r}")
    return rt, opt, zero1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    results = []
    base = None
    for variant in args.variants.split(","):
        rt, opt, zero1 = build(variant, cfg, shape, args.multi_pod)
        try:
            r = run_pair(args.arch, args.shape, args.multi_pod, rt=rt,
                         opt=opt, zero1=zero1)
            r["variant"] = variant
            if variant == "baseline":
                base = r
            d = ""
            if base is not None and r is not base:
                d = ("  Δcompute={:+.1%} Δmemory={:+.1%} Δcoll={:+.1%}"
                     .format(r["compute_s"] / base["compute_s"] - 1,
                             r["memory_s"] / base["memory_s"] - 1,
                             (r["collective_s"] / base["collective_s"] - 1)
                             if base["collective_s"] else 0.0))
            peak = (r.get("memory") or {}).get("temp_bytes")
            print(f"[perf] {args.arch} x {args.shape} [{variant}]: "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s"
                  f" coll={r['collective_s']:.3e}s"
                  f" temp={peak/1e9 if peak else 0:.1f}GB{d}", flush=True)
        except Exception as e:                             # noqa: BLE001
            r = {"variant": variant, "arch": args.arch,
                 "shape": args.shape, "error": f"{type(e).__name__}: {e}"}
            print(f"[perf] {variant}: FAIL {r['error']}", flush=True)
        results.append(r)
    if args.out:
        with open(args.out, "a") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()

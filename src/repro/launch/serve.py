"""Batched LLM token-decode driver: prefill a prompt batch, then decode
with the KV/SSM cache (the decode_32k / long_500k path at laptop scale).

Despite the filename this is *token decoding* for the model zoo, not the
FEEL experiment service — that is ``repro.serve`` (streaming scenario
admissions, compile cache, preemptive chunk scheduling).  Demo entry
point: ``examples/decode_batched.py``."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.fed.train_step import make_serve_step
from repro.models.model import Runtime, init, init_cache, decode_step, forward


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    rt = Runtime(dtype=jnp.float32, attn_impl="naive")
    key = jax.random.key(args.seed)
    params = init(cfg, key)

    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.n_codebooks > 1 else (args.batch, args.prompt_len))
    prompt = jax.random.randint(key, shape, 0, cfg.vocab)

    serve = jax.jit(make_serve_step(cfg, rt), donate_argnums=(1,))
    cache = init_cache(cfg, args.batch, args.ctx, rt)

    # prefill by stepping the decode path over the prompt (CPU-scale demo;
    # the production prefill path is launch/dryrun.py's prefill_32k lowering)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        tok = prompt[:, t:t + 1]
        logits, cache = serve(params, cache, tok)
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    for _ in range(args.gen):
        nxt = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        if cfg.n_codebooks > 1:
            nxt = nxt[:, 0][:, None, :] if nxt.ndim == 3 else nxt
        else:
            nxt = nxt[:, :1]
        logits, cache = serve(params, cache, nxt)
        toks.append(np.asarray(nxt))
    dt = time.time() - t0
    tps = args.gen * args.batch / dt
    print(f"[serve] {cfg.name}: batch={args.batch} prefill={t_prefill:.2f}s "
          f"decode {args.gen} toks/seq at {tps:.1f} tok/s (CPU)")
    out = np.concatenate(toks, axis=1)
    print(f"[serve] sample continuation (seq 0): {out[0].reshape(-1)[:16]}")
    return tps


if __name__ == "__main__":
    main()

"""GSPMD sharding rules for params / optimizer state / batches / caches.

Baseline layout (DESIGN.md §6): tensor-parallel over ``model`` (attention
heads & projections, FFN hidden, experts, vocab), batch over the data axes
(× pod), replicated small tensors.  Uneven dims (arctic's 56 heads) rely
on GSPMD implicit padding.  A dimension is sharded only when doing so is
sane (dim >= axis size or explicitly allowed uneven).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _axis_size(mesh, name) -> int:
    return mesh.shape[name]


def _spec_shard_dim(ndim: int, dim: int, axis="model") -> P:
    parts = [None] * ndim
    parts[dim] = axis
    return P(*parts)


# each rule: (path regex, function(shape)->dim to shard on "model" | None)
_PARAM_RULES = [
    # embeddings / unembeddings: shard the (padded) vocab dim
    (r"embed.*table", lambda s: len(s) - 2),
    (r"lm_head", lambda s: len(s) - 1),
    # attention projections
    (r"attn.*(wq|wk|wv|w_q|w_uq|w_uk|w_uv)'?\]?$", lambda s: len(s) - 1),
    (r"attn.*(wo)'?\]?$", lambda s: len(s) - 2),
    (r"attn.*(bq|bk|bv)'?\]?$", lambda s: len(s) - 1),
    # low-rank MLA down-projections & norms: small -> replicate
    (r"attn.*(w_dkv|w_dq|w_kr|kv_norm|q_norm)", lambda s: None),
    # dense FFN
    (r"(ffn|shared|dense)'?\]\['w_(gate|up)", lambda s: len(s) - 1),
    (r"(ffn|shared|dense)'?\]\['w_down", lambda s: len(s) - 2),
    # MoE experts: expert-parallel over the expert dim
    (r"experts.*w_(gate|up|down)", lambda s: len(s) - 3),
    (r"router", lambda s: None),
    # mamba2 mixer
    (r"mixer'?\]\['in_proj", lambda s: len(s) - 1),
    (r"mixer'?\]\['out_proj", lambda s: len(s) - 2),
]


def param_spec_for(path: str, shape, mesh) -> P:
    msize = _axis_size(mesh, "model")
    for pat, dimfn in _PARAM_RULES:
        if re.search(pat, path):
            dim = dimfn(shape)
            if dim is None or dim < 0:
                return P()
            size = shape[dim]
            # shard when >= axis (uneven allowed: GSPMD pads), else replicate
            if size >= msize:
                return _spec_shard_dim(len(shape), dim)
            return P()
    return P()  # norms, biases, scalars, conv, A_log, D, router, ...


def params_shardings(mesh, params_spec):
    def one(path, leaf):
        spec = param_spec_for(jax.tree_util.keystr(path), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_spec)


def state_shardings(mesh, state_spec):
    """TrainState(params, opt, step): opt leaves inherit the param rules
    (their tree paths embed the param path), step/scalars replicate."""
    def one(path, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        spec = param_spec_for(jax.tree_util.keystr(path), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state_spec)


def state_shardings_zero1(mesh, state_spec):
    """ZeRO-1 variant: OPTIMIZER leaves are additionally sharded over the
    data axes on their largest not-yet-sharded divisible dim (params keep
    the TP layout; GSPMD inserts the reduce-scatter/all-gather pair)."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= _axis_size(mesh, a)
    dspec = daxes if len(daxes) > 1 else daxes[0]

    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        spec = param_spec_for(p, leaf.shape, mesh)
        if p.startswith("[<flat index 1>]"):     # TrainState.opt subtree
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            cands = sorted(range(len(leaf.shape)),
                           key=lambda d: -leaf.shape[d])
            for d in cands:
                if parts[d] is None and leaf.shape[d] % dsize == 0:
                    parts[d] = dspec
                    break
            spec = P(*parts)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state_spec)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def _batch_spec(mesh, shape, *, model_dims=()) -> P:
    """Shard dim 0 over the data axes when divisible; given ``model_dims``
    additionally shard that dim over 'model' when divisible."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= _axis_size(mesh, a)
    parts: list = [None] * len(shape)
    if shape and shape[0] % dsize == 0 and shape[0] > 0:
        parts[0] = daxes if len(daxes) > 1 else daxes[0]
    msize = _axis_size(mesh, "model")
    for d in model_dims:
        if d < len(shape) and shape[d] % msize == 0 and shape[d] >= msize:
            parts[d] = "model"
    return P(*parts)


def batch_shardings(mesh, batch_spec):
    """For train/prefill input dicts: tokens/labels/weights/prefix."""
    def one(path, leaf):
        return NamedSharding(mesh, _batch_spec(mesh, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, batch_spec)


def cache_shardings(mesh, cache_spec):
    """Decode cache: dim 0 is the layer stack; dim 1 the batch; shard the
    head-ish dim over 'model' when divisible."""
    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        shape = leaf.shape
        if getattr(leaf, "ndim", 0) <= 1:        # pos
            return NamedSharding(mesh, _batch_spec(mesh, shape))
        daxes = data_axes(mesh)
        dsize = 1
        for a in daxes:
            dsize *= _axis_size(mesh, a)
        msize = _axis_size(mesh, "model")
        parts: list = [None] * len(shape)
        if shape[1] % dsize == 0:
            parts[1] = daxes if len(daxes) > 1 else daxes[0]
        if "'k'" in p or "'v'" in p:             # (L,B,ctx,Hkv,hd)
            # sequence-sharded KV cache (flash-decode style): the ctx dim is
            # always a multiple of the axis; softmax combines via tiny
            # all-reduces instead of full-cache all-gathers.
            if shape[2] % msize == 0:
                parts[2] = "model"
            elif shape[3] % msize == 0:
                parts[3] = "model"
        elif "'ckv'" in p:                        # (L,B,ctx,width)
            if shape[2] % msize == 0:
                parts[2] = "model"
        elif "'ssm'" in p:                        # (L,B,H,P,N)
            if shape[2] % msize == 0:
                parts[2] = "model"
        elif "'conv'" in p:                       # (L,B,W,CH)
            if shape[3] % msize == 0:
                parts[3] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(one, cache_spec)


def decode_input_shardings(mesh, specs):
    """{"cache": ..., "tokens": (B,1)}"""
    return {
        "cache": cache_shardings(mesh, specs["cache"]),
        "tokens": NamedSharding(mesh,
                                _batch_spec(mesh, specs["tokens"].shape)),
    }


def logits_sharding(mesh, ndim: int, batch: int, vocab: int
                    ) -> NamedSharding:
    """(B, S, V) / (B, S, ncb, V): batch over data, vocab over model —
    each only when divisible."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= _axis_size(mesh, a)
    parts: list = [None] * ndim
    if batch % dsize == 0:
        parts[0] = daxes if len(daxes) > 1 else daxes[0]
    if vocab % _axis_size(mesh, "model") == 0:
        parts[-1] = "model"
    return NamedSharding(mesh, P(*parts))

"""End-to-end FEEL training driver for the transformer zoo.

Maps the paper's K edge devices onto data-parallel groups: each period the
FEEL scheduler plans (B_k, τ_k) from simulated channels; B_k becomes the
per-group example mask of the global batch; eq. (1) aggregation happens
inside the jit'd train step as the weighted data-parallel gradient mean.

CPU-friendly by default (reduced config, 1-device mesh); pass --full to
use the exact assigned config (requires the production mesh / TPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import DeviceProfile, FeelScheduler
from repro.data.pipeline import TokenData
from repro.fed.train_step import TrainState, make_train_step
from repro.models.model import Runtime, init
from repro.optim import momentum
from repro import checkpoint


def device_fleet(k: int):
    """Heterogeneous CPU fleet like the paper: 0.7/1.4/2.1 GHz tiers."""
    tiers = [0.7e9, 1.4e9, 2.1e9]
    return [DeviceProfile(kind="cpu", f_cpu=tiers[i % 3]) for i in range(k)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--devices", type=int, default=4, help="FEEL K")
    ap.add_argument("--slot", type=int, default=8,
                    help="max examples per device per period (B^max)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--policy", default="proposed",
                    choices=["proposed", "online", "full", "random"])
    ap.add_argument("--compress-uplink", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) architecture")
    ap.add_argument("--layers", type=int, default=0,
                    help="override layer count (scaled custom variant)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (heads scale with width/64)")
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if args.layers or args.d_model:
        import dataclasses
        d = args.d_model or cfg.d_model
        heads = max(4, d // 64) if cfg.n_heads else 0
        cfg = dataclasses.replace(
            cfg, name=f"{cfg.name}-custom",
            n_layers=args.layers or cfg.n_layers, d_model=d,
            n_heads=heads, n_kv_heads=min(cfg.n_kv_heads, heads) or heads,
            head_dim=64 if heads else 0,
            d_ff=4 * d if cfg.d_ff else 0)
    rt = Runtime(dtype=jnp.float32, attn_impl="naive")
    key = jax.random.key(args.seed)
    params = init(cfg, key)
    opt = momentum(0.9)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"K={args.devices} devices, policy={args.policy}")

    devs = device_fleet(args.devices)
    sched = FeelScheduler(devices=devs, n_params=n_params,
                          policy=args.policy, b_max=args.slot,
                          base_lr=args.lr, ref_batch=args.devices * args.slot,
                          seed=args.seed)
    data = TokenData.synthetic(n=4096, seq=args.seq,
                               vocab=min(cfg.vocab, 512), seed=args.seed)
    rng = np.random.default_rng(args.seed)

    step_fn = jax.jit(make_train_step(cfg, rt, opt,
                                      compress_uplink=args.compress_uplink))
    sim_time, t0 = 0.0, time.time()
    prev_loss = None
    for step in range(args.steps):
        plan = sched.plan()
        # per-group masks -> per-example weights over the (K*slot) batch
        w = np.zeros((args.devices, args.slot), np.float32)
        for g in range(args.devices):
            w[g, :min(plan.batch[g], args.slot)] = 1.0
        idx = rng.integers(0, len(data.tokens),
                           size=args.devices * args.slot)
        toks = data.tokens[idx]
        if cfg.n_codebooks > 1:
            t_in = np.repeat(toks[:, :-1, None], cfg.n_codebooks, axis=2)
            t_lab = np.repeat(toks[:, 1:, None], cfg.n_codebooks, axis=2)
        else:
            t_in, t_lab = toks[:, :-1], toks[:, 1:]
        batch = {
            "tokens": jnp.asarray(t_in),
            "labels": jnp.asarray(t_lab % cfg.vocab),
            "weights": jnp.broadcast_to(
                jnp.asarray(w.reshape(-1))[:, None],
                (args.devices * args.slot, args.seq)).astype(jnp.float32),
        }
        state, metrics = step_fn(state, batch, plan.lr)
        loss = float(metrics["loss"])
        sim_time += plan.predicted_latency
        if prev_loss is not None:
            sched.observe(prev_loss - loss, plan.global_batch)
        prev_loss = loss
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss={loss:.4f} B={plan.global_batch:4d}"
                  f" lr={plan.lr:.4f} simT={sim_time:8.2f}s"
                  f" wall={time.time()-t0:6.1f}s", flush=True)
    if args.ckpt:
        checkpoint.save_state(args.ckpt, int(state.step), state.params,
                              state.opt)
        print(f"[train] checkpoint -> {args.ckpt}")
    print(f"[train] done: final loss {prev_loss:.4f}, "
          f"simulated wall-clock {sim_time:.1f}s")
    return prev_loss


if __name__ == "__main__":
    main()

from repro.models.model import (Runtime, SMOKE_RT, init, param_spec, forward,
                                init_cache, cache_spec, decode_step)

__all__ = ["Runtime", "SMOKE_RT", "init", "param_spec", "forward",
           "init_cache", "cache_spec", "decode_step"]

"""Attention: GQA (optional QKV bias / sliding window) and MLA.

Train/prefill paths are full-sequence causal; the decode path consumes a
KV cache and one new token per sequence.  The q-chunked implementation
bounds the materialized logits to (B, H, block_q, S) — this is the memory
shape XLA sees, so the roofline memory term stays honest at long context.
On TPU the Pallas flash kernel (repro.kernels.flash_attention) is used
instead; both agree with the naive oracle (test-covered).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _mask(pos_q, pos_k, causal: bool, window: Optional[int]):
    """(Sq, Sk) boolean: True = attend."""
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        m &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        m &= pos_k[None, :] > pos_q[:, None] - window
    return m


def attend_naive(q, k, v, pos_q, pos_k, *, causal=True, window=None):
    """q: (B,Sq,Hq,hd), k/v: (B,Sk,Hkv,hd_v?) -> (B,Sq,Hq,hd_v).

    QK and PV products run in the storage dtype with f32 accumulation
    (preferred_element_type) — materializing f32 score/probability tiles
    would double the dominant memory-roofline traffic (§Perf cycle C2).
    Softmax itself is computed in f32.
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(_mask(pos_q, pos_k, causal, window), logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def attend_chunked(q, k, v, pos_q, pos_k, *, causal=True, window=None,
                   block_q: int = 256, remat_chunks: bool = False):
    """Exact attention, scanning over query chunks to bound live memory.

    ``remat_chunks`` checkpoints each chunk's score/softmax so the scan's
    backward recomputes probability tiles instead of stacking the full
    (nq, B, H, bq, S) = S^2 probability tensor as residuals — the
    dominant memory-roofline term for long-sequence training (§Perf C3).
    """
    B, Sq, Hq, hd = q.shape
    if Sq % block_q != 0:
        return attend_naive(q, k, v, pos_q, pos_k, causal=causal, window=window)
    nq = Sq // block_q
    qc = q.reshape(B, nq, block_q, Hq, hd).swapaxes(0, 1)       # (nq,B,bq,H,hd)
    pc = pos_q.reshape(nq, block_q)

    chunk_fn = partial(attend_naive, causal=causal, window=window)
    if remat_chunks:
        chunk_fn = jax.checkpoint(chunk_fn, static_argnums=())

    def body(_, qp):
        qi, pi = qp
        o = chunk_fn(qi, k, v, pi, pos_k)
        return None, o

    _, out = jax.lax.scan(body, None, (qc, pc))
    return out.swapaxes(0, 1).reshape(B, Sq, Hq, v.shape[-1])


def attend_flashjnp(q, k, v, pos_q, pos_k, *, causal=True, window=None,
                    block_q: int = 256, block_k: int = 512):
    """Online-softmax (flash) attention in pure jnp: double scan over
    (q blocks x kv blocks) carrying (acc, m, l).  Only (bq, bk) score
    tiles are ever live — XLA fuses the tile chain, so the HLO's memory
    traffic drops from O(S^2) materialized logits to O(S^2/bk) tile
    reads (hillclimb #3, EXPERIMENTS.md §Perf)."""
    B, Sq, Hq, hd = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    g = Hq // Hkv
    if Sq % block_q or Sk % block_k:
        return attend_chunked(q, k, v, pos_q, pos_k, causal=causal,
                              window=window, block_q=block_q)
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qb = q.reshape(B, nq, block_q, Hkv, g, hd).swapaxes(0, 1)
    pqb = pos_q.reshape(nq, block_q)
    kb = k.reshape(B, nk, block_k, Hkv, hd).swapaxes(0, 1)
    vb = v.reshape(B, nk, block_k, Hkv, hd).swapaxes(0, 1)
    pkb = pos_k.reshape(nk, block_k)

    def q_step(_, qp):
        qi, pq = qp                                 # (B,bq,Hkv,g,hd), (bq,)

        def kv_step(carry, kvp):
            acc, m, l = carry
            ki, vi, pk = kvp
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(pq, pk, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, Hkv, g, block_q, hd), jnp.float32)
        m0 = jnp.full((B, Hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      (kb, vb, pkb))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B,Hkv,g,bq,hd) -> (B,bq,Hq,hd)
        return None, out.transpose(0, 3, 1, 2, 4).reshape(
            B, block_q, Hq, hd).astype(q.dtype)

    _, ob = jax.lax.scan(q_step, None, (qb, pqb))
    return ob.swapaxes(0, 1).reshape(B, Sq, Hq, hd)


def attend(q, k, v, pos_q, pos_k, *, causal=True, window=None, impl="auto",
           block_q=256, remat_chunks=False):
    if impl == "naive" or (impl == "auto" and q.shape[1] <= 1024):
        return attend_naive(q, k, v, pos_q, pos_k, causal=causal, window=window)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if impl == "flashjnp":
        return attend_flashjnp(q, k, v, pos_q, pos_k, causal=causal,
                               window=window, block_q=block_q)
    return attend_chunked(q, k, v, pos_q, pos_k, causal=causal, window=window,
                          block_q=block_q, remat_chunks=remat_chunks)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ArchConfig, dtype):
    hd = cfg.hd()
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _qkv(params, cfg: ArchConfig, x, positions):
    B, S, _ = x.shape
    hd = cfg.hd()
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(params, cfg: ArchConfig, x, positions, *, window=None,
                impl="auto", remat_chunks=False, expand_heads=False):
    """Full-sequence causal self-attention (train / prefill).

    ``expand_heads``: repeat kv to the full query-head count and pin all
    three tensors to head-dim model sharding — avoids the redundant-pair
    all-reduces GSPMD emits for uneven GQA head counts (§Perf pair A.4).
    """
    q, k, v = _qkv(params, cfg, x, positions)
    if expand_heads and cfg.n_kv_heads < cfg.n_heads:
        from jax.sharding import PartitionSpec as P
        g = cfg.n_heads // cfg.n_kv_heads
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        spec = P(None, None, "model", None)
        q = jax.lax.with_sharding_constraint(q, spec)
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)
    out = attend(q, k, v, positions, positions, causal=True,
                 window=window, impl=impl, remat_chunks=remat_chunks)
    return out.reshape(x.shape[0], x.shape[1], -1) @ params["wo"]


def gqa_decode(params, cfg: ArchConfig, x, cache_k, cache_v, pos, *,
               window=None):
    """One-token decode, synchronized batch.

    x: (B, 1, d); cache_k/v: (B, ctx, Hkv, hd) ring-buffered when ``window``
    is set (ctx == window); ``pos``: scalar — the absolute position of the
    new token, shared across the batch (synchronized serving; a scalar
    index keeps the batch dim sharded under GSPMD — per-sequence dynamic
    indices would force cache all-gathers).
    Returns (out, new_k, new_v).
    """
    B = x.shape[0]
    ctx = cache_k.shape[1]
    q, k, v = _qkv(params, cfg, x, pos[None])
    slot = pos % ctx if window is not None else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    # key absolute positions for masking (ring buffer stores absolute pos
    # implicitly: slot i holds the latest position p ≡ i (mod ctx), p <= pos)
    idx = jnp.arange(ctx)
    if window is not None:
        key_pos = pos - ((pos - idx) % ctx)
    else:
        key_pos = idx
    valid = (key_pos <= pos) & (key_pos >= 0)   # >=0: slot actually written
    if window is not None:
        valid &= key_pos > pos - window

    hd = cfg.hd()
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    # keep the cache in its storage dtype; accumulate in f32 (a cast would
    # make XLA hoist a full-cache f32 copy out of the layer loop)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype)
    return out @ params["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], cfg.d_model, m.kv_lora_rank, dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": dense_init(ks[1], cfg.d_model, m.qk_rope_head_dim, dtype),
        "w_uk": dense_init(ks[2], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[3], m.kv_lora_rank, H * m.v_head_dim, dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, cfg.d_model, dtype),
    }
    qdim = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], cfg.d_model, m.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora_rank, dtype)
        p["w_uq"] = dense_init(ks[6], m.q_lora_rank, qdim, dtype)
    else:
        p["w_q"] = dense_init(ks[5], cfg.d_model, qdim, dtype)
    return p


def _mla_q(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    if m.q_lora_rank:
        q = rmsnorm(params["q_norm"], x @ params["w_dq"]) @ params["w_uq"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(params, cfg: ArchConfig, x, positions, *, impl="auto",
                window=None, remat_chunks=False):
    """Full-sequence MLA (decompressed form for train/prefill)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(params, cfg, x, positions)

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])   # (B,S,r)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)                       # (B,S,1,rope)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    out = attend(q, k, v, positions, positions, causal=True, impl=impl,
                 window=window, remat_chunks=remat_chunks)
    return out.reshape(B, S, H * m.v_head_dim) @ params["wo"]


def mla_decode(params, cfg: ArchConfig, x, cache_ckv, pos):
    """Absorbed-matrix MLA decode against the compressed cache.

    cache_ckv: (B, ctx, kv_lora + qk_rope) — per-token compressed KV plus the
    shared rope key; ``pos``: scalar (synchronized batch).  Per-step cost is
    linear in ctx; cache is tiny (the MLA advantage), so long_500k runs
    natively.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    r = m.kv_lora_rank
    ctx = cache_ckv.shape[1]

    q_nope, q_rope = _mla_q(params, cfg, x, pos[None])        # (B,1,H,·)
    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])    # (B,1,r)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], pos[None],
                        cfg.rope_theta)[:, :, 0, :]           # (B,1,rope)
    new_entry = jnp.concatenate([c_kv, k_rope], axis=-1)
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, new_entry,
                                             (0, pos, 0))

    w_uk = params["w_uk"].reshape(r, H, m.qk_nope_head_dim)
    # absorb W_UK into q: (B,H,r)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    ckv, krope = cache_ckv[..., :r], cache_ckv[..., r:]
    scale = 1.0 / jnp.sqrt(jnp.asarray(m.qk_nope_head_dim + m.qk_rope_head_dim,
                                       jnp.float32))
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(ckv.dtype), ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(krope.dtype),
                           krope, preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(ctx) <= pos
    logits = jnp.where(valid[None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhs,bsr->bhr", w.astype(ckv.dtype), ckv,
                         preferred_element_type=jnp.float32)
    w_uv = params["w_uv"].reshape(r, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return out @ params["wo"], cache_ckv

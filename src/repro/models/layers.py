"""Shared model layers: init helpers, RMSNorm, RoPE, embeddings, SwiGLU FFN.

Everything is functional: params are nested dicts of jnp arrays, and every
layer is ``apply(params, x, ...) -> y``.  Layer params for the repeated
decoder stack carry a leading ``n_layers`` axis so the forward pass can
``lax.scan`` over them (small HLO, fast compiles, scan-friendly remat).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def stacked(keys, fn):
    """vmap an init fn over a leading layer axis."""
    return jax.vmap(fn)(keys)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]              # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding (vocab padded for even model-axis sharding)
# ---------------------------------------------------------------------------

VOCAB_PAD_MULTIPLE = 128


def padded_vocab(vocab: int) -> int:
    return ((vocab + VOCAB_PAD_MULTIPLE - 1) // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


def embedding_init(key, vocab: int, d: int, dtype):
    pv = padded_vocab(vocab)
    return {"table": (jax.random.normal(key, (pv, d), jnp.float32) * 0.02).astype(dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x, true_vocab: int):
    """Project to (padded) vocab logits; mask padding ids to -inf."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"])
    pv = params["table"].shape[0]
    if pv != true_vocab:
        mask = jnp.arange(pv) < true_vocab
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def ffn_init(key, d: int, d_ff: int, dtype, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = dense_init(k1, d, d_ff, dtype)
    return p


def ffn(params, x):
    if "w_gate" in params:                       # SwiGLU
        g = jax.nn.silu(x @ params["w_gate"])
        u = x @ params["w_up"]
        return (g * u) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]

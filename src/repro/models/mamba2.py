"""Mamba2 block — SSD (state-space duality, arXiv:2405.21060).

Train path: chunked SSD scan (intra-chunk quadratic + inter-chunk linear
recurrence), pure-jnp; the per-chunk compute is what the Pallas
``ssd_scan`` kernel accelerates on TPU (kernels/ssd_scan.py agrees with
this oracle, test-covered).

Decode path: exact single-step recurrence on the (B, H, hd, N) state plus
a (B, d_conv-1, ch) rolling conv window — O(1) per token, which is why the
SSM/hybrid archs run long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


def dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_ch


def mamba2_init(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d_in, H, conv_ch = dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + H   # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(ks[2], d_in, cfg.d_model, dtype),
    }


def _split_proj(cfg: ArchConfig, proj):
    s = cfg.ssm
    d_in, H, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * gn], axis=-1)
    return z, xbc, dt                    # xbc holds conv channels


def _causal_conv(w, b, xbc):
    """Depthwise causal conv over (B, S, CH)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def segsum(a):
    """Stable 'segment sum': out[..., i, j] = sum_{j<k<=i} a[..., k]."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x:  (B, S, H, P)    dt: (B, S, H)    A: (H,) negative decay rates
    Bm, Cm: (B, S, G, N) with H % G == 0.
    Returns y: (B, S, H, P), final state (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nc = S // chunk
    rep = H // G

    def ch(t):  # (B, S, ...) -> (B, nc, chunk, ...)
        return t.reshape((Bsz, nc, chunk) + t.shape[2:])

    xc, dtc = ch(x.astype(jnp.float32)), ch(dt.astype(jnp.float32))
    Bc, Cc = ch(Bm.astype(jnp.float32)), ch(Cm.astype(jnp.float32))
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)                   # (B,nc,l,H,N)
    Ch_ = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]                  # (B,nc,l,H)
    dA_cum = jnp.cumsum(dA, axis=2)                    # within chunk
    # intra-chunk (diagonal block): y = (C B^T ∘ L) (dt x)
    Lmat = jnp.exp(segsum(jnp.moveaxis(dA, -1, -2)))   # (B,nc,H,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch_, Bh)
    xdt = xc * dtc[..., None]                          # (B,nc,l,H,P)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores * Lmat, xdt)

    # chunk states: S_c = sum_s exp(dA_end - dA_cum_s) B_s (dt x)_s
    decay_out = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum) # (B,nc,l,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh, decay_out, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])         # (B,nc,H)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                              # emit state ENTERING chunk

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (B,nc,H,P,N)

    # off-diagonal contribution: C_t exp(dA_cum_t) state_in
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                       Ch_, jnp.exp(dA_cum), prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final


def mamba2_forward(params, cfg: ArchConfig, x):
    """Full-sequence train/prefill path. x: (B, S, d)."""
    s = cfg.ssm
    d_in, H, _ = dims(cfg)
    B_, S, _ = x.shape
    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(params["conv_w"], params["conv_b"], xbc)
    gn = s.n_groups * s.d_state
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    xs = xs.reshape(B_, S, H, s.head_dim)
    Bm = Bm.reshape(B_, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    chunk = min(s.chunk, S)
    # dispatch through kernels.ops: pallas ssd_scan on TPU, ssd_reference
    # on CPU (lazy import — kernels.ref imports this module for the oracle)
    from repro.kernels import ops as _kops
    y = _kops.ssd(xs, dt, A, Bm, Cm, chunk=chunk)
    y = y + xs * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, d_in)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"]


def mamba2_decode(params, cfg: ArchConfig, x, conv_state, ssm_state):
    """One-token step. x: (B,1,d); conv_state: (B, d_conv-1, CH);
    ssm_state: (B, H, P, N). Returns (y, conv_state, ssm_state)."""
    s = cfg.ssm
    d_in, H, CH = dims(cfg)
    B_ = x.shape[0]
    proj = x[:, 0] @ params["in_proj"]                 # (B, ·)
    z, xbc, dt = _split_proj(cfg, proj)

    # rolling conv window
    win = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,W,CH)
    w = params["conv_w"]
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, w) + params["conv_b"])
    new_conv_state = win[:, 1:]

    gn = s.n_groups * s.d_state
    xs, Bm, Cm = jnp.split(conv, [d_in, d_in + gn], axis=-1)
    xs = xs.reshape(B_, H, s.head_dim).astype(jnp.float32)
    Bm = Bm.reshape(B_, s.n_groups, s.d_state).astype(jnp.float32)
    Cm = Cm.reshape(B_, s.n_groups, s.d_state).astype(jnp.float32)
    rep = H // s.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1)                   # (B,H,N)
    Ch_ = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                      # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, xs, Bh)
    ssm_state = ssm_state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, Ch_)
    y = y + xs * params["D"][None, :, None]
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z[:, None, :]))
    return y @ params["out_proj"], new_conv_state, ssm_state

"""Composable decoder model covering all assigned families.

Families and wiring (see DESIGN.md §5):
  dense / vlm        : [ln → attn(GQA|MLA) → ln → FFN] × L
  audio (musicgen)   : same, multi-codebook embed/unembed
  moe                : [ln → attn → ln → MoE] × L (first_dense_layers dense)
  ssm (mamba2)       : [ln → mamba2] × L
  hybrid (zamba2)    : L ssm layers in segments of ``hybrid_every``; after
                       each segment ONE shared attention+FFN block (same
                       weights every time) runs.

The repeated stack is ``lax.scan``-ed over stacked layer params (compact
HLO, scan-remat).  Decode threads per-layer caches through the same scan.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.layers import (dense_init, embedding_init, ffn, ffn_init,
                                 padded_vocab, rmsnorm, rmsnorm_init)


@dataclass(frozen=True)
class Runtime:
    """Execution knobs independent of the architecture."""
    dtype: jnp.dtype = jnp.float32
    attn_impl: str = "auto"   # auto | naive | blockwise | flashjnp | pallas
    block_q: int = 256
    window: Optional[int] = None   # overrides cfg.attn_window when set
    remat: bool = False
    remat_attn: bool = False       # checkpoint the attention sub-block so
                                   # the q-chunk scan does not stash the
                                   # full S^2 probability stack (§Perf C3)
    capacity_factor: float = 1.25
    moe_impl: str = "scatter"      # scatter | expert_choice (§Perf A)
    moe_shard_axes: tuple = ()     # data axes for expert-buffer constraint
                                   # (set by the launcher; empty on 1 dev)
    gqa_expand: bool = False       # expand kv->q heads + head-dim sharding
                                   # constraint (uneven-GQA fix, §Perf A.4)
    seq_parallel: bool = False     # Megatron-SP: residual stream sharded
                                   # over 'model' on the sequence dim

    def win(self, cfg: ArchConfig):
        return self.window if self.window is not None else cfg.attn_window


def _sp(x, rt: Runtime):
    """Sequence-parallel sharding constraint on the residual stream."""
    if not rt.seq_parallel:
        return x
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(None, "model", None))


SMOKE_RT = Runtime(dtype=jnp.float32, attn_impl="naive")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg, dtype):
    if cfg.attn_kind == "mla":
        return attn.mla_init(key, cfg, dtype)
    return attn.gqa_init(key, cfg, dtype)


def _dense_layer_init(key, cfg, dtype, d_ff=None):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "ffn": ffn_init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype,
                        cfg.ffn_kind),
    }


def _moe_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_mod.moe_init(k2, cfg, dtype),
    }


def _ssm_layer_init(key, cfg, dtype):
    return {
        "ln": rmsnorm_init(cfg.d_model, dtype),
        "mixer": m2.mamba2_init(key, cfg, dtype),
    }


def init(cfg: ArchConfig, key, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    p = {}
    if cfg.n_codebooks > 1:
        tabs = jax.vmap(lambda k: embedding_init(k, cfg.vocab, cfg.d_model,
                                                 dtype)["table"])(
            jax.random.split(keys[0], cfg.n_codebooks))
        p["embed"] = {"table": tabs}       # (n_cb, pv, d)
        p["lm_head"] = jax.vmap(
            lambda k: dense_init(k, cfg.d_model, padded_vocab(cfg.vocab),
                                 dtype))(
            jax.random.split(keys[1], cfg.n_codebooks))  # (n_cb, d, pv)
    else:
        p["embed"] = embedding_init(keys[0], cfg.vocab, cfg.d_model, dtype)
        p["lm_head"] = dense_init(keys[1], cfg.d_model,
                                  padded_vocab(cfg.vocab), dtype)
    p["final_norm"] = rmsnorm_init(cfg.d_model, dtype)

    lkeys = jax.random.split(keys[2], max(cfg.n_layers, 1))
    if cfg.family in ("dense", "vlm", "audio"):
        p["layers"] = jax.vmap(
            lambda k: _dense_layer_init(k, cfg, dtype))(lkeys)
    elif cfg.family == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            p["dense0"] = jax.vmap(
                lambda k: _dense_layer_init(k, cfg, dtype))(lkeys[:nd])
        p["layers"] = jax.vmap(
            lambda k: _moe_layer_init(k, cfg, dtype))(lkeys[nd:])
    elif cfg.family == "ssm":
        p["layers"] = jax.vmap(lambda k: _ssm_layer_init(k, cfg, dtype))(lkeys)
    elif cfg.family == "hybrid":
        p["layers"] = jax.vmap(lambda k: _ssm_layer_init(k, cfg, dtype))(lkeys)
        p["shared_attn"] = _dense_layer_init(keys[3], cfg, dtype)
    else:
        raise ValueError(cfg.family)
    return p


def param_spec(cfg: ArchConfig, dtype=jnp.float32):
    return jax.eval_shape(lambda: init(cfg, jax.random.key(0), dtype))


# ---------------------------------------------------------------------------
# embed / unembed
# ---------------------------------------------------------------------------


def _embed(params, cfg, tokens):
    if cfg.n_codebooks > 1:
        # tokens: (B, S, n_cb); sum codebook embeddings (MusicGen §3.1)
        tabs = params["embed"]["table"]            # (n_cb, pv, d)
        return sum(tabs[i][tokens[..., i]] for i in range(cfg.n_codebooks))
    return params["embed"]["table"][tokens]


def _unembed(params, cfg, x):
    pv = padded_vocab(cfg.vocab)
    if cfg.n_codebooks > 1:
        logits = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
    else:
        logits = x @ params["lm_head"]
    if pv != cfg.vocab:
        mask = jnp.arange(pv) < cfg.vocab
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


# ---------------------------------------------------------------------------
# layer bodies (full-sequence)
# ---------------------------------------------------------------------------


def _attn_fwd(lp, cfg, x, positions, rt: Runtime):
    if cfg.attn_kind == "mla":
        return attn.mla_forward(lp, cfg, x, positions, impl=rt.attn_impl,
                                window=rt.win(cfg),
                                remat_chunks=rt.remat_attn)
    return attn.gqa_forward(lp, cfg, x, positions, window=rt.win(cfg),
                            impl=rt.attn_impl, remat_chunks=rt.remat_attn,
                            expand_heads=rt.gqa_expand)


def _dense_block(lp, cfg, x, positions, rt):
    x = _sp(x, rt)
    x = x + _attn_fwd(lp["attn"], cfg, rmsnorm(lp["ln1"], x), positions, rt)
    x = _sp(x, rt)
    x = x + ffn(lp["ffn"], rmsnorm(lp["ln2"], x))
    return x


def _moe_block(lp, cfg, x, positions, rt):
    x = _sp(x, rt)
    x = x + _attn_fwd(lp["attn"], cfg, rmsnorm(lp["ln1"], x), positions, rt)
    x = _sp(x, rt)
    y, aux = moe_mod.moe_forward(lp["moe"], cfg, rmsnorm(lp["ln2"], x),
                                 capacity_factor=rt.capacity_factor,
                                 impl=rt.moe_impl,
                                 shard_axes=rt.moe_shard_axes)
    return x + y, aux


def _ssm_block(lp, cfg, x, rt):
    return _sp(x, rt) + m2.mamba2_forward(lp["mixer"], cfg,
                                          rmsnorm(lp["ln"], x))


def _maybe_remat(fn, rt: Runtime):
    return jax.checkpoint(fn) if rt.remat else fn


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params, tokens, *, prefix_embeds=None,
            rt: Runtime = SMOKE_RT):
    """Full-sequence forward.

    tokens: (B, S) int32 — or (B, S, n_cb) for multi-codebook audio.
    prefix_embeds: (B, P, d) pre-projected patch/frame embeddings (vlm stub);
    they replace the first P token positions.
    Returns (logits, aux_loss).
    """
    x = _embed(params, cfg, tokens).astype(rt.dtype)
    B, S = x.shape[0], x.shape[1]
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(rt.dtype), x[:, P:]], axis=1)
    positions = jnp.arange(S)          # 1-D; identical across the batch
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "audio"):
        body = _maybe_remat(
            lambda h, lp: (_dense_block(lp, cfg, h, positions, rt), None), rt)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "moe":
        if "dense0" in params:
            bodyd = _maybe_remat(
                lambda h, lp: (_dense_block(lp, cfg, h, positions, rt), None),
                rt)
            x, _ = jax.lax.scan(bodyd, x, params["dense0"])

        def bodym(carry, lp):
            h, a = carry
            h, al = _moe_block(lp, cfg, h, positions, rt)
            return (h, a + al), None
        bodym = _maybe_remat(bodym, rt)
        (x, aux), _ = jax.lax.scan(bodym, (x, aux), params["layers"])
    elif cfg.family == "ssm":
        body = _maybe_remat(
            lambda h, lp: (_ssm_block(lp, cfg, h, rt), None), rt)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif cfg.family == "hybrid":
        per = cfg.hybrid_every
        nseg = cfg.n_layers // per
        seg_params = jax.tree_util.tree_map(
            lambda a: a.reshape((nseg, per) + a.shape[1:]), params["layers"])
        shared = params["shared_attn"]

        def inner(h, lp):
            return _ssm_block(lp, cfg, h, rt), None

        def outer(h, sp):
            h, _ = jax.lax.scan(_maybe_remat(inner, rt), h, sp)
            h = _maybe_remat(
                lambda hh: _dense_block(shared, cfg, hh, positions, rt), rt)(h)
            return h, None

        x, _ = jax.lax.scan(outer, x, seg_params)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x)
    return _unembed(params, cfg, x), aux


# ---------------------------------------------------------------------------
# decode (one token, KV/SSM caches)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, ctx: int, rt: Runtime = SMOKE_RT,
               _zeros=jnp.zeros):
    """Build the decode cache pytree (use with jax.eval_shape for specs).

    ``ctx`` is the attention context to *allocate*: for sliding-window archs
    pass min(seq_len, window); SSM state is O(1) regardless.
    """
    dt = rt.dtype
    c = {"pos": _zeros((), jnp.int32)}   # synchronized decode position
    hd = cfg.hd()
    L = cfg.n_layers
    win = rt.win(cfg)
    kv_ctx = min(ctx, win) if win else ctx

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        if cfg.attn_kind == "mla":
            m = cfg.mla
            width = m.kv_lora_rank + m.qk_rope_head_dim
            c["ckv"] = _zeros((L, batch, kv_ctx, width), dt)
        else:
            c["k"] = _zeros((L, batch, kv_ctx, cfg.n_kv_heads, hd), dt)
            c["v"] = _zeros((L, batch, kv_ctx, cfg.n_kv_heads, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        d_in, H, CH = m2.dims(cfg)
        s = cfg.ssm
        c["conv"] = _zeros((L, batch, s.d_conv - 1, CH), dt)
        c["ssm"] = _zeros((L, batch, H, s.head_dim, s.d_state), jnp.float32)
    if cfg.family == "hybrid":
        nseg = cfg.n_layers // cfg.hybrid_every
        c["k"] = _zeros((nseg, batch, kv_ctx, cfg.n_kv_heads, hd), dt)
        c["v"] = _zeros((nseg, batch, kv_ctx, cfg.n_kv_heads, hd), dt)
    return c


def cache_spec(cfg, batch, ctx, rt: Runtime = SMOKE_RT):
    return jax.eval_shape(partial(init_cache, cfg, batch, ctx, rt))


def _attn_decode(lp, cfg, x, cache_layer, pos, rt):
    if cfg.attn_kind == "mla":
        out, ckv = attn.mla_decode(lp, cfg, x, cache_layer["ckv"], pos)
        return out, {"ckv": ckv}
    out, k, v = attn.gqa_decode(lp, cfg, x, cache_layer["k"], cache_layer["v"],
                                pos, window=rt.win(cfg))
    return out, {"k": k, "v": v}


def _dense_block_decode(lp, cfg, x, cl, pos, rt):
    a, cl = _attn_decode(lp["attn"], cfg, rmsnorm(lp["ln1"], x), cl, pos, rt)
    x = x + a
    x = x + ffn(lp["ffn"], rmsnorm(lp["ln2"], x))
    return x, cl


def _moe_block_decode(lp, cfg, x, cl, pos, rt):
    a, cl = _attn_decode(lp["attn"], cfg, rmsnorm(lp["ln1"], x), cl, pos, rt)
    x = x + a
    # decode is drop-free: per-row capacity = S*top_k (= top_k at S=1)
    y, _ = moe_mod.moe_forward(lp["moe"], cfg, rmsnorm(lp["ln2"], x),
                               cap=x.shape[1] * cfg.moe.top_k)
    return x + y, cl


def _ssm_block_decode(lp, cfg, x, cl, rt):
    y, conv, ssm = m2.mamba2_decode(lp["mixer"], cfg, rmsnorm(lp["ln"], x),
                                    cl["conv"], cl["ssm"])
    return x + y, {"conv": conv, "ssm": ssm}


def _slice_attn_cache(cache, keys=("k", "v", "ckv")):
    return {k: cache[k] for k in keys if k in cache}


def decode_step(cfg: ArchConfig, params, cache, tokens, *,
                rt: Runtime = SMOKE_RT):
    """One decode step for the whole batch.

    tokens: (B, 1) int32 (or (B, 1, n_cb)).  Returns (logits, new_cache).
    """
    pos = cache["pos"]                                  # scalar, synchronized
    x = _embed(params, cfg, tokens).astype(rt.dtype)
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        ac = _slice_attn_cache(cache)
        nd = cfg.moe.first_dense_layers if (cfg.family == "moe" and
                                            "dense0" in params) else 0
        if nd:
            ac0 = jax.tree_util.tree_map(lambda a: a[:nd], ac)
            acr = jax.tree_util.tree_map(lambda a: a[nd:], ac)

            def body0(h, inp):
                lp, cl = inp
                h, cl = _dense_block_decode(lp, cfg, h, cl, pos, rt)
                return h, cl
            x, ac0 = jax.lax.scan(body0, x, (params["dense0"], ac0))
        else:
            acr = ac

        def body(h, inp):
            lp, cl = inp
            if cfg.family == "moe":
                return _moe_block_decode(lp, cfg, h, cl, pos, rt)
            return _dense_block_decode(lp, cfg, h, cl, pos, rt)
        x, acr = jax.lax.scan(body, x, (params["layers"], acr))
        if nd:
            merged = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], 0), ac0, acr)
        else:
            merged = acr
        new_cache.update(merged)

    elif cfg.family == "ssm":
        def body(h, inp):
            lp, cl = inp
            return _ssm_block_decode(lp, cfg, h, cl, rt)
        x, sc = jax.lax.scan(
            body, x, (params["layers"],
                      {"conv": cache["conv"], "ssm": cache["ssm"]}))
        new_cache.update(sc)

    elif cfg.family == "hybrid":
        per = cfg.hybrid_every
        nseg = cfg.n_layers // per
        seg_params = jax.tree_util.tree_map(
            lambda a: a.reshape((nseg, per) + a.shape[1:]), params["layers"])
        seg_ssm = jax.tree_util.tree_map(
            lambda a: a.reshape((nseg, per) + a.shape[1:]),
            {"conv": cache["conv"], "ssm": cache["ssm"]})
        shared = params["shared_attn"]

        def outer(h, inp):
            sp, sc, kl, vl = inp

            def inner(hh, ii):
                lp, cl = ii
                return _ssm_block_decode(lp, cfg, hh, cl, rt)
            h, sc = jax.lax.scan(inner, h, (sp, sc))
            h, acl = _dense_block_decode(shared, cfg, h, {"k": kl, "v": vl},
                                         pos, rt)
            return h, (sc, acl["k"], acl["v"])

        x, (sc, ks, vs) = jax.lax.scan(
            outer, x, (seg_params, seg_ssm, cache["k"], cache["v"]))
        new_cache["conv"] = sc["conv"].reshape(cache["conv"].shape)
        new_cache["ssm"] = sc["ssm"].reshape(cache["ssm"].shape)
        new_cache["k"], new_cache["v"] = ks, vs
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x)
    new_cache["pos"] = pos + 1
    return _unembed(params, cfg, x), new_cache

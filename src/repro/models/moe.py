"""Mixture-of-Experts layer with GROUPED dispatch (group = batch row).

Routing positions (cumsum) and scatter/gather are computed per batch row,
so the expert buffers are (B, E, C, d) with B shardable over the data axes
and E over the model axis (expert parallel) — no global-capacity buffer
that would defeat data parallelism (that failure mode cost 10× compute in
§Perf pair A iteration 3; grouped dispatch is the GShard "group" design).

Two dispatch impls:
  scatter        — token-choice top-k with per-row capacity (faithful to
                   the source models; capacity overflow drops tokens).
  expert_choice  — per-row, each expert takes its top-C tokens (Zhou et
                   al. 2022): drop-free, load-balanced by construction.

Shared experts (DeepSeek) and a dense residual branch (Arctic) ride on top.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, ffn, ffn_init


def moe_init(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    ek = jax.random.split(ks[0], 3)

    def one_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w_gate": dense_init(k1, cfg.d_model, m.d_ff_expert, dtype),
            "w_up": dense_init(k2, cfg.d_model, m.d_ff_expert, dtype),
            "w_down": dense_init(k3, m.d_ff_expert, cfg.d_model, dtype),
        }

    p = {
        "router": dense_init(ks[1], cfg.d_model, m.n_experts, dtype,
                             scale=0.1),
        "experts": jax.vmap(one_expert)(jax.random.split(ek[0], m.n_experts)),
    }
    if m.n_shared:
        p["shared"] = ffn_init(ks[2], cfg.d_model, m.d_ff_expert * m.n_shared,
                               dtype)
    if m.dense_residual:
        p["dense"] = ffn_init(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def capacity(tokens_per_group: int, cfg: ArchConfig,
             factor: float = 1.25) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k * factor / m.n_experts)
    return max(4, (c + 3) // 4 * 4)


def _expert_ffn(ex, buf):
    """(B, E, C, d) x stacked expert weights -> (B, E, C, d)."""
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, ex["w_gate"]))
    u = jnp.einsum("becd,edf->becf", buf, ex["w_up"])
    return jnp.einsum("becf,efd->becd", g * u, ex["w_down"])


def _dispatch_scatter(probs, x, E, K, C):
    """Token-choice top-k, per-row capacity. x: (B,S,d), probs: (B,S,E)."""
    B, S, d = x.shape
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # (B,S,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    def row(xb, eidx, gates):
        # positions within each expert buffer: cumsum over (S*K,) slots
        flat = eidx.reshape(-1)                             # (S*K,)
        onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, 0) - onehot, flat[:, None], 1)[:, 0]
        keep = (pos < C).reshape(S, K)
        pos = pos.reshape(S, K)
        buf = jnp.zeros((E, C, d), x.dtype)
        for kk in range(K):
            buf = buf.at[eidx[:, kk],
                         jnp.where(keep[:, kk], pos[:, kk], C - 1)].add(
                jnp.where(keep[:, kk, None], xb, 0))
        return buf, pos, keep

    buf, pos, keep = jax.vmap(row)(x, expert_idx, gate_vals)
    return buf, (expert_idx, gate_vals, pos, keep)


def _combine_scatter(out_buf, meta, x_dtype):
    expert_idx, gate_vals, pos, keep = meta
    B, E, C, d = out_buf.shape
    S, K = expert_idx.shape[1], expert_idx.shape[2]

    def row(ob, eidx, gates, p, kp):
        y = jnp.zeros((S, d), x_dtype)
        for kk in range(K):
            g = ob[eidx[:, kk], jnp.where(kp[:, kk], p[:, kk], 0)]
            y = y + g * (gates[:, kk] * kp[:, kk]).astype(x_dtype)[:, None]
        return y

    return jax.vmap(row)(out_buf, expert_idx, gate_vals, pos, keep)


def _constrain(buf, shard_axes):
    """Pin expert buffers to (B->data axes, E->model): without this GSPMD
    replicates the scatter output over data and every device computes all
    batch rows for its experts (§Perf pair A, 10x compute)."""
    if not shard_axes:
        return buf
    from jax.sharding import PartitionSpec as P
    spec = P(shard_axes if len(shard_axes) > 1 else shard_axes[0],
             "model", *([None] * (buf.ndim - 2)))
    return jax.lax.with_sharding_constraint(buf, spec)


def moe_forward(params, cfg: ArchConfig, x, *, capacity_factor: float = 1.25,
                cap: int = 0, impl: str = "scatter", shard_axes=()):
    """x: (B, S, d) -> (y, aux_loss).  ``cap`` overrides per-row capacity."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    C = cap or capacity(S, cfg, capacity_factor)
    C = min(C, S * K)

    logits = jnp.einsum("bsd,de->bse", x, params["router"]
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    ex = params["experts"]

    if impl == "expert_choice":
        Cec = min(S, C)
        sel_p, sel_idx = jax.lax.top_k(probs.swapaxes(1, 2), Cec)  # (B,E,Cec)
        buf = jax.vmap(lambda xb, ib: xb[ib])(x, sel_idx)          # (B,E,Cec,d)
        buf = _constrain(buf, shard_axes)
        out_buf = _constrain(_expert_ffn(ex, buf), shard_axes)
        w = sel_p.astype(x.dtype)[..., None]

        def row(ob, ib, wb):
            return jnp.zeros((S, d), x.dtype).at[ib.reshape(-1)].add(
                (ob * wb).reshape(-1, d))

        y = jax.vmap(row)(out_buf, sel_idx, w)
        top1 = jax.nn.one_hot(jnp.argmax(probs, -1), E, dtype=jnp.float32)
        aux = (E * jnp.mean(probs.mean((0, 1)) * top1.mean((0, 1)))
               * m.load_balance_coef)
    else:
        buf, meta = _dispatch_scatter(probs, x, E, K, C)
        buf = _constrain(buf, shard_axes)
        out_buf = _constrain(_expert_ffn(ex, buf), shard_axes)
        y = _combine_scatter(out_buf, meta, x.dtype)
        assign = jax.nn.one_hot(meta[0], E, dtype=jnp.float32).sum(2)
        aux = (E * jnp.mean(probs.mean((0, 1)) * assign.mean((0, 1)))
               * m.load_balance_coef)

    xt2 = x.reshape(B * S, d)
    if m.n_shared:
        y = y + ffn(params["shared"], xt2).reshape(B, S, d)
    if m.dense_residual:
        y = y + ffn(params["dense"], xt2).reshape(B, S, d)
    return y, aux

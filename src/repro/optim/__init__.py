from repro.optim.optimizers import (sgd, momentum, adamw, Optimizer,
                                    apply_updates)

__all__ = ["sgd", "momentum", "adamw", "Optimizer", "apply_updates"]

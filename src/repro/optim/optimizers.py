"""Pure-JAX optimizers (no optax offline): SGD, SGD-momentum, AdamW.

The paper's Step 5 is plain SGD with η[n] scaled ∝ √B (core.efficiency
.lr_scale); momentum/AdamW are provided for the beyond-paper experiments.
Each optimizer is (init_fn, update_fn) packaged in ``Optimizer``;
``update(grads, state, params, lr)`` returns (updates, new_state) and
``apply_updates`` adds them — the lr is a traced scalar so one compiled
train step serves every period plan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable      # (grads, state, params, lr) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, state_dtype=jnp.float32) -> Optimizer:
    """SGD+momentum; ``state_dtype=bfloat16`` halves optimizer-state
    traffic/footprint (a §Perf hillclimb knob)."""
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, state_dtype), params)

    def update(grads, state, params, lr):
        new_m = jax.tree_util.tree_map(
            lambda m, g: (beta * m.astype(jnp.float32)
                          + g.astype(jnp.float32)).astype(state_dtype),
            state, grads)
        upd = jax.tree_util.tree_map(
            lambda m: -lr * m.astype(jnp.float32), new_m)
        return upd, new_m

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m_, v_, p):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)

"""Experiment-as-a-service: streaming scenario arrivals, online
bucketing, a persistent compile cache, and chunk-granular preemptive
scheduling.

The static :class:`~repro.api.Experiment` answers "run this grid"; this
package answers "keep running whatever arrives".  A long-running
:class:`ExperimentService` accepts :class:`~repro.api.ScenarioSpec`
requests over time and streams each request's ``Results`` back chunk by
chunk, built from three serving-specific mechanisms layered on the
existing lowering:

* **online bucketing** (``admission.py``) — arrivals micro-batch into
  compiled-program groups keyed on ``spec.bucket_key()`` (the same
  structural rule static lowering buckets on) within a tunable batching
  window;
* **persistent compile cache** (``program_cache.py``) — an index over
  every dispatched :func:`~repro.api.lowering.program_key`; repeat
  bucket shapes admit *warm* and skip compilation entirely (zero new
  ``TraceEvent``s in the PR-6 ledger, test-enforced);
* **chunk-granular preemption** (``scheduler.py``) — PR 5's resumable
  :class:`~repro.api.lowering.BucketRun` makes every chunk boundary a
  preemption point: hot requests take the device from long-horizon
  background runs, which later resume *bit-identically* (suspended runs
  are just parked :class:`~repro.fed.engine.EngineState`).

``stats.py`` carries the counters and latency percentiles
(``benchmarks/serve_load.py`` → ``BENCH_serve.json``).

Naming note: ``launch/serve.py`` and ``examples/decode_batched.py`` are
the *LLM token-decode* demos for the model zoo — unrelated to this
package, which is the FEEL experiment service.
"""
from repro.serve.admission import AdmissionQueue, PendingRequest
from repro.serve.program_cache import ProgramCache
from repro.serve.scheduler import PreemptiveScheduler, ServiceRun
from repro.serve.service import ExperimentService, Ticket
from repro.serve.stats import RequestRecord, ServiceStats

__all__ = ["AdmissionQueue", "ExperimentService", "PendingRequest",
           "PreemptiveScheduler", "ProgramCache", "RequestRecord",
           "ServiceRun", "ServiceStats", "Ticket"]

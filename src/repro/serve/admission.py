"""Admission queue + online bucketer.

Arriving :class:`~repro.api.ScenarioSpec` requests micro-batch into
shape-compatible groups *online*: the admission key is exactly
``(spec.bucket_key(), periods)`` — the same structural compatibility rule
the static ``Experiment`` lowering buckets on, plus the horizon length
(rows of one compiled program must scan the same number of periods).
Compatible arrivals that land inside the **batching window** merge into
one bucket and cost one compiled-program dispatch for the whole group;
the window is the admit-now-vs-wait-for-batchmates knob:

* ``window=0`` — admit immediately (lowest queue latency, no batching);
* ``window=w`` — a group is held until its *oldest* request has waited
  ``w`` seconds (or the group reaches ``max_batch``), so a burst of
  compatible requests amortizes planning and dispatch into one program
  at the price of up to ``w`` seconds of queueing.

Time comes from the service's injected clock, so the window is exactly
testable with a :class:`repro.testing.VirtualClock`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["PendingRequest", "AdmissionQueue"]


@dataclass
class PendingRequest:
    """One queued request: the ticket it answers plus its admission key
    ingredients (``spec`` frozen, ``periods`` the requested horizon).

    ``band`` is the K-band sub-bucketing width when the service runs with
    ``bands=True`` (``repro.topology.band_width`` of the fleet size):
    requests only merge within their band, so a K=8 arrival never admits
    into a K=10240 neighbour's padded program.

    ``deadline`` is an optional service-clock completion target: due
    groups admit in order of *slack* (deadline minus now, tightest
    first), so an urgent late arrival overtakes deadline-less batchmates
    at the admission gate without touching the in-flight preemption
    policy.  ``None`` means no deadline — infinite slack, FIFO among
    themselves (the pre-deadline behaviour, bit-for-bit)."""
    ticket: object
    spec: object
    periods: int
    priority: int
    submitted_at: float
    seq: int                      # global submission order (FIFO ties)
    band: Optional[int] = None
    deadline: Optional[float] = None

    def slack(self, now: float) -> float:
        """Seconds until this request's deadline (+inf when none)."""
        if self.deadline is None:
            return float("inf")
        return self.deadline - now

    @property
    def group_key(self) -> tuple:
        return (self.spec.bucket_key(), self.periods, self.band)


@dataclass
class AdmissionQueue:
    """Online bucketer over the arrival stream (see module doc)."""
    window: float = 0.0
    max_batch: Optional[int] = None
    _groups: Dict[tuple, List[PendingRequest]] = field(default_factory=dict)

    def __post_init__(self):
        if self.window < 0:
            raise ValueError(f"window must be >= 0, got {self.window}")
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError(
                f"max_batch must be >= 1, got {self.max_batch}")

    @property
    def pending(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def push(self, req: PendingRequest) -> None:
        self._groups.setdefault(req.group_key, []).append(req)

    def _due(self, group: List[PendingRequest], now: float) -> bool:
        if self.max_batch is not None and len(group) >= self.max_batch:
            return True
        return now - group[0].submitted_at >= self.window

    def pop_due(self, now: float,
                flush: bool = False) -> List[List[PendingRequest]]:
        """Remove and return every micro-batch due for admission at
        ``now`` (``flush=True`` ignores the window — drain semantics),
        ordered deadline-aware: micro-batches sort by their tightest
        member's slack (``PendingRequest.slack``), then by oldest member
        — so with no deadlines anywhere the order is exactly the old
        FIFO (every slack is +inf and the seq tiebreak decides).

        ``max_batch`` bounds the micro-batch *size*, not just the
        trigger: a due group larger than ``max_batch`` is sliced into
        consecutive ``max_batch``-sized admissions (submission order),
        which keeps compiled-program batch shapes small and *recurring* —
        the repeat-shape property the compile cache wins on.  When a
        group reached ``max_batch`` before its window expired, only the
        full slices admit; the remainder keeps waiting for batchmates.
        """
        batches: List[List[PendingRequest]] = []
        for key in list(self._groups):
            group = self._groups[key]
            if flush or self._due(group, now):
                window_due = flush or \
                    now - group[0].submitted_at >= self.window
                cap = self.max_batch or len(group)
                while len(group) >= cap and group:
                    batches.append(group[:cap])
                    group = group[cap:]
                if group and window_due:
                    batches.append(group)
                    group = []
                if group:
                    self._groups[key] = group
                else:
                    del self._groups[key]
        batches.sort(key=lambda g: (min(r.slack(now) for r in g),
                                    g[0].seq))
        return batches

    def next_due_at(self) -> Optional[float]:
        """The earliest service-clock time any queued group becomes due
        by window expiry (``None`` when the queue is empty).  Lets a
        driver with a virtual clock jump straight to the next admission
        instead of polling."""
        if not self._groups:
            return None
        return min(g[0].submitted_at + self.window
                   for g in self._groups.values())

"""The persistent compiled-program cache index.

The compiled executables themselves live in the engine's program caches
(``fed.engine``'s ``lru_cache``'d program constructors + each program's
jit cache, keyed on static config × abstract argument signature) — jax
already guarantees that dispatching a previously-seen shape skips
compilation entirely.  What the engine layer does *not* know is the
serving question: **will this admission compile or not, and how often do
we win?**  :class:`ProgramCache` is that index: it tracks every
:func:`~repro.api.lowering.program_key` ever dispatched and classifies
each admission warm (all of its chunk programs seen before → zero new
``TraceEvent``s in the PR-6 ledger, test-enforced) or cold, feeding the
hit/miss counters ``ServiceStats`` reports.

Persistence has two scopes:

* **process scope** (default): the registry is class-shared, so every
  service instance in a process sees programs warmed by any other — a
  restarted service object re-admits known shapes warm because the jit
  caches it fronts are process-level too.
* **disk scope** (``persist_dir=``): best-effort enablement of jax's
  own compilation cache, which persists *compiled XLA executables*
  across processes.  The key registry stays process-scoped on purpose —
  in a fresh process a known shape still costs one trace (jax re-traces
  before consulting the XLA cache), so pre-marking disk-cached keys as
  warm would break the warm ⇒ zero-``TraceEvent`` contract.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

__all__ = ["ProgramCache"]


class ProgramCache:
    """Hit/miss index over dispatched program shapes (see module doc)."""

    _SHARED: Dict[tuple, int] = {}

    def __init__(self, shared: bool = True,
                 persist_dir: Optional[str] = None):
        self._seen: Dict[tuple, int] = (ProgramCache._SHARED if shared
                                        else {})
        self.hits = 0
        self.misses = 0
        if persist_dir is not None:
            self._enable_disk_cache(persist_dir)

    @staticmethod
    def _enable_disk_cache(path: str) -> bool:
        """Point jax's compilation cache at ``path`` (best-effort: older
        jax builds without the knob are tolerated silently)."""
        try:
            import jax
            jax.config.update("jax_compilation_cache_dir", str(path))
            return True
        except Exception:                                 # noqa: BLE001
            return False

    def __len__(self) -> int:
        return len(self._seen)

    def __contains__(self, key: tuple) -> bool:
        return key in self._seen

    def admit(self, keys: Iterable[tuple]) -> Tuple[int, int]:
        """Record one admission's program keys; returns ``(hits,
        misses)`` over the keys (a key both looked up and inserted here
        counts once).  ``misses == 0`` is the *warm admission* contract:
        every program this bucket will dispatch has already been traced
        and compiled in this process, so running it must add zero
        ``TraceEvent``s to the engine ledger."""
        hits = misses = 0
        for key in keys:
            if key in self._seen:
                self._seen[key] += 1
                hits += 1
            else:
                self._seen[key] = 1
                misses += 1
        self.hits += hits
        self.misses += misses
        return hits, misses

    def use_count(self, key: tuple) -> int:
        """How many admissions have dispatched ``key`` (0 = never)."""
        return self._seen.get(key, 0)

    @classmethod
    def clear_shared(cls) -> None:
        """Drop the process-shared registry (tests only — the jit caches
        it fronts are NOT cleared, so a cleared index under-reports
        warmth but never breaks the warm ⇒ no-trace contract)."""
        cls._SHARED.clear()

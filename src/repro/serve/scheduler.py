"""Chunk-granular preemptive scheduling over parked :class:`BucketRun`s.

PR 5 made every horizon a resumable chunked scan with an explicit engine
carry — which means a *suspended run is just parked state*: nothing holds
the device between chunks, and the scheduler is free to hand the next
chunk slot to whichever admitted run is hottest.  Preemption therefore
costs nothing semantically (chunked execution is interleaving-invariant;
a preempted-then-resumed run is bit-identical to its uninterrupted twin,
test-enforced) — the policy here only decides *latency*: a long-horizon
background run yields at its next chunk boundary when a hot request
arrives, instead of holding the device for its whole horizon.

Policy: strict priority (lower number = hotter), FIFO admission order
within a priority level, one chunk per scheduling decision.  A switch
away from an unfinished run counts as a **preemption** (the run is
parked — its in-flight work fenced via
:meth:`~repro.api.lowering.BucketRun.park`); scheduling a previously
parked run again counts as a **resume**.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.api.lowering import BucketRun

__all__ = ["ServiceRun", "PreemptiveScheduler"]


@dataclass
class ServiceRun:
    """One admitted micro-batch in flight: the resumable
    :class:`~repro.api.lowering.BucketRun` plus its service metadata.

    ``requests`` are the admitted :class:`PendingRequest`s in submission
    order; ``deliveries`` holds one ``(ticket, take)`` pair per request —
    ``take`` the computed-row indices, in the ticket's local row order,
    that fan each collected chunk back out to the tickets that asked for
    it (duplicate (spec, seed) pairs across concurrent requests share one
    computed row, exactly like the static ``Experiment`` dedup).
    """
    run: BucketRun
    requests: list
    priority: int
    seq: int                       # admission order (FIFO ties)
    warm: bool                     # every program key was cache-warm
    deliveries: List[tuple] = field(default_factory=list)
    trace_mark: int = 0            # engine ledger length at admission
    parked: bool = False

    @property
    def done(self) -> bool:
        return self.run.done


class PreemptiveScheduler:
    """Priority/FIFO chunk scheduler with preemption accounting."""

    def __init__(self, stats=None):
        self._active: List[ServiceRun] = []
        self._current: Optional[ServiceRun] = None
        self.stats = stats

    @property
    def active(self) -> tuple:
        return tuple(self._active)

    @property
    def current(self) -> Optional[ServiceRun]:
        return self._current

    def add(self, run: ServiceRun) -> None:
        self._active.append(run)

    def pick(self) -> Optional[ServiceRun]:
        """Choose the run that gets the next chunk slot; accounts the
        preemption/resume transitions this choice implies."""
        if not self._active:
            self._current = None
            return None
        chosen = min(self._active, key=lambda r: (r.priority, r.seq))
        prev = self._current
        if prev is not None and prev is not chosen and not prev.done:
            # a hotter run takes the slot: park the incumbent at its
            # chunk boundary (fences in-flight device work; the banked
            # chunks were already streamed at collect time)
            prev.run.park()
            prev.parked = True
            if self.stats is not None:
                self.stats.preemptions += 1
        if chosen.parked:
            chosen.parked = False
            if self.stats is not None:
                self.stats.resumes += 1
        self._current = chosen
        return chosen

    def remove(self, run: ServiceRun) -> None:
        self._active.remove(run)
        if self._current is run:
            self._current = None

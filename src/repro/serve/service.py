"""The experiment service: streaming scenario arrivals in, chunked
results out.

:class:`ExperimentService` is the long-running counterpart of the static
:class:`~repro.api.Experiment`: instead of a grid known up front, it
accepts :class:`~repro.api.ScenarioSpec` requests *over time*
(:meth:`submit` → :class:`Ticket`) and streams each request's results
back chunk by chunk.  The moving parts, each its own module:

* :class:`~repro.serve.admission.AdmissionQueue` — online bucketing:
  compatible arrivals (same ``bucket_key`` + horizon) inside the batching
  window merge into one compiled-program micro-batch;
* :class:`~repro.serve.program_cache.ProgramCache` — the persistent
  compile-cache index: admissions whose every chunk-program shape was
  dispatched before are *warm* and must record zero new ``TraceEvent``s
  in the PR-6 engine ledger (test-enforced);
* :class:`~repro.serve.scheduler.PreemptiveScheduler` — chunk-granular
  preemption over PR 5's resumable :class:`~repro.api.lowering.BucketRun`:
  a long horizon parks at a chunk boundary when a hotter request arrives
  and later resumes bit-identically (suspended runs are just parked
  state);
* :class:`~repro.serve.stats.ServiceStats` — counters and latency
  percentiles (the ``BENCH_serve.json`` surface).

The service is single-threaded and *step-driven*: :meth:`step` performs
due admissions and runs at most one chunk of the hottest active run.
Time comes from an injected clock (``repro.testing.VirtualClock`` /
``WallClock``), so tests and the load generator drive arrival tapes and
measure latency without a single ``time.sleep``.  Drive it like::

    svc = ExperimentService(data, test, chunk_periods=2, window=0.01)
    t = svc.submit(spec, periods=40)          # returns immediately
    while not t.done:
        svc.step()                            # admit + one chunk
        view = t.partial()                    # complete=False Results
    final = t.result()                        # bit-identical to the
                                              # Experiment twin

NOT the LLM decode demo: ``launch/serve.py`` / ``examples/
decode_batched.py`` serve *token decoding* for the model-zoo side of the
repo; this package is the FEEL experiment service the ROADMAP's
experiment-as-a-service item names.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.api import lowering
from repro.api.results import Results, assign_row_coords, empty_coords
from repro.api.spec import ScenarioSpec
from repro.fed import engine
from repro.launch.mesh import ensure_batch_mesh, pad_batch
from repro.serve.admission import AdmissionQueue, PendingRequest
from repro.serve.program_cache import ProgramCache
from repro.serve.scheduler import PreemptiveScheduler, ServiceRun
from repro.serve.stats import RequestRecord, ServiceStats
from repro.testing.clock import WallClock
from repro.topology import band_width

__all__ = ["ExperimentService", "Ticket"]


class Ticket:
    """One submitted request's streaming result surface.

    The service delivers results chunk by chunk as the scheduler runs the
    request's bucket; :meth:`partial` exposes everything delivered so far
    as a ``complete=False`` :class:`~repro.api.results.Results` view (the
    same named-coordinate surface the static API returns — ``sel`` /
    ``speed`` / ``final_acc`` all work mid-stream), and :meth:`result`
    returns the complete view once :attr:`done`.
    """

    def __init__(self, spec: ScenarioSpec, periods: int, priority: int,
                 record: RequestRecord):
        self.spec = spec
        self.periods = periods
        self.priority = priority
        self.record = record
        self.n_rows = len(spec.seeds)
        self._coords = empty_coords(self.n_rows)
        for i, seed in enumerate(spec.seeds):
            assign_row_coords(self._coords, i, spec, seed)
        self._chunks: List[tuple] = []
        self.collected = 0

    @property
    def done(self) -> bool:
        return self.collected >= self.periods

    @property
    def admitted(self) -> bool:
        return self.record.admitted_at is not None

    def _deliver(self, chunk: tuple, p_c: int) -> None:
        self._chunks.append(chunk)
        self.collected += p_c

    def _series(self) -> tuple:
        if not self._chunks:
            z = np.zeros((self.n_rows, 0))
            return z, z, z.astype(np.float64), z.astype(np.int64)
        return tuple(np.concatenate([c[j] for c in self._chunks], axis=1)
                     for j in range(4))

    def partial(self) -> Results:
        """Everything delivered so far (``complete`` flips once the full
        horizon has streamed in; before that, a zero-period view is a
        legitimate selection surface, never an error)."""
        losses, accs, times, gb = self._series()
        return Results(coords=self._coords, losses=losses, accs=accs,
                       times=times, global_batch=gb, n_buckets=1,
                       complete=self.done)

    def result(self) -> Results:
        """The complete per-request ``Results``; raises while chunks are
        still outstanding."""
        if not self.done:
            raise RuntimeError(
                f"request not complete: {self.collected} of "
                f"{self.periods} periods delivered")
        return self.partial()


class ExperimentService:
    """Long-running FEEL experiment service (see module docstring).

    ``chunk_periods`` is the scheduling granularity: horizons execute as
    resumable chunks of this many periods (closed-loop ``replan=`` specs
    chunk at their replan interval instead, exactly like the static
    executors), and every chunk boundary is a preemption point.
    ``window`` / ``max_batch`` tune the admission micro-batcher;
    ``audit=True`` runs the PR-6 static passes (padding taint + compile
    hygiene) over every *cold* admission's program before it dispatches,
    accumulating into :attr:`audit_report` (error findings raise).
    ``bands=True`` sub-buckets admissions by power-of-two K band
    (``repro.topology.band_width``): requests pad to their band instead
    of whatever fleet happens to share the window, so the program-cache
    key space stays small and recurring across a massive-fleet mix.
    """

    def __init__(self, data, test, *, chunk_periods: int = 1,
                 window: float = 0.0, max_batch: Optional[int] = None,
                 clock=None, cache: Optional[ProgramCache] = None,
                 mesh=None, audit: bool = False, bands: bool = False):
        if chunk_periods < 1:
            raise ValueError(
                f"chunk_periods must be >= 1, got {chunk_periods}")
        self.data = data
        self.test = test
        self.chunk_periods = chunk_periods
        self.clock = clock if clock is not None else WallClock()
        self.cache = cache if cache is not None else ProgramCache()
        self.mesh = None if mesh is None else ensure_batch_mesh(mesh)
        self.audit = audit
        self.bands = bands
        self.audit_report = None
        self.stats = ServiceStats()
        self._admission = AdmissionQueue(window=window, max_batch=max_batch)
        self._scheduler = PreemptiveScheduler(stats=self.stats)
        self._seq = 0

    # ---- request surface --------------------------------------------------
    def submit(self, spec: ScenarioSpec, periods: int,
               priority: int = 0,
               deadline: Optional[float] = None) -> Ticket:
        """Enqueue one scenario request; returns its :class:`Ticket`
        immediately (admission happens on a later :meth:`step`, once the
        batching window admits the request's group).  Lower ``priority``
        numbers are hotter — they take the next chunk slot from any
        cooler run already in flight.  ``deadline`` (service-clock
        seconds) makes admission deadline-aware: due groups admit
        tightest-slack first instead of FIFO."""
        if not isinstance(spec, ScenarioSpec):
            raise TypeError(f"submit expects a ScenarioSpec, got "
                            f"{type(spec).__name__}")
        if periods < 1:
            raise ValueError(f"periods must be >= 1, got {periods}")
        if spec.adapt_tau is not None:
            raise ValueError(
                "adaptive local steps (adapt_tau=) compile one program "
                "variant per realized τ, so the admission-time program "
                "key is undecidable; the serving layer rejects such specs")
        now = self.clock.now()
        record = RequestRecord(
            ticket_id=self._seq, label=spec.label, periods=periods,
            priority=priority, submitted_at=now)
        ticket = Ticket(spec, periods, priority, record)
        self.stats.on_submit(record)
        self._admission.push(PendingRequest(
            ticket=ticket, spec=spec, periods=periods, priority=priority,
            submitted_at=now, seq=self._seq,
            band=band_width(spec.k) if self.bands else None,
            deadline=deadline))
        self._seq += 1
        return ticket

    def reset_stats(self) -> ServiceStats:
        """Start a fresh measurement window (e.g. after a warm-up phase):
        replaces :attr:`stats` with a zeroed :class:`ServiceStats`.  The
        compile cache, admission queue and active runs are untouched — only
        the counters and latency records restart."""
        self.stats = ServiceStats()
        self._scheduler.stats = self.stats
        return self.stats

    # ---- service loop -----------------------------------------------------
    @property
    def idle(self) -> bool:
        """No queued arrivals and no admitted run with work left."""
        return (self._admission.pending == 0
                and not any(not r.done for r in self._scheduler.active))

    def next_admission_at(self) -> Optional[float]:
        """Earliest clock time a queued group becomes window-due (lets a
        virtual-clock driver jump straight there)."""
        return self._admission.next_due_at()

    def step(self, flush: bool = False) -> bool:
        """One service-loop turn: perform due admissions, then run one
        chunk of the hottest active run.  Returns whether any work
        happened (``False`` = idle at the current clock time).
        ``flush=True`` admits every queued group regardless of the
        batching window (drain semantics)."""
        admitted = self._admit_due(flush=flush)
        return self._run_one_chunk() or admitted

    def drain(self) -> None:
        """Flush the admission queue and run until every ticket is done."""
        while not self.idle:
            self.step(flush=True)

    # ---- internals --------------------------------------------------------
    def _admit_due(self, flush: bool) -> bool:
        groups = self._admission.pop_due(self.clock.now(), flush=flush)
        for group in groups:
            self._admit(group)
        return bool(groups)

    def _admit(self, group: List[PendingRequest]) -> None:
        now = self.clock.now()
        buckets = lowering.group_rows([r.spec for r in group],
                                      bands=self.bands)
        assert len(buckets) == 1, "admission groups on bucket_key"
        bucket = buckets[0]
        chunk = (bucket.replan if bucket.replan is not None
                 else self.chunk_periods)
        periods = group[0].periods

        n = len(bucket.rows)
        n_exec = n + (pad_batch(n, self.mesh) if self.mesh is not None
                      else 0)
        keys = lowering.bucket_program_keys(
            bucket, n_exec, periods, chunk, self.data, self.test)
        hits, misses = self.cache.admit(keys)
        self.stats.on_admission([r.ticket.record for r in group], now,
                                hits=hits, misses=misses)
        if self.audit and misses:
            self._audit_cold(bucket, min(chunk, periods))

        run = lowering.BucketRun(bucket, self.data, self.test, periods,
                                 chunk, mesh=self.mesh)
        srun = ServiceRun(
            run=run, requests=list(group),
            priority=min(r.priority for r in group),
            seq=min(r.seq for r in group), warm=(misses == 0),
            trace_mark=engine.trace_count())
        # fan-out map: output index -> computed row, then one take per
        # request in its local row order (group_rows flattens the group's
        # specs x seeds in submission order)
        computed_of = {}
        for j, row in enumerate(bucket.rows):
            for i in row.indices:
                computed_of[i] = j
        offset = 0
        for req in group:
            take = np.array([computed_of[offset + l]
                             for l in range(len(req.spec.seeds))], np.int64)
            srun.deliveries.append((req.ticket, take))
            offset += len(req.spec.seeds)
        self._scheduler.add(srun)

    def _audit_cold(self, bucket, chunk_len: int) -> None:
        """PR-6 static passes over a cold admission's program (padding
        taint + compile hygiene; probe-only — no device work, no ledger
        pollution).  Error findings raise before anything dispatches."""
        from repro.analysis import compile_audit, taint
        from repro.analysis.report import AuditReport
        if self.audit_report is None:
            self.audit_report = AuditReport()
        plan = lowering.plan_bucket(bucket, self.data, chunk_len)
        traced = lowering.trace_bucket(plan, self.data, self.test)
        taint.analyze_jaxpr(traced.closed, traced.in_labels,
                            traced.out_contracts, program=traced.program,
                            report=self.audit_report)
        compile_audit.audit_jaxpr_hygiene(
            traced.closed, program=traced.program,
            report=self.audit_report)
        self.audit_report.raise_on_error()

    def _run_one_chunk(self) -> bool:
        srun = self._scheduler.pick()
        if srun is None:
            return False
        mark = engine.trace_count()
        if srun.run.can_advance:
            srun.run.advance()
        p_before = srun.run.collected
        chunk = srun.run.collect()
        p_c = srun.run.collected - p_before
        now = self.clock.now()
        records = [r.ticket.record for r in srun.requests]
        self.stats.on_chunk(records, now,
                            traces=engine.trace_count() - mark,
                            warm=srun.warm)
        for ticket, take in srun.deliveries:
            ticket._deliver(tuple(arr[take] for arr in chunk), p_c)
        if srun.done:
            self.stats.on_complete(records, now)
            self._scheduler.remove(srun)
        return True

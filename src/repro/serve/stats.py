"""Service observability: counters, latency records, percentiles.

One :class:`ServiceStats` per :class:`~repro.serve.ExperimentService`.
Everything here is plain host bookkeeping — no device work — and
``to_dict()`` is the JSON surface ``benchmarks/serve_load.py`` emits as
``BENCH_serve.json``.

Latency conventions (all in service-clock seconds, whatever clock the
service was built with):

* **queue latency** — submit → admission (the online bucketer's
  admit-now-vs-wait-for-batchmates cost);
* **first-result latency** — submit → first chunk of results delivered
  (the streaming surface's time-to-first-byte);
* **result latency** — submit → final chunk delivered (what the p50/p99
  acceptance numbers are computed over).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

__all__ = ["RequestRecord", "ServiceStats"]


@dataclass
class RequestRecord:
    """Lifecycle timestamps of one submitted request (``None`` until the
    corresponding transition happens)."""
    ticket_id: int
    label: str
    periods: int
    priority: int
    submitted_at: float
    admitted_at: Optional[float] = None
    first_result_at: Optional[float] = None
    completed_at: Optional[float] = None

    @property
    def queue_latency(self) -> Optional[float]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def first_result_latency(self) -> Optional[float]:
        if self.first_result_at is None:
            return None
        return self.first_result_at - self.submitted_at

    @property
    def result_latency(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


@dataclass
class ServiceStats:
    """Counters + request records for one service instance."""
    submitted: int = 0
    admitted_requests: int = 0
    admissions: int = 0            # admitted buckets (micro-batches)
    completed: int = 0
    chunks: int = 0                # chunk dispatch+collect cycles run
    preemptions: int = 0           # scheduler switched off an unfinished run
    resumes: int = 0               # a previously-parked run ran again
    cache_hits: int = 0            # program keys admitted already warm
    cache_misses: int = 0          # program keys admitted cold
    warm_admissions: int = 0       # admissions with every program key warm
    cold_admissions: int = 0
    new_traces: int = 0            # TraceEvents recorded across all chunks
    warm_admission_traces: int = 0  # ledger entries charged to warm
    #                                 admissions — the zero-retrace contract
    records: List[RequestRecord] = field(default_factory=list)

    # ---- transitions ------------------------------------------------------
    def on_submit(self, record: RequestRecord) -> None:
        self.submitted += 1
        self.records.append(record)

    def on_admission(self, records, now: float, *, hits: int,
                     misses: int) -> None:
        self.admissions += 1
        self.admitted_requests += len(records)
        self.cache_hits += hits
        self.cache_misses += misses
        if misses == 0:
            self.warm_admissions += 1
        else:
            self.cold_admissions += 1
        for r in records:
            r.admitted_at = now

    def on_chunk(self, records, now: float, *, traces: int,
                 warm: bool) -> None:
        self.chunks += 1
        self.new_traces += traces
        if warm:
            self.warm_admission_traces += traces
        for r in records:
            if r.first_result_at is None:
                r.first_result_at = now

    def on_complete(self, records, now: float) -> None:
        for r in records:
            r.completed_at = now
            self.completed += 1

    # ---- derived ----------------------------------------------------------
    @property
    def cache_hit_rate(self) -> float:
        """Warm fraction of all program keys admitted (0.0 when none)."""
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def latencies(self, kind: str = "result") -> np.ndarray:
        """Finished ``kind`` latencies (seconds), submission order.
        ``kind``: ``result`` | ``first_result`` | ``queue``."""
        attr = f"{kind}_latency"
        vals = [getattr(r, attr) for r in self.records]
        return np.array([v for v in vals if v is not None], np.float64)

    def percentiles(self, qs=(50.0, 99.0), kind: str = "result") -> Dict:
        lat = self.latencies(kind)
        if not len(lat):
            return {f"p{q:g}": None for q in qs}
        return {f"p{q:g}": float(np.percentile(lat, q)) for q in qs}

    def to_dict(self) -> Dict:
        """The JSON-ready summary (``BENCH_serve.json`` schema)."""
        return {
            "submitted": self.submitted,
            "admitted_requests": self.admitted_requests,
            "admissions": self.admissions,
            "completed": self.completed,
            "chunks": self.chunks,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": self.cache_hit_rate,
            "warm_admissions": self.warm_admissions,
            "cold_admissions": self.cold_admissions,
            "new_traces": self.new_traces,
            "warm_admission_traces": self.warm_admission_traces,
            "latency": self.percentiles((50.0, 90.0, 99.0)),
            "first_result_latency":
                self.percentiles((50.0, 99.0), kind="first_result"),
            "queue_latency": self.percentiles((50.0, 99.0), kind="queue"),
        }

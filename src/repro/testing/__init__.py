"""Test-support utilities shipped with the library.

``repro.testing.proptest`` provides the property-testing surface the
test suite uses: the real ``hypothesis`` package when it is installed,
or a minimal API-compatible fallback driver when it is not — so the
property tests *run* everywhere instead of skipping on lean images.

``repro.testing.no_retrace`` is the compile-discipline guard: a context
manager asserting exactly how many jit traces a block may cost (default
zero), replacing ad-hoc ``engine.trace_count()`` before/after pairs.

``repro.testing.clock`` / ``repro.testing.arrivals`` are the serving
layer's determinism fixtures: a manually-advanced :class:`VirtualClock`
(service tests never ``time.sleep``) and seeded Poisson/burst
arrival-process generators shared by ``tests/test_serve.py`` and
``benchmarks/serve_load.py``.
"""
from __future__ import annotations

import contextlib

from repro.fed import engine
from repro.testing.arrivals import (assign_templates, burst_arrivals,
                                    poisson_arrivals)
from repro.testing.clock import VirtualClock, WallClock

__all__ = ["VirtualClock", "WallClock", "assign_templates",
           "burst_arrivals", "no_retrace", "poisson_arrivals"]


@contextlib.contextmanager
def no_retrace(expect: int = 0):
    """Assert the block traces exactly ``expect`` trajectory programs.

    ``expect=0`` (the default) guards warm paths — chunked resumption,
    replan rounds, cache hits across a grid — where any trace is a
    retrace bug.  ``expect=n`` pins a cold path's trace budget (e.g. one
    trace for a fresh bucket).  On top of the count, the structured
    ledger is checked for duplicate (kind, key, signature) events across
    the WHOLE process history: a duplicate means jax traced the same
    program twice for the same abstract inputs, which the count alone
    can miss when one legitimate cold trace masks one retrace.

    Usage::

        with no_retrace():            # warm path: zero traces allowed
            run.advance()
        with no_retrace(expect=1):    # cold path: exactly one trace
            exp.run(periods=3)
    """
    before = engine.trace_count()
    yield
    got = engine.trace_count() - before
    assert got == expect, (
        f"expected exactly {expect} jit trace(s) in block, got {got}; "
        f"trace events: {engine.trace_events()[before:]}")
    events = engine.trace_events()
    seen = {}
    for i, ev in enumerate(events):
        dup = seen.get(ev)
        assert dup is None, (
            f"duplicate trace (retrace) of {ev.kind} program: event #{i} "
            f"repeats event #{dup}: key={ev.key}")
        seen[ev] = i

"""Test-support utilities shipped with the library.

``repro.testing.proptest`` provides the property-testing surface the
test suite uses: the real ``hypothesis`` package when it is installed,
or a minimal API-compatible fallback driver when it is not — so the
property tests *run* everywhere instead of skipping on lean images.
"""

"""Seeded arrival-process generators for the serving layer.

The service benchmarks and tests share these fixtures: a workload is a
sorted array of arrival *times* (seconds on the service clock) zipped
with request templates.  Everything is a pure function of its seed —
``poisson_arrivals(rate, n, seed=7)`` is the same tape on every machine —
so service tests replay identical traffic without a single
``time.sleep`` (drive a :class:`repro.testing.VirtualClock` along the
tape instead).

Cumulative times use the *seeded* cumsum form (``cumsum([[start], gaps])``)
rather than ``cumsum(gaps) + start``: float addition is non-associative
and the repo's ledgers treat the seeded form as the only bit-stable one
(see ``analysis.determinism``) — the arrival tapes follow the same
discipline so two tapes differing only in ``start`` stay exactly
translation-consistent.
"""
from __future__ import annotations

from itertools import cycle, islice
from typing import Sequence

import numpy as np

__all__ = ["poisson_arrivals", "burst_arrivals", "assign_templates"]


def _seeded_cumsum(start: float, gaps: np.ndarray) -> np.ndarray:
    return np.cumsum(np.concatenate([[float(start)], gaps]))[1:]


def poisson_arrivals(rate: float, n: int, seed: int,
                     start: float = 0.0) -> np.ndarray:
    """``(n,)`` f64 arrival times of a homogeneous Poisson process.

    ``rate`` is arrivals per second (exponential inter-arrival gaps with
    mean ``1/rate``); deterministic per ``seed``.
    """
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / rate, size=n)
    return _seeded_cumsum(start, gaps)


def burst_arrivals(bursts: int, size: int, spacing: float,
                   intra: float = 0.0, seed: int = 0,
                   start: float = 0.0) -> np.ndarray:
    """``(bursts * size,)`` f64 times of a bursty process: ``bursts``
    groups ``spacing`` seconds apart, each of ``size`` near-simultaneous
    arrivals ``intra`` seconds apart inside the burst, plus a small
    seeded jitter (±10% of ``intra``, zero when ``intra`` is 0) so two
    bursts never produce byte-identical sub-tapes.
    """
    if bursts < 1 or size < 1:
        raise ValueError(f"bursts and size must be >= 1, got "
                         f"({bursts}, {size})")
    rng = np.random.default_rng(seed)
    times = np.empty(bursts * size, np.float64)
    for b in range(bursts):
        base = start + b * spacing
        offs = np.arange(size) * intra
        if intra > 0:
            offs = offs + rng.uniform(0.0, 0.1 * intra, size=size)
        times[b * size:(b + 1) * size] = base + offs
    return np.sort(times)


def assign_templates(times: np.ndarray,
                     templates: Sequence) -> list:
    """Zip an arrival tape with request templates, round-robin: returns
    ``[(t_0, templates[0]), (t_1, templates[1]), ...]`` cycling through
    ``templates`` — the repeat-shape workload shape the compile cache is
    benchmarked on (every template revisits its bucket shape)."""
    if not len(templates):
        raise ValueError("templates must be non-empty")
    return list(zip(np.asarray(times, np.float64).tolist(),
                    islice(cycle(templates), len(times))))

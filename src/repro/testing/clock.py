"""Deterministic time sources for the serving layer.

The experiment service (``repro.serve``) never reads ``time.time``
directly: it takes a *clock* object, so service tests are bit-reproducible
and sleep-free — a test advances a :class:`VirtualClock` by hand (or by
measured chunk durations, as ``benchmarks/serve_load.py`` does) instead of
waiting for wall time, and the admission window / latency stamps follow
the injected time exactly.  :class:`WallClock` is the production source.

The only contract is ``now() -> float`` (monotonic seconds).
"""
from __future__ import annotations

import time

__all__ = ["VirtualClock", "WallClock"]


class VirtualClock:
    """Manually-advanced monotonic clock (no relation to wall time).

    ``advance(dt)`` moves time forward by ``dt`` seconds; ``advance_to``
    jumps to an absolute timestamp (no-op when already past it, so
    replaying a sorted arrival tape can never move time backwards).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by negative dt ({dt})")
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        self._t = max(self._t, float(t))
        return self._t


class WallClock:
    """Monotonic wall-clock seconds (``time.perf_counter``), zeroed at
    construction so timestamps read as seconds-since-service-start."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

"""Property-testing surface: ``hypothesis`` when available, else a
minimal API-compatible fallback.

The test suite writes property tests against the hypothesis idiom::

    from repro.testing.proptest import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 100), x=st.floats(0.0, 1.0))
    def test_prop(n, x): ...

With ``hypothesis`` installed those names *are* hypothesis's (full
shrinking, database, profiles).  Without it, the fallback below drives
the same tests with deterministic pseudo-random examples — no shrinking,
but the failing example is printed and the seed is derived from the test
name, so failures reproduce exactly across runs and machines.  This is
the repo's "stub optional deps, never skip coverage" pattern: property
tests assert real invariants (solver feasibility, padding bit-identity,
grid round-trips) that must run even on images without the optional dep.

Profiles: ``load_profile_from_env()`` honours ``HYPOTHESIS_PROFILE``
(used by CI's quick property job) in both modes — under real hypothesis
it registers/loads ``ci`` (more examples) and ``dev`` profiles; the
fallback scales its default example count the same way.  Tests that pin
``max_examples`` explicitly keep their pinned count (hypothesis
semantics: the decorator wins over the profile), so the profile governs
the tests that leave it unset.
"""
from __future__ import annotations

import os
import zlib

import numpy as np

PROFILES = {"default": 20, "dev": 10, "ci": 100}

try:                                                  # pragma: no cover
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True

    def load_profile_from_env() -> str:
        """Register the repo's profiles and load ``HYPOTHESIS_PROFILE``."""
        for name, n in PROFILES.items():
            settings.register_profile(name, max_examples=n, deadline=None)
        profile = os.environ.get("HYPOTHESIS_PROFILE", "default")
        settings.load_profile(profile if profile in PROFILES else "default")
        return profile

except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    _ACTIVE = {"profile": "default"}

    def load_profile_from_env() -> str:
        profile = os.environ.get("HYPOTHESIS_PROFILE", "default")
        _ACTIVE["profile"] = profile if profile in PROFILES else "default"
        return _ACTIVE["profile"]

    class SearchStrategy:
        """A draw rule: ``example(rng)`` produces one value."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: np.random.Generator):
            return self._draw(rng)

        def map(self, fn) -> "SearchStrategy":
            return SearchStrategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        """The ``hypothesis.strategies`` subset the suite draws from."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> SearchStrategy:
            return SearchStrategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float, max_value: float) -> SearchStrategy:
            return SearchStrategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans() -> SearchStrategy:
            return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq) -> SearchStrategy:
            seq = list(seq)
            return SearchStrategy(
                lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements: SearchStrategy, min_size: int = 0,
                  max_size: int = 10) -> SearchStrategy:
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]
            return SearchStrategy(draw)

        @staticmethod
        def tuples(*parts: SearchStrategy) -> SearchStrategy:
            return SearchStrategy(
                lambda rng: tuple(p.example(rng) for p in parts))

    strategies = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        """Pin a test's example count (``deadline`` accepted, unused)."""
        def deco(fn):
            if max_examples is not None:
                fn._proptest_max_examples = max_examples
            return fn
        return deco

    def given(**named_strategies):
        """Run the wrapped test once per drawn example.

        The rng seed derives from the test's qualified name, so the
        example sequence is stable across runs; the active profile sets
        the example count unless the test pinned one via ``settings``.
        On failure the falsifying example is printed and the original
        exception re-raised (no shrinking).
        """
        def deco(fn):
            def wrapper(*args, **kwargs):
                # settings() may sit above (attribute on wrapper) or
                # below (attribute on fn) this decorator — honour both
                n = getattr(wrapper, "_proptest_max_examples",
                            getattr(fn, "_proptest_max_examples", None))
                if n is None:
                    n = PROFILES[_ACTIVE["profile"]]
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    example = {k: s.example(rng)
                               for k, s in named_strategies.items()}
                    try:
                        fn(*args, **{**kwargs, **example})
                    except Exception:
                        print(f"proptest: falsifying example "
                              f"({fn.__qualname__}, run {i}): {example!r}")
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._proptest_inner = fn
            return wrapper
        return deco

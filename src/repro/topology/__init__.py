"""Massive-fleet topology: per-round client sampling, hierarchical
cell→edge→cloud aggregation, and K-banded sub-bucketing.

The three legs that take the fleet axis from K<=16 to K=10^4+:

* :class:`Sampling` / :class:`ParticipationSampler` — S-of-K per-period
  participation as a *time-varying* mask through the PR-4 active-mask
  machinery (the static mask is the T=constant special case);
* :class:`Topology` — two-tier edge aggregation with a per-cell
  Algorithm-1 solve and a wired backhaul ledger on cloud rounds;
* :func:`band_width` / :func:`split_bands` — powers-of-two sub-bucket
  pads so mixed-K grids compile one program per band, not per K.
"""
from repro.topology.bands import band_width, split_bands
from repro.topology.hierarchy import Topology
from repro.topology.sampling import ParticipationSampler, Sampling

__all__ = ["Sampling", "ParticipationSampler", "Topology",
           "band_width", "split_bands"]

"""K-banded sub-bucketing: powers-of-two user-axis pads.

PR 4 made fleet size a sweep axis by padding every row of a bucket to the
bucket's max K.  That is the right call for *near*-K grids, but a
``users=[8, 1024, 10240]`` grid would run its 8-user row at width 10240 —
a ~1000x FLOP tax on the smallest member.  Banding splits each bucket's
rows into powers-of-two K *bands* (8 → band 8, 1024 → band 1024, 10240 →
band 16384): one compiled program per band instead of one per K, and
within a band the PR-4 active-mask contract applies unchanged, so results
stay bit-identical to the unbanded (and to the solo) run.

Band width doubles as the band's ``k_pad``; since ``program_key`` already
carries ``k_pad``, banded programs land in the serve-path
:class:`~repro.serve.program_cache.ProgramCache` under per-band keys — a
warm band admission is warm no matter which true K arrives next.
"""
from __future__ import annotations

from typing import Dict, List

__all__ = ["band_width", "split_bands"]


def band_width(k: int) -> int:
    """Smallest power of two >= k (the band's padded user-axis width)."""
    if k < 1:
        raise ValueError(f"band_width needs k >= 1, got {k}")
    return 1 << (k - 1).bit_length()


def split_bands(rows: List) -> Dict[int, List]:
    """Group bucket rows (anything with ``.spec.k``) by band, preserving
    first-seen band order and row order within each band."""
    bands: Dict[int, List] = {}
    for row in rows:
        bands.setdefault(band_width(row.spec.k), []).append(row)
    return bands

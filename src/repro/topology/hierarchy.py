"""Two-tier cell → edge-server → cloud aggregation topology.

:class:`Topology` is the frozen spec-side value (``ScenarioSpec.topology``):
the fleet's K users split contiguously across ``cells`` wireless cells,
the cells split contiguously across ``edges`` edge servers, and the edge
servers sync to the cloud every ``agg_every`` periods over a wired
backhaul.  Semantics (HierFAVG-style, after the edge/client selection in
the ``drzhang3/Fed`` server and the hierarchy surveyed by Qin et al.
2005.05265):

* every period, Algorithm 1 allocates batchsize/slots *within each cell*
  (a masked per-cell rows solve over the same channel draws the flat
  scenario uses — the cell partition is a mask, not a new Monte-Carlo
  stream), and each edge server aggregates its own users' gradients into
  its own model replica;
* every ``agg_every``-th period is a *cloud round*: edge replicas merge
  into the batch-weighted global average (which is also the model every
  reported metric evaluates), and the period's latency ledger gains the
  edge→cloud backhaul round trip on top of the slowest cell's radio
  round;
* ``(cells, edges, agg_every)`` is *structural* (it shapes the compiled
  hierarchical scan: number of edge replicas, cloud-merge cadence), while
  ``backhaul_bps`` only changes ledger values — so scenarios differing
  only in backhaul rate share one program.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Topology"]


@dataclass(frozen=True)
class Topology:
    """Cell→edge→cloud grouping for one scenario (see module docstring)."""
    cells: int = 2
    edges: int = 1
    agg_every: int = 1
    backhaul_bps: float = 1e9

    def __post_init__(self):
        for name in ("cells", "edges", "agg_every"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"topology {name} must be a positive int, got {v!r}")
        if self.edges > self.cells:
            raise ValueError(
                f"topology needs edges <= cells, got {self.edges} edge "
                f"servers over {self.cells} cells")
        if self.backhaul_bps <= 0:
            raise ValueError(
                f"backhaul_bps must be positive, got {self.backhaul_bps!r}")

    # ---- structural identity ---------------------------------------------
    def structural_key(self) -> tuple:
        """The bucket-key element: everything that shapes the compiled
        hierarchical scan.  ``backhaul_bps`` is absent on purpose (ledger
        values only)."""
        return (self.cells, self.edges, self.agg_every)

    # ---- membership ------------------------------------------------------
    def cell_of_users(self, k: int) -> np.ndarray:
        """Contiguous user→cell assignment, ``(k,)`` int."""
        if k < self.cells:
            raise ValueError(
                f"fleet of {k} users cannot populate {self.cells} cells")
        out = np.empty(k, np.int64)
        for c, idx in enumerate(np.array_split(np.arange(k), self.cells)):
            out[idx] = c
        return out

    def edge_of_cells(self) -> np.ndarray:
        """Contiguous cell→edge assignment, ``(cells,)`` int."""
        out = np.empty(self.cells, np.int64)
        for e, idx in enumerate(np.array_split(np.arange(self.cells),
                                               self.edges)):
            out[idx] = e
        return out

    def cell_masks(self, k: int) -> np.ndarray:
        """``(cells, k)`` float {0,1} one-hot rows (disjoint, covering)."""
        cell = self.cell_of_users(k)
        return (cell[None, :] == np.arange(self.cells)[:, None]) * 1.0

    def member_matrix(self, k: int, k_pad: int = None) -> np.ndarray:
        """``(edges, k_pad)`` float32 user→edge one-hot; pad columns (users
        beyond the true fleet) belong to no edge — all-zero columns, so
        padded lanes carry the monoid identity through every edge
        contraction."""
        k_pad = k if k_pad is None else k_pad
        edge = self.edge_of_cells()[self.cell_of_users(k)]
        member = np.zeros((self.edges, k_pad), np.float32)
        member[edge, np.arange(k)] = 1.0
        return member

    # ---- ledgers ---------------------------------------------------------
    def cloud_rounds(self, periods: int, offset: int = 0) -> np.ndarray:
        """``(periods,)`` float32 {0,1}: 1 on cloud-round periods.  The
        cadence counts *global* periods (``offset`` = periods already
        planned), so chunked horizons reproduce the monolithic cadence."""
        p = offset + 1 + np.arange(periods)
        return (p % self.agg_every == 0).astype(np.float32)

    def backhaul_roundtrip(self, payload_bits: float) -> float:
        """Edge→cloud upload + cloud→edge broadcast wall time for one
        model-sized payload in each direction."""
        from repro.channels.model import wired_latency
        return (wired_latency(payload_bits, self.backhaul_bps)
                + wired_latency(payload_bits, self.backhaul_bps))

    def __str__(self) -> str:  # readable grid-axis coordinate
        return (f"c{self.cells}e{self.edges}a{self.agg_every}")

"""Per-round client sampling: S of K users participate each period.

:class:`Sampling` is the frozen spec-side value (``ScenarioSpec.sampling``)
— either a fixed per-period cohort ``size`` S or a ``fraction`` S/K, plus
its own seed.  :class:`ParticipationSampler` is the host-side stream that
realizes it as a *time-varying* participation mask, one ``(periods, K)``
{0,1} block per planned horizon.

Stream discipline (the bit-exactness contract):

* the sampler owns a dedicated rng stream derived from
  ``(scenario_seed, sampling.seed, _STREAM_TAG)`` — it never touches the
  channel-fading stream (``Cell.make(seed)``), the scheduler stream
  (``seed + 1``) or the batcher stream (``seed``), so adding sampling to
  a scenario leaves every other draw bit-identical;
* exactly one cohort permutation is consumed per planned period, so a
  horizon planned in chunks (PR 5) draws the same masks as the monolithic
  plan — chunked runs stay bit-identical to their uninterrupted twin;
* channel rates are still drawn for ALL K users every period (the mask
  selects, it does not re-shape the Monte-Carlo draw), and the data
  batcher's consumption is already independent of the realized batch, so
  a sampled-out period leaves both streams exactly where a participating
  period would.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = ["Sampling", "ParticipationSampler"]

# rng stream tag: keeps the participation stream disjoint from every
# other (seed, ...)-derived stream in the repo (see module docstring)
_STREAM_TAG = 0x5A17


@dataclass(frozen=True)
class Sampling:
    """Per-round participation policy: exactly one of ``size`` (fixed S
    users per period) or ``fraction`` (S = ceil(fraction * K)) is set.
    ``size`` larger than the fleet clamps to full participation, so one
    Sampling value can ride a ``users=[...]`` sweep axis unchanged.

    ``weighted=True`` turns on Horvitz-Thompson (1/p) importance
    correction of the sampled aggregation: the planner allocates
    batchsizes for the FULL fleet (so every user has a planned share
    b̄_k even when absent), each period's cohort aggregates against the
    *fixed* denominator p·Σ_all b̄_k instead of the realized Σ_cohort
    b_k, and the estimator's expectation equals the full-participation
    aggregate exactly — the realized-denominator mean is biased toward
    whichever users happen to show up, which matters at tiny cohort
    fractions (property-tested).  Weights no longer sum to one per draw
    (only in expectation); that variance is the price of unbiasedness."""
    size: Optional[int] = None
    fraction: Optional[float] = None
    seed: int = 0
    weighted: bool = False

    def __post_init__(self):
        if (self.size is None) == (self.fraction is None):
            raise ValueError(
                "Sampling needs exactly one of size= or fraction=, got "
                f"size={self.size!r} fraction={self.fraction!r}")
        if self.size is not None and (
                not isinstance(self.size, int)
                or isinstance(self.size, bool) or self.size < 1):
            raise ValueError(
                f"sampling size must be a positive int, got {self.size!r}")
        if self.fraction is not None and not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"sampling fraction must be in (0, 1], got {self.fraction!r}")
        if not isinstance(self.weighted, bool):
            raise TypeError(
                f"weighted must be a bool, got {self.weighted!r}")

    def s_of(self, k: int) -> int:
        """Cohort size for a K-user fleet (always in ``1..k``)."""
        if self.size is not None:
            return min(self.size, k)
        return min(k, max(1, int(np.ceil(self.fraction * k))))

    def p_of(self, k: int) -> float:
        """Per-user inclusion probability S/K (uniform cohorts)."""
        return self.s_of(k) / k

    def __str__(self) -> str:  # readable grid-axis coordinate
        w = "w" if self.weighted else ""
        if self.size is not None:
            return f"S{self.size}@{self.seed}{w}"
        return f"S{self.fraction:g}K@{self.seed}{w}"


class ParticipationSampler:
    """Seeded per-period cohort stream for one scenario row.

    ``draw(periods)`` returns a ``(periods, k)`` float32 {0,1} mask with
    exactly ``S = sampling.s_of(k)`` ones per row; consecutive calls
    continue the stream (chunked planning equals monolithic planning
    row-for-row)."""

    def __init__(self, sampling: Sampling, k: int, seed: int):
        self.sampling = sampling
        self.k = k
        self.s = sampling.s_of(k)
        self.rng = np.random.default_rng((seed, sampling.seed, _STREAM_TAG))

    def draw(self, periods: int) -> np.ndarray:
        mask = np.zeros((periods, self.k), np.float32)
        for p in range(periods):
            # one permutation per period, drawn even at S == k, so the
            # stream position depends only on how many periods were
            # planned — never on the cohort size
            mask[p, self.rng.permutation(self.k)[:self.s]] = 1.0
        return mask

"""Suite-wide fixtures/config.

Loads the property-testing profile from ``HYPOTHESIS_PROFILE`` (default
/ dev / ci) for both real hypothesis and the ``repro.testing.proptest``
fallback — CI's quick property job runs the ``ci`` profile with more
examples; tests that pin ``max_examples`` keep their pinned count.
"""
from repro.testing.proptest import load_profile_from_env

load_profile_from_env()

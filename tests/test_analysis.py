"""The static-analysis subsystem (repro.analysis): padding-taint
property tests on synthetic jaxprs (mask-dominated reductions certify,
the seeded poisoned-padding mutant is rejected), the acceptance sweep
(all four Table-II scheme programs + the ragged users=[4,8,16] padded
program certify), compile hygiene (x64 leak, folded constants, trace
ledger), the determinism lint, the ``Experiment.run(audit=True)`` hook,
the ``no_retrace`` guard, and the host↔device dtype boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import AuditError, AuditReport, Severity
from repro.analysis import compile_audit, determinism, taint
from repro.analysis.report import Finding
from repro.analysis.taint import LaneLabel, NO_LABEL, OutContract
from repro.api import Experiment, ScenarioSpec, SerialExecutor, grid
from repro.api.lowering import group_rows, plan_bucket, trace_bucket
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.testing import no_retrace
from repro.testing.proptest import given, settings, strategies as st

# distinctive shapes (no other test module uses dim=20 / hidden=24 /
# b_max=10) so the lru-cached engine programs are fresh here and the
# trace assertions below are exact
DIM, HIDDEN, BMAX = 20, 24, 10
PERIODS = 3


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=260, dim=DIM, seed=0, spread=6.0)
    return full.split(60)


def _fleet(k):
    return tuple(DeviceProfile(kind="cpu", f_cpu=(0.7 + 0.35 * (i % 3)) * 1e9)
                 for i in range(k))


def _spec(k, **kw):
    kw.setdefault("name", f"K{k}")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    kw.setdefault("seeds", (0,))
    return ScenarioSpec(fleet=_fleet(k), **kw)


def _certify(specs, users=None):
    """Lower the specs' buckets and run the taint pass over each."""
    full = ClassificationData.synthetic(n=260, dim=DIM, seed=0, spread=6.0)
    data, test = full.split(60)
    report = AuditReport()
    rows = grid(specs[0], users=users) if users else specs
    names = []
    for bucket in group_rows(rows):
        plan = plan_bucket(bucket, data, PERIODS)
        traced = trace_bucket(plan, data, test)
        taint.analyze_jaxpr(traced.closed, traced.in_labels,
                            traced.out_contracts, program=traced.program,
                            report=report)
        names.append(traced.program)
    return report, names


# ---------------------------------------------------------------------------
# taint lattice: property tests on synthetic jaxprs
# ---------------------------------------------------------------------------


def _analyze(fn, args, labels, contracts=None, program="synthetic"):
    report = AuditReport()
    closed = jax.make_jaxpr(fn)(*args)
    taint.analyze_jaxpr(closed, labels, contracts, program=program,
                        report=report)
    return report


@settings(max_examples=30, deadline=None)
@given(k=st.integers(2, 6), feat=st.integers(1, 6),
       op=st.sampled_from(["sum", "reshape-sum", "dot"]))
def test_prop_mask_dominated_reductions_certify(k, feat, op):
    """Any cross-user reduction whose operand is mask-multiplied (padded
    lanes provably the monoid identity) certifies, including through a
    reshape that merges the user axis and through dot contraction."""
    def good(x, mask):
        xm = x * mask[:, None]
        if op == "sum":
            return xm.sum(axis=0) / (mask.sum() + 1.0)
        if op == "reshape-sum":
            return xm.reshape(-1).sum() / (mask.sum() + 1.0)
        return jnp.dot(mask, x)
    report = _analyze(
        good, (np.zeros((k, feat), np.float32), np.zeros(k, np.float32)),
        [LaneLabel(0), LaneLabel(0, 0.0)])
    assert report.ok, [f.detail for f in report.errors()]
    summary = report.programs["synthetic"]
    assert summary["n_certified_reductions"] >= 1
    assert summary["n_poisoned_outputs"] == 0


@settings(max_examples=30, deadline=None)
@given(k=st.integers(2, 6), feat=st.integers(1, 6),
       op=st.sampled_from(["sum", "reshape-sum", "dot"]))
def test_prop_poisoned_padding_mutant_rejected(k, feat, op):
    """The seeded mutant — the mask dropped from one reduction — must
    fail loudly with an unmasked-reduction (or contraction) finding."""
    def poisoned(x, mask):
        if op == "sum":
            return x.sum(axis=0) / (mask.sum() + 1.0)
        if op == "reshape-sum":
            return x.reshape(-1).sum() / (mask.sum() + 1.0)
        return jnp.dot(jnp.ones(k, np.float32) * 1.0 + 0.0 * mask, x)
    report = _analyze(
        poisoned, (np.zeros((k, feat), np.float32),
                   np.zeros(k, np.float32)),
        [LaneLabel(0), LaneLabel(0, 0.0)])
    assert not report.ok
    checks = {f.check for f in report.errors()}
    assert checks & {"taint.unmasked-reduction",
                     "taint.unmasked-contraction"}, checks


def test_identityless_reduction_never_certifies():
    """Known(0) lanes prove a SUM safe but not a MAX (identity is -inf):
    the monoid rule must reject op/identity mismatches."""
    report = _analyze(
        lambda x, m: (x * m[:, None]).max(axis=0),
        (np.zeros((4, 3), np.float32), np.zeros(4, np.float32)),
        [LaneLabel(0), LaneLabel(0, 0.0)])
    assert not report.ok
    assert any(f.check == "taint.unmasked-reduction"
               for f in report.errors())


def test_output_contract_violation_detected():
    """An output contracted to Known(0) on padded lanes fails when the
    program leaves those lanes variant."""
    report = _analyze(
        lambda x: x * 2.0, (np.zeros((4, 3), np.float32),),
        [LaneLabel(0)], contracts={0: OutContract(axis=0, value=0.0)})
    assert not report.ok
    assert any(f.check == "taint.output-contract" for f in report.errors())


def test_poisoned_output_detected():
    """A poisoned value reaching an output (even without a reduction) is
    an error: garbage escapes to the host."""
    report = _analyze(
        lambda x, m: x.sum(axis=0),
        (np.zeros((4, 3), np.float32), np.zeros(4, np.float32)),
        [LaneLabel(0), LaneLabel(0, 0.0)])
    assert any(f.check == "taint.poisoned-output" for f in report.errors())


def test_same_lane_cancellation():
    """The local-steps delta rule: broadcast(p) - p_k is Known(0) on
    every lane, so its cross-user sum certifies with no mask at all."""
    def delta(p, pk):
        return (pk - p[None, :]).sum(axis=0)
    report = _analyze(
        delta, (np.zeros(3, np.float32), np.zeros((4, 3), np.float32)),
        [NO_LABEL, LaneLabel(0, "variant")])
    # pk's lanes are variant, yet pk - broadcast(p) of ITSELF cancels
    # only when both sides alias; here they don't — expect failure...
    assert not report.ok


def test_same_lane_cancellation_through_broadcast():
    """...but when the padded lanes of pk provably EQUAL the broadcast
    source (the Same lattice element), the difference is Known(0)."""
    def delta(p):
        pk = jnp.broadcast_to(p[None, :], (4, 3))
        return (pk - p[None, :]).sum(axis=0)
    report = _analyze(delta, (np.zeros(3, np.float32),), [NO_LABEL])
    assert report.ok, [f.detail for f in report.errors()]


# ---------------------------------------------------------------------------
# acceptance: the real bucket programs certify
# ---------------------------------------------------------------------------


def test_table2_scheme_programs_certify():
    """ISSUE-6 acceptance: the four Table-II scheme programs (feel ==
    gradient_fl+SBC, the uncompressed gradient_fl variant, individual,
    model_fl) all pass the taint certificate with certified reductions."""
    specs = [_spec(4, scheme="feel"),
             _spec(4, scheme="feel", compress=False),
             _spec(4, scheme="individual"),
             _spec(4, scheme="model_fl")]
    report, names = _certify(specs)
    assert report.ok, [f.detail for f in report.errors()]
    assert len(names) == 4
    for name in names:
        summary = report.programs[name]
        assert summary["ok"], name
        assert summary["n_certified_reductions"] >= 1, name
        assert summary["n_poisoned_outputs"] == 0, name


def test_ragged_users_program_certifies():
    """ISSUE-6 acceptance: the ONE padded program behind the ragged
    users=[4,8,16] sweep certifies — the masking is proven for every
    fleet size the program will ever run at."""
    report, names = _certify([_spec(4, scheme="feel", seeds=(0,))],
                             users=[4, 8, 16])
    assert len(names) == 1                        # one bucket, k_pad=16
    assert report.ok, [f.detail for f in report.errors()]
    assert report.programs[names[0]]["n_certified_reductions"] >= 1


def test_feel_bucket_carries_residual_contract(dataset):
    """trace_bucket pins the SBC residual carry to Known(0) on padded
    lanes (the chunk-resumption induction) — the contract must exist and
    must hold."""
    data, test = dataset
    bucket = group_rows([_spec(3, scheme="feel")])[0]
    plan = plan_bucket(bucket, data, PERIODS)
    traced = trace_bucket(plan, data, test)
    assert traced.out_contracts                    # non-empty for FEEL
    assert all(c.axis == 1 and c.value == 0.0
               for c in traced.out_contracts.values())
    report = taint.analyze_jaxpr(traced.closed, traced.in_labels,
                                 traced.out_contracts,
                                 program=traced.program)
    assert report.ok, [f.detail for f in report.errors()]


# ---------------------------------------------------------------------------
# compile hygiene
# ---------------------------------------------------------------------------


def test_trace_ledger_flags_retrace_and_count():
    ev = engine.TraceEvent("feel", (1, True), (("f32", (2, 3)),))
    ok = compile_audit.audit_traces([ev], label="t1", expect_total=1)
    assert ok.ok and ok.programs["t1"]["n_retraces"] == 0
    bad = compile_audit.audit_traces([ev, ev], label="t2")
    assert not bad.ok
    assert any(f.check == "compile.retrace" for f in bad.errors())
    miscount = compile_audit.audit_traces([ev], label="t3", expect_total=2)
    assert any(f.check == "compile.trace-count" for f in miscount.errors())


def test_hygiene_flags_x64_leak():
    from jax.experimental import enable_x64
    with enable_x64():
        closed = jax.make_jaxpr(lambda x: x * 2.0)(np.float64(1.0))
    report = compile_audit.audit_jaxpr_hygiene(closed, program="x64")
    assert not report.ok
    assert any(f.check == "compile.x64-leak" for f in report.errors())


def test_hygiene_flags_folded_constant():
    big = np.zeros(5000, np.float32)
    closed = jax.make_jaxpr(lambda x: x + jnp.asarray(big))(
        np.float32(1.0))
    report = compile_audit.audit_jaxpr_hygiene(closed, program="folded")
    assert report.ok                               # WARN, not ERROR
    assert any(f.check == "compile.folded-constant"
               for f in report.warnings())


def test_real_programs_pass_hygiene(dataset):
    data, test = dataset
    report = AuditReport()
    for bucket in group_rows([_spec(3, scheme="feel"),
                              _spec(3, scheme="individual")]):
        plan = plan_bucket(bucket, data, PERIODS)
        traced = trace_bucket(plan, data, test)
        compile_audit.audit_jaxpr_hygiene(traced.closed,
                                          program=traced.program,
                                          report=report)
    assert report.ok, [f.detail for f in report.errors()]


# ---------------------------------------------------------------------------
# determinism lint
# ---------------------------------------------------------------------------


def test_determinism_lint_on_library_sources():
    """The repo's host planning passes the lint with zero errors; the
    one known PRNG seed-sharing group surfaces as an advisory WARN."""
    report = determinism.lint_sources()
    assert not report.errors(), [f.detail for f in report.errors()]
    assert report.programs["determinism-lint"]["ok"]
    assert any(f.check == "det.prng-stream-collision"
               for f in report.warnings())


def test_determinism_lint_catches_unseeded_cumsum(tmp_path):
    (tmp_path / "repro").mkdir()
    (tmp_path / "repro" / "bad.py").write_text(
        "import numpy as np\n"
        "def ledger(x, offset):\n"
        "    return np.cumsum(x) + offset\n")
    report = determinism.lint_sources(root=tmp_path / "repro")
    assert any(f.check == "det.unseeded-cumsum" for f in report.errors())


# ---------------------------------------------------------------------------
# the run(audit=True) hook and the report surface
# ---------------------------------------------------------------------------


def test_run_audit_attaches_clean_report(dataset):
    """run(audit=True) on a chunked closed-loop grid: Results.audit is a
    passing AuditReport whose scoped trace ledger proves zero retraces
    across chunks and replan rounds."""
    data, test = dataset
    specs = [_spec(3, scheme="feel", seeds=(0, 1)),
             _spec(3, scheme="individual")]
    res = Experiment(data, test, specs).run(
        periods=PERIODS, executor=SerialExecutor(), replan=2, audit=True)
    report = res.audit
    assert isinstance(report, AuditReport) and report.ok
    ledger = report.programs["trace-ledger"]
    assert ledger["n_retraces"] == 0
    assert ledger["n_traces"] == ledger["n_unique_programs"]
    taint_progs = [p for p in report.programs.values()
                   if p["pass"] == "taint"]
    assert taint_progs and all(p["ok"] for p in taint_progs)
    # the report survives row selection
    assert res.sel(scheme="individual").audit is report
    # ...and serializes
    js = report.to_json()
    assert js["ok"] and js["programs"]["trace-ledger"]["n_retraces"] == 0


def test_audit_error_raises_with_findings():
    report = AuditReport()
    report.add("taint.unmasked-reduction", Severity.ERROR, "x", "boom")
    assert not report.ok
    with pytest.raises(AuditError):
        report.raise_on_error()
    f = report.findings[0]
    assert isinstance(f, Finding) and f.to_json()["severity"] == "error"


def test_audit_cli_static_passes(tmp_path):
    """The packaged CLI (static passes on a reduced grid) exits 0 and
    writes the machine-readable report artifact."""
    from repro.analysis.audit import main
    out = tmp_path / "AUDIT_report.json"
    rc = main(["--out", str(out), "--users", "3,5", "--periods", "2",
               "--skip-run"])
    assert rc == 0 and out.exists()
    import json
    js = json.loads(out.read_text())
    assert js["ok"] and js["n_errors"] == 0


# ---------------------------------------------------------------------------
# no_retrace guard + dtype boundary
# ---------------------------------------------------------------------------


def test_no_retrace_counts_and_passes(dataset):
    data, test = dataset
    exp = Experiment(data, test, [_spec(3, scheme="model_fl",
                                       seeds=(0,))])
    with no_retrace(expect=1):                    # cold: exactly one trace
        exp.run(periods=PERIODS)
    with no_retrace():                            # warm: zero traces
        exp.run(periods=PERIODS)


def test_no_retrace_fails_on_unexpected_trace(dataset):
    data, test = dataset
    exp = Experiment(data, test, [_spec(5, scheme="model_fl",
                                       seeds=(0,))])
    with pytest.raises(AssertionError, match="trace"):
        with no_retrace():                        # cold path declared warm
            exp.run(periods=PERIODS)


def test_host_to_device_casts_and_gate_rejects_x64():
    tree = {"a": np.arange(4, dtype=np.float64),
            "b": np.arange(4, dtype=np.int64),
            "c": np.ones(2, dtype=np.bool_)}
    cast = engine.host_to_device(tree)
    assert cast["a"].dtype == jnp.float32
    assert cast["b"].dtype == jnp.int32
    assert cast["c"].dtype == jnp.bool_
    engine.assert_device_safe(cast, "test")       # casts pass the gate
    with pytest.raises(TypeError, match="float64"):
        engine.assert_device_safe({"x": np.zeros(3, np.float64)}, "test")


# ---------------------------------------------------------------------------
# taint lattice: per-primitive handler battery (synthetic jaxprs)
# ---------------------------------------------------------------------------


def _ok(fn, args, labels, program="prim"):
    report = _analyze(fn, args, labels, program=program)
    assert report.ok, [f.detail for f in report.errors()]
    return report


def _fails(fn, args, labels, check):
    report = _analyze(fn, args, labels)
    assert not report.ok
    assert any(f.check == check for f in report.errors()), \
        {f.check for f in report.errors()}
    return report


_X = np.zeros((4, 3), np.float32)
_M = np.zeros(4, np.float32)
_XM_LABELS = [LaneLabel(0), LaneLabel(0, 0.0)]


def test_prim_where_mask_certifies():
    """select_n with a Known-lane predicate picks that case: the
    jnp.where masking idiom certifies like w*=active does."""
    _ok(lambda x, m: jnp.where(m[:, None] > 0, x, 0.0).sum(axis=0),
        (_X, _M), _XM_LABELS)


def test_prim_clamp_convert_preserve_known_zero():
    _ok(lambda x, m: jnp.clip(x * m[:, None], 0.0, 1.0).sum(axis=0),
        (_X, _M), _XM_LABELS)
    _ok(lambda x, m: (x * m[:, None]).astype(jnp.int32).sum(axis=0),
        (_X, _M), _XM_LABELS)


def test_prim_structural_ops_preserve_lanes():
    """flip/pad/slice/dynamic-slice/concat on non-user axes keep the
    padded-lane facts; the downstream reduction still certifies."""
    _ok(lambda x, m: jnp.flip(x * m[:, None], axis=1).sum(axis=0),
        (_X, _M), _XM_LABELS)
    _ok(lambda x, m: jnp.pad(x * m[:, None],
                             ((0, 0), (1, 1))).sum(axis=0),
        (_X, _M), _XM_LABELS)
    _ok(lambda x, m: (x * m[:, None])[:, 1:].sum(axis=0),
        (_X, _M), _XM_LABELS)
    _ok(lambda x, m: jax.lax.dynamic_slice(
            x * m[:, None], (0, 0), (4, 2)).sum(axis=0),
        (_X, _M), _XM_LABELS)
    _ok(lambda x, m: jnp.concatenate(
            [x * m[:, None], x * m[:, None]], axis=1).sum(axis=0),
        (_X, _M), _XM_LABELS)


def test_prim_dynamic_update_slice():
    _ok(lambda x, m: jax.lax.dynamic_update_slice(
            x * m[:, None], jnp.zeros((4, 1), jnp.float32),
            (0, 0)).sum(axis=0),
        (_X, _M), _XM_LABELS)


def test_prim_sort_topk_within_lane_ok_across_lanes_flagged():
    # a within-lane sort keeps the user digits but conservatively drops
    # Known(0): no cross-lane finding, yet downstream sums won't certify
    report = _analyze(
        lambda x, m: jnp.sort(x * m[:, None], axis=1).sum(axis=0),
        (_X, _M), _XM_LABELS)
    assert not any(f.check == "taint.sort-over-user-axis"
                   for f in report.findings)
    assert not report.ok  # conservative lanes degrade → unmasked
    _fails(lambda x, m: jnp.sort(x, axis=0),
           (_X, _M), _XM_LABELS, "taint.sort-over-user-axis")
    _fails(lambda x, m: jax.lax.top_k(x.T, 2)[0],
           (_X, _M), _XM_LABELS, "taint.topk-over-user-axis")


def test_prim_cumsum_within_lane_ok_over_user_axis_flagged():
    _ok(lambda x, m: jnp.cumsum(x * m[:, None], axis=1).sum(axis=0),
        (_X, _M), _XM_LABELS)
    _fails(lambda x, m: jnp.cumsum(x, axis=0),
           (_X, _M), _XM_LABELS, "taint.cumulative-over-user-axis")


def test_prim_gather_within_lane_ok_over_user_axis_flagged():
    idx = np.array([2, 0], np.int32)
    _ok(lambda x, m: (x * m[:, None])[:, idx].sum(axis=0),
        (_X, _M), _XM_LABELS)
    _fails(lambda x, m: x[jnp.array([0, 1]), :],
           (_X, _M), _XM_LABELS, "taint.gather-over-user-axis")


def test_prim_scatter_add_across_user_lanes_flagged():
    _fails(lambda x, m: jnp.zeros((6, 3), np.float32)
                           .at[jnp.array([1, 3, 0, 2])].add(x),
           (_X, _M), _XM_LABELS, "taint.scatter-across-user-axis")


def test_prim_cond_joins_branches():
    _ok(lambda x, m: jax.lax.cond(
            (m.sum() > 0), lambda v: v * 2.0, lambda v: v * 3.0,
            x * m[:, None]).sum(axis=0),
        (_X, _M), _XM_LABELS)


def test_prim_scan_over_user_axis_flagged():
    _fails(lambda x, m: jax.lax.scan(
               lambda c, xi: (c + xi.sum(), c), 0.0, x)[0],
           (_X, _M), _XM_LABELS, "taint.scan-over-user-axis")


def test_prim_dot_free_user_axis_maps_to_output():
    """User axis as a FREE (non-contracted) dot dimension: the output
    inherits the digit and Known(0) lanes, so the later reduction over
    it still certifies."""
    w = np.ones((3, 5), np.float32)
    _ok(lambda x, m: ((x * m[:, None]) @ w).sum(axis=0),
        (_X, _M), _XM_LABELS)


def test_prim_custom_vjp_recurses():
    @jax.custom_vjp
    def f(v):
        return v * 2.0

    f.defvjp(lambda v: (v * 2.0, None), lambda _, g: (g * 2.0,))
    _ok(lambda x, m: f(x * m[:, None]).sum(axis=0),
        (_X, _M), _XM_LABELS)

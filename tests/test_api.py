"""Declarative experiment API: bucketing rule, one-trace-per-bucket
lowering, equivalence against the per-cell PR-1 paths (bit-for-bit for the
planner ledgers), executor runtimes (serial == async == meshed,
bit-for-bit), duplicate-spec dedup + fan-out, streaming collection, NaN
speed masking, and the mesh-sharded batch axis."""
import math
import warnings

import numpy as np
import pytest

from repro.api import (AsyncExecutor, Experiment, MeshExecutor,
                       ScenarioSpec, SerialExecutor, time_to_target)
from repro.channels.model import Cell
from repro.core import DeviceProfile, FeelScheduler
from repro.core.latency import period_latency, uplink_latency
from repro.core.scheduler import plan_horizons_batch
from repro.data.pipeline import ClassificationData, partition_noniid
from repro.fed import engine
from repro.fed.sweep import SweepCell, run_seed_batch, run_sweep
from repro.testing import no_retrace
from repro.fed.trainer import FeelSimulation, RunResult, run_scheme
from repro.launch.mesh import make_batch_mesh

# deliberately distinctive shapes: no other test module uses dim=40 /
# hidden=96 / b_max=24, so the lru-cached engine programs are fresh and
# the trace-count assertions below are exact.
DIM, HIDDEN, BMAX = 40, 96, 24


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=520, dim=DIM, seed=0, spread=6.0)
    return full.split(100)


@pytest.fixture(scope="module")
def fleet():
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7, 1.4, 2.1])


def _spec(fleet, **kw):
    kw.setdefault("name", "cpu3")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    return ScenarioSpec(fleet=fleet, **kw)


# ---------------------------------------------------------------------------
# bucketing rule
# ---------------------------------------------------------------------------


def test_bucketing_rule(dataset, fleet):
    """Partition/policy/seed/base_lr — and, since the ragged-fleet
    redesign, fleet size/composition — vary values only → one bucket;
    shape- or structure-changing knobs (b_max, scheme, local_steps)
    split."""
    data, test = dataset
    same = [_spec(fleet, partition=p, policy=pol, base_lr=lr, seeds=(0, 1))
            for p, pol, lr in [("iid", "proposed", 0.15),
                               ("noniid", "full", 0.1),
                               ("noniid", "random", 0.2)]]
    same.append(_spec(fleet[:2], name="cpu2"))    # smaller fleet: same bucket
    exp = Experiment(data, test, same)
    buckets = exp.lower()
    assert len(buckets) == 1
    assert len(buckets[0].rows) == 7              # 3 specs × 2 seeds + K2
    assert buckets[0].k_pad == len(fleet)
    mask = buckets[0].active_mask()
    assert mask.shape == (7, 3)
    np.testing.assert_array_equal(mask[-1], [1.0, 1.0, 0.0])

    split = same + [
        _spec(fleet, b_max=BMAX * 2),             # slot width
        _spec(fleet, local_steps=2),              # scan-body structure
        _spec(fleet, scheme="individual"),        # dev-family program
        _spec(fleet, scheme="model_fl"),          # averaging compiled in
        _spec(fleet[:2], name="cpu2i", scheme="individual"),  # dev: merges
    ]
    keys = [b.key for b in Experiment(data, test, split).lower()]
    assert len(keys) == len(set(keys)) == 5       # base bucket + 4 splits


def test_spec_validation(fleet):
    with pytest.raises(ValueError):
        ScenarioSpec(fleet=fleet, scheme="nope")
    with pytest.raises(ValueError):
        ScenarioSpec(fleet=fleet, seeds=())
    with pytest.raises(ValueError):
        ScenarioSpec(fleet=fleet, partition="sorted")
    with pytest.raises(ValueError):                # typo fails at build time,
        ScenarioSpec(fleet=fleet, policy="propsed")  # not deep in planning
    # hashable + usable as static jit metadata
    assert hash(ScenarioSpec(fleet=fleet)) == hash(ScenarioSpec(fleet=fleet))


# ---------------------------------------------------------------------------
# one compiled program per bucket
# ---------------------------------------------------------------------------


def test_grid_compiles_to_single_program_per_bucket(dataset, fleet):
    """ISSUE-2 acceptance: N shape-compatible cells → ONE trace, and a
    second same-shape grid with different values reuses it (0 traces)."""
    data, test = dataset
    grid = [_spec(fleet, partition=p, policy=pol, seeds=(0, 1))
            for p in ("iid", "noniid") for pol in ("proposed", "online")]
    with no_retrace(expect=1):                    # 4 cells, one program
        res = Experiment(data, test, grid).run(periods=4)
    assert res.n_buckets == 1

    other = [_spec(fleet, partition="noniid", policy="random",
                   base_lr=0.3, seeds=tuple(range(3, 11)))]  # 8 rows again
    with no_retrace():                            # same shapes: cache hit
        Experiment(data, test, other).run(periods=4)


# ---------------------------------------------------------------------------
# equivalence: bucketed lowering == per-cell PR-1 paths
# ---------------------------------------------------------------------------


def test_bucket_matches_per_cell_run_seed_batch(dataset, fleet):
    """2-cell × 2-seed bucket reproduces each cell's run_seed_batch."""
    data, test = dataset
    grid = [_spec(fleet, partition=p, policy="proposed", seeds=(0, 1))
            for p in ("iid", "noniid")]
    res = Experiment(data, test, grid).run(periods=5)
    for part in ("iid", "noniid"):
        sims = [FeelSimulation(list(fleet), data, test, partition=part,
                               policy="proposed", b_max=BMAX, base_lr=0.15,
                               seed=s, hidden=HIDDEN) for s in (0, 1)]
        losses, accs, times, gb = run_seed_batch(sims, 5)
        cell = res.sel(partition=part)
        np.testing.assert_array_equal(cell.times, times)   # host ledger
        np.testing.assert_array_equal(cell.global_batch, gb)
        np.testing.assert_allclose(cell.losses, losses, atol=1e-5)
        np.testing.assert_allclose(cell.accs, accs, atol=1e-5)


def test_horizon_dedup_rescales_lr_exactly(dataset, fleet):
    """Rows that are scheduler-identical modulo partition/base_lr share ONE
    planned horizon (the lowering's dedup); the per-row lr rescale must
    keep every row bit-equal (ledger) / tolerance-equal (series) to its
    standalone per-cell run."""
    data, test = dataset
    grid = [_spec(fleet, name=f"cpu3-lr{lr}", partition=p,
                  policy="proposed", base_lr=lr, seeds=(0,))
            for p in ("iid", "noniid") for lr in (0.1, 0.15)]
    res = Experiment(data, test, grid).run(periods=5)
    assert res.n_buckets == 1
    for p in ("iid", "noniid"):
        for lr in (0.1, 0.15):
            sims = [FeelSimulation(list(fleet), data, test, partition=p,
                                   policy="proposed", b_max=BMAX,
                                   base_lr=lr, seed=0, hidden=HIDDEN)]
            losses, accs, times, gb = run_seed_batch(sims, 5)
            row = res.sel(fleet=f"cpu3-lr{lr}", partition=p)
            assert row.rows == 1
            np.testing.assert_array_equal(row.times[0], times[0])
            np.testing.assert_array_equal(row.global_batch[0], gb[0])
            np.testing.assert_allclose(row.losses[0], losses[0], atol=1e-5)
            np.testing.assert_allclose(row.accs[0], accs[0], atol=1e-5)


def test_dev_schemes_bit_for_bit_vs_pr1_ledger(dataset, fleet):
    """individual/model_fl under the vectorized DevScheduler reproduce the
    PR-1 run_scheme trajectories bit-for-bit: the time ledger below is the
    PR-1 loop verbatim (interleaved rng draws, downlink via a second
    uplink_latency call — numerically identical to eq. (11))."""
    data, test = dataset
    periods, seed, k = 6, 3, len(fleet)

    def pr1_times(scheme):
        parts = partition_noniid(data.y, k, seed=seed)
        cell = Cell.make(seed)
        dist = cell.drop_users(k)
        rng = np.random.default_rng(seed)
        batch = 64
        n_params = sum((i * o + o) for i, o in
                       zip([DIM, 256, 256], [256, 256, 10]))
        s_bits = 32.0 * n_params
        times, t = np.empty(periods), 0.0
        for p in range(periods):
            np.stack([rng.choice(pp, size=batch, replace=len(pp) < batch)
                      for pp in parts])
            rates_up = cell.avg_rate(dist)
            rates_down = cell.avg_rate(dist)
            t_local = np.array([d.local_grad_latency(batch)
                                * max(1, len(pp) // batch)
                                for d, pp in zip(fleet, parts)])
            if scheme == "model_fl":
                tau_u = np.full(k, cell.cfg.frame_up_s / k)
                tau_d = np.full(k, cell.cfg.frame_down_s / k)
                t_up = uplink_latency(s_bits, tau_u, cell.cfg.frame_up_s,
                                      rates_up)
                t_down = uplink_latency(s_bits, tau_d, cell.cfg.frame_down_s,
                                        rates_down)
                t_upd = np.array([d.update_latency() for d in fleet])
                t += period_latency(t_local, t_up, t_down, t_upd)
            else:
                t += float(np.max(t_local))
            times[p] = t
        return times

    for scheme in ("individual", "model_fl"):
        with pytest.warns(DeprecationWarning):
            r = run_scheme(scheme, list(fleet), data, test, "noniid",
                           periods, seed=seed, eval_every=2)
        want = pr1_times(scheme)[[0, 2, 4, 5]]
        np.testing.assert_array_equal(np.array(r.times), want)
        assert np.all(np.isfinite(r.losses)) and np.all(np.isfinite(r.accs))


def test_dev_bucket_matches_run_scheme(dataset, fleet):
    """The batched dev-family lowering agrees with the per-run shim on the
    full loss/acc/time series."""
    data, test = dataset
    specs = [_spec(fleet, scheme=s, partition="noniid", base_lr=0.05,
                   b_max=128, hidden=256, seeds=(0, 1))
             for s in ("individual", "model_fl")]
    res = Experiment(data, test, specs).run(periods=5)
    for s in ("individual", "model_fl"):
        for seed in (0, 1):
            with pytest.warns(DeprecationWarning):
                r = run_scheme(s, list(fleet), data, test, "noniid", 5,
                               seed=seed, eval_every=2)
            row = res.sel(scheme=s, seed=seed)
            np.testing.assert_array_equal(row.times[0][[0, 2, 4]], r.times)
            np.testing.assert_allclose(row.losses[0][[0, 2, 4]], r.losses,
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(row.accs[0][[0, 2, 4]], r.accs,
                                       atol=1e-5, rtol=1e-5)


def test_plan_horizons_batch_bitwise(fleet):
    """Fused shared-fleet Algorithm-1 rows == per-scheduler planning."""
    mk = lambda: [FeelScheduler(devices=list(fleet), n_params=37000,  # noqa
                                policy=pol, b_max=BMAX, seed=s)
                  for s in (0, 1) for pol in ("proposed", "full")]
    fused, solo = mk(), mk()
    hs_fused = plan_horizons_batch(fused, 7)
    hs_solo = [s.plan_horizon(7) for s in solo]
    for a, b in zip(hs_fused, hs_solo):
        np.testing.assert_array_equal(a.batch, b.batch)
        np.testing.assert_array_equal(a.latency, b.latency)
        np.testing.assert_array_equal(a.lr, b.lr)
        np.testing.assert_array_equal(a.global_batch, b.global_batch)
    for a, b in zip(fused, solo):
        assert a._b_cache == b._b_cache and a._period == b._period


# ---------------------------------------------------------------------------
# executor runtimes: serial == async (bit-for-bit), streaming, dedup
# ---------------------------------------------------------------------------


def _multibucket_specs(fleet):
    """Three shape buckets: FEEL family (2 cells × 2 seeds), individual,
    model_fl."""
    return ([_spec(fleet, partition=p, policy="proposed", seeds=(0, 1))
             for p in ("iid", "noniid")]
            + [_spec(fleet, scheme="individual", seeds=(0,)),
               _spec(fleet, scheme="model_fl", seeds=(0,))])


def test_async_executor_bit_identical_to_serial(dataset, fleet):
    """ISSUE-3 acceptance: AsyncExecutor results are bit-for-bit identical
    to SerialExecutor on a multi-bucket grid — scheduling policy must not
    touch values."""
    data, test = dataset
    specs = _multibucket_specs(fleet)
    exp = Experiment(data, test, specs)
    assert len(exp.lower()) == 3
    serial = exp.run(periods=4, executor=SerialExecutor())
    done = exp.run(periods=4, executor=AsyncExecutor())
    default = exp.run(periods=4)                  # default == serial
    for got in (done, default):
        np.testing.assert_array_equal(
            np.asarray(serial.losses), np.asarray(got.losses))
        np.testing.assert_array_equal(
            np.asarray(serial.accs), np.asarray(got.accs))
        np.testing.assert_array_equal(serial.times, got.times)
        np.testing.assert_array_equal(serial.global_batch, got.global_batch)
    assert serial.n_buckets == done.n_buckets == 3


def test_stream_yields_cumulative_partials(dataset, fleet):
    """stream() hands back one cumulative partial Results per bucket; the
    final partial equals run()'s complete Results."""
    data, test = dataset
    specs = _multibucket_specs(fleet)
    exp = Experiment(data, test, specs)
    partials = list(exp.stream(periods=4, executor=AsyncExecutor()))
    assert len(partials) == 3
    assert [p.rows for p in partials] == [4, 5, 6]  # 4 feel rows, then +1, +1
    full = exp.run(periods=4)
    np.testing.assert_array_equal(np.asarray(partials[-1].losses),
                                  np.asarray(full.losses))
    np.testing.assert_array_equal(partials[-1].times, full.times)
    # early partials carry the already-collected rows in output order
    np.testing.assert_array_equal(np.asarray(partials[0].losses),
                                  np.asarray(full.losses[:4]))


def test_duplicate_specs_dedupe_and_fan_out(dataset, fleet):
    """The same ScenarioSpec declared twice is computed ONCE (one row per
    (spec, seed) in the lowering) and fanned back out to both output
    positions."""
    data, test = dataset
    spec = _spec(fleet, partition="iid", policy="full", seeds=(0, 1))
    other = _spec(fleet, partition="noniid", policy="full", seeds=(0,))
    exp = Experiment(data, test, [spec, other, spec])
    buckets = exp.lower()
    assert len(buckets) == 1
    assert len(buckets[0].rows) == 3              # 2 unique + 1, not 5
    fan = [r.indices for r in buckets[0].rows]
    assert fan == [(0, 3), (1, 4), (2,)]
    res = exp.run(periods=4)
    assert res.rows == 5                          # output keeps both copies
    np.testing.assert_array_equal(np.asarray(res.losses[0]),
                                  np.asarray(res.losses[3]))
    np.testing.assert_array_equal(np.asarray(res.losses[1]),
                                  np.asarray(res.losses[4]))
    np.testing.assert_array_equal(res.times[0], res.times[3])
    assert res.coords["spec"][0] == res.coords["spec"][3] == spec


def test_legacy_mesh_kwarg_is_gone(dataset, fleet):
    """The PR-3 ``Experiment(mesh=...)`` / ``run(mesh=...)`` shim has been
    removed: meshes belong to executors now."""
    data, test = dataset
    specs = [_spec(fleet, seeds=(0,))]
    mesh = make_batch_mesh()
    with pytest.raises(TypeError):
        Experiment(data, test, specs, mesh=mesh)
    with pytest.raises(TypeError):
        Experiment(data, test, specs).run(periods=2, mesh=mesh)


def test_run_sweep_and_run_scheme_warn_deprecation(dataset, fleet):
    """The legacy drivers must emit DeprecationWarning."""
    data, test = dataset
    with pytest.warns(DeprecationWarning, match="run_sweep is deprecated"):
        run_sweep({"cpu3": list(fleet)}, data, test, policies=("full",),
                  partitions=("iid",), seeds=(0,), periods=2, b_max=BMAX,
                  base_lr=0.15)
    with pytest.warns(DeprecationWarning, match="run_scheme is deprecated"):
        run_scheme("individual", list(fleet), data, test, "noniid", 2,
                   seed=0)


# ---------------------------------------------------------------------------
# NaN speed masking (python engine leaves NaN at non-eval periods)
# ---------------------------------------------------------------------------


def test_speed_masks_nan_explicitly():
    accs = np.array([[np.nan, 0.4, np.nan, 0.7],
                     [np.nan, np.nan, np.nan, np.nan],
                     [0.9, np.nan, 0.2, 0.3]])
    times = np.arange(1.0, 5.0) * np.ones((3, 1))
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # invalid-compare leaks
        got = time_to_target(accs, times, 0.6)
    np.testing.assert_array_equal(got, [4.0, np.inf, 1.0])

    cell = SweepCell(name="c", fleet="f", partition="iid", policy="full",
                     seeds=(0, 1, 2), losses=np.zeros_like(accs), accs=accs,
                     times=times, global_batch=np.ones_like(accs))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        np.testing.assert_array_equal(cell.speed(0.6), [4.0, np.inf, 1.0])

    r = RunResult(scheme="feel", losses=[0, 0, 0],
                  accs=[float("nan"), 0.65, 0.7], times=[1.0, 2.0, 3.0])
    assert r.speed(0.6) == 2.0
    r_never = RunResult(scheme="feel", losses=[0], accs=[float("nan")],
                        times=[1.0])
    assert math.isinf(r_never.speed(0.6))


# ---------------------------------------------------------------------------
# Results axes + reductions, shims, mesh
# ---------------------------------------------------------------------------


def test_results_named_axes(dataset, fleet):
    data, test = dataset
    grid = [_spec(fleet, partition=p, policy=pol, seeds=(0, 1))
            for p in ("iid", "noniid") for pol in ("proposed", "online")]
    res = Experiment(data, test, grid).run(periods=4)
    assert res.rows == 8 and res.periods == 4
    assert set(res.coords) == {"fleet", "partition", "policy", "scheme",
                               "seed", "spec"}
    sub = res.sel(partition="iid", seed=1)
    assert sub.rows == 2 and set(sub.coords["policy"]) == {"proposed",
                                                           "online"}
    assert res.speed(2.0).shape == (8,)           # unreachable => inf
    assert np.all(np.isinf(res.speed(2.0)))
    assert res.final_acc.shape == (8,)
    cells = list(res.cells())
    assert len(cells) == 4
    labels, rows = cells[0]
    assert rows.rows == 2 and labels["scheme"] == "feel"
    # the spec coordinate isolates exactly one scenario's seed rows
    one = res.sel(spec=grid[2])
    assert one.rows == 2
    assert set(one.coords["partition"]) == {grid[2].partition}
    with pytest.raises(KeyError):
        res.sel(flavor="wrong")


def test_policy_coordinate_excludes_dev_schemes(dataset, fleet):
    """individual/model_fl carry policy="none": a sel on a FEEL policy
    must never mix per-device-parameter rows into the selection."""
    data, test = dataset
    specs = [_spec(fleet, partition="noniid", policy="proposed"),
             _spec(fleet, partition="noniid", scheme="individual"),
             _spec(fleet, partition="noniid", scheme="model_fl")]
    res = Experiment(data, test, specs).run(periods=4)
    prop = res.sel(policy="proposed", partition="noniid")
    assert set(prop.coords["scheme"]) == {"feel"}
    assert set(res.sel(policy="none").coords["scheme"]) == {"individual",
                                                            "model_fl"}


def test_spec_coordinate_separates_label_twins(dataset, fleet):
    """Two specs differing only in base_lr share every label coordinate;
    cells()/sel(spec=...) still keep them apart."""
    data, test = dataset
    twins = [_spec(fleet, partition="iid", policy="full", base_lr=lr)
             for lr in (0.1, 0.2)]
    res = Experiment(data, test, twins).run(periods=4)
    assert len(list(res.cells())) == 2            # not merged into one cell
    a = res.sel(spec=twins[0])
    b = res.sel(spec=twins[1])
    assert a.rows == b.rows == 1
    assert not np.allclose(a.losses, b.losses)    # different lr trajectories
    assert np.array_equal(a.times, b.times)       # shared (deduped) ledger


def test_run_sweep_shim_unchanged(dataset, fleet):
    """Shim returns per-cell SweepCells matching run_seed_batch values."""
    data, test = dataset
    with pytest.warns(DeprecationWarning):
        sw = run_sweep({"cpu3": list(fleet)}, data, test,
                       policies=("proposed",), partitions=("iid",),
                       seeds=(0, 1), periods=4, b_max=BMAX, base_lr=0.15)
    cell = sw["cpu3/iid/proposed"]
    sims = [FeelSimulation(list(fleet), data, test, partition="iid",
                           policy="proposed", b_max=BMAX, base_lr=0.15,
                           seed=s) for s in (0, 1)]
    losses, accs, times, gb = run_seed_batch(sims, 4)
    np.testing.assert_array_equal(cell.times, times)
    np.testing.assert_allclose(cell.losses, losses, atol=1e-5)
    np.testing.assert_allclose(cell.accs, accs, atol=1e-5)
    rr = cell.run_result(seed_i=1, eval_every=2)
    assert len(rr.accs) == 3                      # periods 0, 2, 3


def test_pad_rows_wraps_cyclically_when_pad_exceeds_rows():
    """A mesh larger than the bucket needs cyclic row repetition, not a
    single wrap of the first ``pad`` rows (regression: pad > n used to
    under-pad and fail the divisibility check at device_put)."""
    from repro.api.lowering import _pad_rows
    a = np.arange(6).reshape(3, 2)
    padded = _pad_rows(a, 3, 5)                    # 3 rows onto an 8-mesh
    assert padded.shape == (8, 2)
    np.testing.assert_array_equal(padded, a[np.arange(8) % 3])


def test_mesh_multi_device_sharding():
    """End-to-end on a real 8-device mesh (forced host devices, so this
    must run in a subprocess): sharded == plain for MeshExecutor and the
    async-with-mesh combination, including a ragged feel bucket (two
    fleet sizes padded into one program) and a dev bucket, both smaller
    than the mesh."""
    import subprocess
    import sys
    prog = """
import numpy as np
from repro.api import AsyncExecutor, Experiment, MeshExecutor, ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.launch.mesh import make_batch_mesh
full = ClassificationData.synthetic(n=300, dim=24, seed=0, spread=6.0)
data, test = full.split(60)
fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9) for f in (0.7, 2.1))
wide = fleet + (DeviceProfile(kind="cpu", f_cpu=1.4e9),)
specs = [ScenarioSpec(fleet=fleet, partition=p, policy="full", b_max=8,
                      base_lr=0.15, hidden=32, seeds=(0,))
         for p in ("iid", "noniid")]
specs.append(ScenarioSpec(fleet=wide, name="K3", partition="iid",
                          policy="full", b_max=8, base_lr=0.15, hidden=32,
                          seeds=(0,)))        # ragged row: padded K2 -> K3
specs.append(ScenarioSpec(fleet=fleet, scheme="individual", b_max=8,
                          hidden=32, seeds=(0,)))
mesh = make_batch_mesh()
assert mesh.devices.size == 8, mesh.devices.size
plain = Experiment(data, test, specs).run(periods=3)
for runner in (lambda e: e.run(periods=3, executor=MeshExecutor()),
               lambda e: e.run(periods=3,
                               executor=AsyncExecutor(mesh=mesh)),
               lambda e: e.run(periods=3,
                               executor=AsyncExecutor(mesh=mesh,
                                                      max_in_flight=1))):
    sharded = runner(Experiment(data, test, specs))
    assert np.array_equal(plain.times, sharded.times)
    assert np.allclose(plain.losses, sharded.losses, atol=1e-5)
    assert np.allclose(plain.accs, sharded.accs, atol=1e-5)
print("OK")
"""
    import os
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_mesh_one_device_fallback(dataset, fleet):
    """Sharded lowering on a 1-device mesh == plain lowering (values and
    the dev-family path both), including non-divisible row padding."""
    data, test = dataset
    specs = [_spec(fleet, partition="noniid", policy="proposed",
                   seeds=(0, 1, 2)),              # 3 rows: padding exercised
             _spec(fleet, scheme="individual", seeds=(0,))]
    plain = Experiment(data, test, specs).run(periods=4)
    sharded = Experiment(data, test, specs).run(
        periods=4, executor=MeshExecutor())       # lazy make_batch_mesh()
    np.testing.assert_array_equal(plain.times, sharded.times)
    np.testing.assert_allclose(plain.losses, sharded.losses, atol=1e-6)
    np.testing.assert_allclose(plain.accs, sharded.accs, atol=1e-6)


def test_mesh_executor_rejects_non_batch_mesh(dataset, fleet):
    """Executors validate their mesh up front: a mesh without a 'batch'
    axis fails fast instead of deep inside device_put."""
    data, test = dataset
    specs = [_spec(fleet, partition="iid", policy="full", seeds=(0,))]
    from repro.launch.mesh import make_host_mesh
    with pytest.raises(ValueError, match="batch"):
        Experiment(data, test, specs).run(
            periods=3, executor=MeshExecutor(make_host_mesh()))


def test_async_max_in_flight_validation():
    with pytest.raises(ValueError, match="max_in_flight"):
        AsyncExecutor(max_in_flight=0)


def test_async_max_in_flight_bit_equal(dataset, fleet):
    """The dispatch-backlog cap is pure scheduling policy: capped (1 and
    2 in flight) vs uncapped AsyncExecutor runs are bit-equal on a
    3-bucket grid."""
    data, test = dataset
    specs = _multibucket_specs(fleet)
    exp = Experiment(data, test, specs)
    assert len(exp.lower()) == 3
    uncapped = exp.run(periods=4, executor=AsyncExecutor())
    for cap in (1, 2):
        capped = exp.run(periods=4,
                         executor=AsyncExecutor(max_in_flight=cap))
        np.testing.assert_array_equal(np.asarray(uncapped.losses),
                                      np.asarray(capped.losses))
        np.testing.assert_array_equal(np.asarray(uncapped.accs),
                                      np.asarray(capped.accs))
        np.testing.assert_array_equal(uncapped.times, capped.times)
        np.testing.assert_array_equal(uncapped.global_batch,
                                      capped.global_batch)
    # streaming still yields one cumulative partial per bucket
    partials = list(exp.stream(periods=4,
                               executor=AsyncExecutor(max_in_flight=1)))
    assert len(partials) == 3
    np.testing.assert_array_equal(np.asarray(partials[-1].losses),
                                  np.asarray(uncapped.losses))

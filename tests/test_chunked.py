"""Chunked-horizon pipelined execution: the frozen-ξ chunk-equivalence
matrix (chunk sizes 1..H × Serial/Async executors × the 8-device mesh,
all bit-identical to the monolithic scan), the resumable EngineState
contract at the engine level, closed-loop ξ re-planning (the replan=
surface, per-chunk estimator feedback, the ξ-invariance result, the
decay-cap steer), and the AsyncExecutor(max_in_flight)+stream() ordering
regression."""
import numpy as np
import pytest

from repro.api import (AsyncExecutor, Experiment, MeshExecutor,
                       ScenarioSpec, SerialExecutor)
from repro.api.lowering import BucketRun, group_rows
from repro.core import DeviceProfile
from repro.core.solver import FleetRows, optimize_batch_rows
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.testing import no_retrace

# distinctive shapes (no other module uses dim=28/hidden=40/b_max=12) so
# engine program caches never collide across test modules
DIM, HIDDEN, BMAX = 28, 40, 12
PERIODS = 5


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=360, dim=DIM, seed=0, spread=6.0)
    return full.split(80)


@pytest.fixture(scope="module")
def fleet():
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7, 1.4, 2.1])


def _spec(fleet, **kw):
    kw.setdefault("name", "chk3")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    return ScenarioSpec(fleet=fleet, **kw)


def _grid(fleet):
    """Three shape buckets: a ragged FEEL bucket (two fleet sizes, two
    policies, horizon-deduped lr twins), individual, model_fl."""
    return ([_spec(fleet, partition=p, policy=pol, seeds=(0, 1))
             for p in ("iid", "noniid") for pol in ("proposed", "full")]
            + [_spec(fleet[:2], name="chk2", partition="noniid",
                     policy="proposed", base_lr=0.1, seeds=(0,))]
            + [_spec(fleet, scheme="individual", seeds=(0,)),
               _spec(fleet, scheme="model_fl", seeds=(0,))])


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.losses),
                                  np.asarray(b.losses))
    np.testing.assert_array_equal(np.asarray(a.accs), np.asarray(b.accs))
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.global_batch, b.global_batch)


# ---------------------------------------------------------------------------
# the chunk-equivalence acceptance matrix (frozen ξ)
# ---------------------------------------------------------------------------


def test_chunked_bit_identical_to_monolithic_matrix(dataset, fleet):
    """ISSUE-5 acceptance: with ξ frozen, a horizon executed as chunked
    scans is bit-identical (ledgers AND device series array_equal) to the
    monolithic scan — for every chunk size 1..H, under both the serial
    reference and the pipelined async executor, on a grid that covers
    ragged FEEL buckets and both dev-family schemes."""
    data, test = dataset
    exp = Experiment(data, test, _grid(fleet))
    assert len(exp.lower()) == 3
    mono = exp.run(PERIODS)
    for chunk in range(1, PERIODS + 1):
        serial = exp.run(PERIODS,
                         executor=SerialExecutor(chunk_periods=chunk))
        _assert_bitwise(mono, serial)
    # the serial sweep above warmed every (bucket, chunk-length) program,
    # so the whole pipelined pass must cost ZERO additional traces
    with no_retrace():
        for chunk, mif in ((1, None), (2, None), (3, 1), (PERIODS, 2)):
            pipelined = exp.run(PERIODS, executor=AsyncExecutor(
                chunk_periods=chunk, max_in_flight=mif))
            _assert_bitwise(mono, pipelined)


def test_chunked_stream_equals_monolithic_stream(dataset, fleet):
    """Chunking is invisible to the streaming surface: same number of
    cumulative partials (one per bucket), same final Results."""
    data, test = dataset
    exp = Experiment(data, test, _grid(fleet))
    plain = list(exp.stream(PERIODS))
    chunked = list(exp.stream(PERIODS, executor=AsyncExecutor(
        chunk_periods=2)))
    assert len(plain) == len(chunked) == 3
    for a, b in zip(plain, chunked):
        assert a.rows == b.rows
    _assert_bitwise(plain[-1], chunked[-1])


def test_chunked_mesh_subprocess():
    """The chunk-equivalence matrix under a real 8-device host mesh
    (forced device count, so this runs in a subprocess): chunked and
    monolithic sharded runs are bit-identical, for MeshExecutor and the
    async-with-mesh pipeline, closed loop included."""
    import os
    import subprocess
    import sys
    prog = """
import numpy as np
from repro.api import AsyncExecutor, Experiment, MeshExecutor, ScenarioSpec
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.launch.mesh import make_batch_mesh
full = ClassificationData.synthetic(n=300, dim=24, seed=0, spread=6.0)
data, test = full.split(60)
fleet = tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9) for f in (0.7, 2.1))
wide = fleet + (DeviceProfile(kind="cpu", f_cpu=1.4e9),)
specs = [ScenarioSpec(fleet=fleet, partition=p, policy="proposed", b_max=8,
                      base_lr=0.15, hidden=32, seeds=(0,))
         for p in ("iid", "noniid")]
specs.append(ScenarioSpec(fleet=wide, name="K3", partition="iid",
                          policy="proposed", b_max=8, base_lr=0.15,
                          hidden=32, seeds=(0,)))   # ragged row: K2 -> K3
specs.append(ScenarioSpec(fleet=fleet, scheme="individual", b_max=8,
                          hidden=32, seeds=(0,)))
mesh = make_batch_mesh()
assert mesh.devices.size == 8, mesh.devices.size
exp = Experiment(data, test, specs)
mono = exp.run(periods=4, executor=MeshExecutor(mesh))
for ex in (MeshExecutor(mesh, chunk_periods=1),
           MeshExecutor(mesh, chunk_periods=3),
           AsyncExecutor(mesh=mesh, chunk_periods=2),
           AsyncExecutor(mesh=mesh, chunk_periods=2, max_in_flight=1)):
    got = exp.run(periods=4, executor=ex)
    assert np.array_equal(np.asarray(mono.losses), np.asarray(got.losses))
    assert np.array_equal(np.asarray(mono.accs), np.asarray(got.accs))
    assert np.array_equal(mono.times, got.times)
    assert np.array_equal(mono.global_batch, got.global_batch)
# closed loop under the mesh: serial == async, and the run completes
cl_s = exp.run(periods=4, executor=MeshExecutor(mesh), replan=2)
cl_a = exp.run(periods=4, executor=AsyncExecutor(mesh=mesh), replan=2)
assert np.array_equal(np.asarray(cl_s.losses), np.asarray(cl_a.losses))
assert np.array_equal(cl_s.times, cl_a.times)
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# engine level: the resumable EngineState contract in isolation
# ---------------------------------------------------------------------------


def test_engine_resume_state_bit_identity(dataset, fleet):
    """N chunked scans with the explicit EngineState carry == one
    monolithic scan, straight at the engine API (no lowering involved):
    same series bits, same final carry bits — for the FEEL scan and the
    dev-family scan."""
    import jax
    from repro.core import FeelScheduler
    from repro.data.pipeline import FederatedBatcher, partition_noniid
    from repro.fed import feel_model
    data, test = dataset
    k = len(fleet)
    sched = FeelScheduler(devices=list(fleet), n_params=4000,
                          policy="proposed", b_max=BMAX, seed=0)
    parts = partition_noniid(data.y, k, seed=0)
    batcher = FederatedBatcher(parts, BMAX, 0)
    schedule = engine.build_schedule(sched, batcher, fleet, 6)
    p0 = feel_model.init(jax.random.key(0), HIDDEN, depth=3, input_dim=DIM)
    stack = lambda t: jax.tree_util.tree_map(lambda a: a[None], t)  # noqa
    params0 = stack(p0)
    residual0 = jax.tree_util.tree_map(
        lambda p: np.zeros((1, k) + p.shape, p.dtype), p0)

    pm, rm, (lm, am, dm) = engine.run_trajectory_batch(
        params0, residual0, [schedule], data, test, ratio=0.01)

    state = engine.EngineState(params=params0, residual=residual0)
    series = []
    for lo, hi in ((0, 2), (2, 5), (5, 6)):
        state, s = engine.resume_trajectory_batch(
            state, [engine.slice_schedule(schedule, lo, hi)], data, test,
            ratio=0.01)
        series.append(s)
    for j, mono in enumerate((lm, am, dm)):
        got = np.concatenate([np.asarray(s[j]) for s in series], axis=1)
        np.testing.assert_array_equal(np.asarray(mono), got)
    for a, b in zip(jax.tree_util.tree_leaves((pm, rm)),
                    jax.tree_util.tree_leaves((state.params,
                                               state.residual))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # dev-family: same contract, params-only carry
    idx = np.stack([np.stack([rng_part[:8] for rng_part in parts])
                    for _ in range(6)])[None]       # (1, 6, K, 8)
    dev0 = jax.tree_util.tree_map(
        lambda a: np.broadcast_to(a[None, None], (1, k) + a.shape), p0)
    lr = np.array([0.05], np.float32)
    fm, (dl_, da_) = engine.run_dev_trajectory_batch(
        dev0, idx, lr, data, test, average=True)
    st = engine.EngineState(params=dev0)
    dser = []
    for lo, hi in ((0, 3), (3, 6)):
        st, s = engine.resume_dev_trajectory_batch(
            st, idx[:, lo:hi], lr, data, test, average=True)
        dser.append(s)
    for j, mono in enumerate((dl_, da_)):
        got = np.concatenate([np.asarray(s[j]) for s in dser], axis=1)
        np.testing.assert_array_equal(np.asarray(mono), got)
    for a, b in zip(jax.tree_util.tree_leaves(fm),
                    jax.tree_util.tree_leaves(st.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# closed loop: replan= surface, feedback, ξ-invariance, decay-cap steer
# ---------------------------------------------------------------------------


def test_replan_validation(fleet):
    with pytest.raises(ValueError, match="replan"):
        _spec(fleet, replan=0)
    with pytest.raises(ValueError, match="replan"):
        _spec(fleet, replan=True)
    with pytest.raises(ValueError, match="batchsize policy"):
        _spec(fleet, scheme="individual", replan=4)
    with pytest.raises(ValueError, match="replan"):
        group_rows([_spec(fleet)], replan=-1)
    with pytest.raises(ValueError, match="chunk_periods"):
        SerialExecutor(chunk_periods=0)
    with pytest.raises(ValueError, match="chunk_periods"):
        AsyncExecutor(chunk_periods=-2)


def test_replan_is_structural_and_overridable(dataset, fleet):
    """replan splits FEEL buckets (chunk boundaries are compiled-schedule
    structure); the run-level override re-groups them; dev buckets keep
    replan None under an override."""
    data, test = dataset
    specs = [_spec(fleet, partition="iid"),
             _spec(fleet, partition="noniid", replan=2),
             _spec(fleet, scheme="individual")]
    exp = Experiment(data, test, specs)
    buckets = exp.lower()
    assert [b.replan for b in buckets] == [None, 2, None]
    assert len(buckets) == 3                      # replan split the feel pair
    merged = exp.lower(replan=4)
    assert [b.replan for b in merged] == [4, None]
    assert len(merged) == 2                       # one feel bucket again
    assert merged[1].kind == "dev" and merged[1].replan is None


def test_replan_override_dedupes_replan_twins(dataset, fleet):
    """Specs differing ONLY in replan collapse onto one computed row
    when a run-level override unifies them (dedup keys on the spec as
    executed, not as declared) — an experiment never runs one trajectory
    twice."""
    from dataclasses import replace
    data, test = dataset
    s = _spec(fleet, partition="iid", policy="full", seeds=(0,))
    twin = replace(s, replan=2)
    exp = Experiment(data, test, [s, twin])
    assert len(exp.lower()) == 2                  # no override: structural
    merged = exp.lower(replan=2)
    assert len(merged) == 1
    assert [r.indices for r in merged[0].rows] == [(0, 1)]
    res = exp.run(PERIODS, replan=2)
    assert res.rows == 2                          # both outputs delivered
    np.testing.assert_array_equal(np.asarray(res.losses[0]),
                                  np.asarray(res.losses[1]))


def test_stream_partial_sel_does_not_raise_on_uncollected(dataset, fleet):
    """Fail-loudly sel() must not crash a stream consumer: on a partial,
    a valid coordinate value whose bucket has not collected yet selects
    empty; the final (complete) partial raises as usual."""
    data, test = dataset
    specs = [_spec(fleet, partition="iid", policy="full", seeds=(0,)),
             _spec(fleet, scheme="individual", seeds=(0,))]
    exp = Experiment(data, test, specs)
    partials = list(exp.stream(PERIODS))
    first, last = partials[0], partials[-1]
    assert not first.complete and last.complete
    early = first.sel(scheme="individual")        # valid, not yet arrived
    assert early.rows == 0
    assert last.sel(scheme="individual").rows == 1
    with pytest.raises(ValueError, match="matches no row"):
        last.sel(scheme="no-such-scheme")


def test_closed_loop_feedback_reaches_estimators(dataset, fleet):
    """Chunk c's realized decays land in every row's ξ estimator before
    chunk c+1 is planned; per-row schedulers diverge from the shared
    prior (closed-loop rows do NOT share horizons)."""
    data, test = dataset
    spec = _spec(fleet, partition="noniid", policy="proposed",
                 seeds=(0, 1))
    bucket = group_rows([spec], replan=2)[0]
    run = BucketRun(bucket, data, test, PERIODS, 2)
    assert run.closed_loop
    xi0 = [s.xi_est.xi for s in run._planner.schedulers]
    assert len(xi0) == 2                          # one scheduler per row
    run.advance()
    assert not run.can_advance                    # feedback gate
    run.collect()
    xi1 = [s.xi_est.xi for s in run._planner.schedulers]
    assert all(a != b for a, b in zip(xi0, xi1))  # feedback landed
    assert all(s.xi_est.decay_cap is not None
               for s in run._planner.schedulers)
    while not run.done:
        if run.can_advance:
            run.advance()
        else:
            run.collect()
    losses, accs, times, gb = run.result()
    assert losses.shape == (2, PERIODS) and times.shape == (2, PERIODS)
    assert np.all(np.diff(times, axis=1) > 0)     # seeded-cumsum ledger


def test_closed_loop_xi_invariance(dataset, fleet):
    """The documented invariance: Algorithm-1 decisions are ξ-scale-free
    (ΔL·E and ΔL·μ are pinned jointly; the outer argmin drops ξ), so on
    a compute-dominated fleet — where the decay cap cannot bind below
    the already-minimal B* — closed-loop re-planning reproduces every
    open-loop DECISION exactly: identical batch plans (global_batch,
    hence lr/schedules) and bit-identical device series.  Only the
    predicted-latency ledger floats at ulp level (the bisection runs at
    a rescaled ΔL; the fixed point is the same, its rounding is not).
    Closed-loop ξ feedback is free."""
    data, test = dataset
    spec = _spec(fleet, partition="iid", policy="proposed", seeds=(0,))
    exp = Experiment(data, test, [spec])
    mono = exp.run(PERIODS)
    closed_runs = [exp.run(PERIODS, replan=2)]    # warms the chunk programs
    # every further replan round / executor reuses them: zero traces
    with no_retrace():
        closed_runs.append(exp.run(PERIODS, executor=AsyncExecutor(),
                                   replan=2))
    for closed in closed_runs:
        np.testing.assert_array_equal(mono.global_batch,
                                      closed.global_batch)
        np.testing.assert_array_equal(np.asarray(mono.losses),
                                      np.asarray(closed.losses))
        np.testing.assert_array_equal(np.asarray(mono.accs),
                                      np.asarray(closed.accs))
        np.testing.assert_allclose(mono.times, closed.times, rtol=1e-12)


def test_decay_cap_steers_b_star():
    """The decision-relevant half of the closed loop: capping the decay
    credited to a candidate clips B* to the knee (cap/ξ)² on a fleet
    whose uncapped optimum is interior (GPU flat-region economics)."""
    rng = np.random.default_rng(3)
    fleet = tuple(DeviceProfile(kind="gpu", gpu_t_low=0.02, gpu_slope=5e-4,
                                gpu_b_th=16 + 4 * i) for i in range(4))
    fr = FleetRows.from_fleets([fleet])
    up = rng.uniform(5e7, 3e8, size=(1, 4))
    down = rng.uniform(5e7, 3e8, size=(1, 4))
    s_bits, frame, xi = 0.005 * 64 * 1e6, 0.010, 0.05
    open_b = optimize_batch_rows(fr, up, down, s_bits, frame, frame, xi,
                                 128)
    lo_sum = fr.lo.sum()                          # GPU floor: Σ B_th
    assert open_b[0] > lo_sum + 1                 # interior optimum
    # knee halfway between the feasible floor and the open optimum
    knee_b = 0.5 * (lo_sum + open_b[0])
    cap = xi * np.sqrt(knee_b)
    capped = optimize_batch_rows(fr, up, down, s_bits, frame, frame, xi,
                                 128, dl_cap=np.array([cap]))
    assert capped[0] < open_b[0]
    assert capped[0] <= knee_b * 1.1              # clipped to ~the knee
    # an unbinding cap (or inf/nan) changes nothing, bitwise
    for loose in (10.0 * xi * np.sqrt(open_b[0]), np.inf, np.nan):
        same = optimize_batch_rows(fr, up, down, s_bits, frame, frame, xi,
                                   128, dl_cap=np.array([loose]))
        np.testing.assert_array_equal(open_b, same)


# ---------------------------------------------------------------------------
# regression: AsyncExecutor(max_in_flight) + stream() ordering
# ---------------------------------------------------------------------------


def test_stream_max_in_flight_partials_monotone(dataset, fleet):
    """Satellite regression for the capped-backlog streaming path:
    collection is oldest-first even when later (smaller) buckets finish
    on-device before earlier (larger) ones, every partial is cumulative
    (row set grows monotonically), and rows arrive sorted by output
    index within each partial — so coordinates are monotone."""
    data, test = dataset
    # first bucket large/slow (8 rows), later buckets tiny/fast — the
    # out-of-order-completion shape that would expose LIFO or dropped
    # collections
    specs = _grid(fleet)
    exp = Experiment(data, test, specs)
    full = exp.run(PERIODS)
    order = {(s, int(sd)): i
             for i, (s, sd) in enumerate(zip(full.coords["spec"],
                                             full.coords["seed"]))}
    for mif in (1, 2, None):
        partials = list(exp.stream(
            PERIODS, executor=AsyncExecutor(max_in_flight=mif)))
        assert len(partials) == 3                 # one per bucket
        prev_keys: list = []
        for part in partials:
            keys = [(s, int(sd)) for s, sd in zip(part.coords["spec"],
                                                  part.coords["seed"])]
            ranks = [order[k] for k in keys]
            assert ranks == sorted(ranks)         # output-index order
            assert set(prev_keys) <= set(keys)    # cumulative
            assert len(keys) > len(prev_keys)
            prev_keys = keys
            # every delivered row carries the full run's exact values
            sel = np.array(ranks)
            np.testing.assert_array_equal(np.asarray(part.losses),
                                          np.asarray(full.losses)[sel])
            np.testing.assert_array_equal(part.times, full.times[sel])
        assert len(prev_keys) == full.rows        # final partial complete

"""Scenario dynamics (PR 9): drifting block-fading channels,
straggler/dropout faults, per-user energy budgets, and adaptive local
steps.

The contracts under test:

* **identity** — a spec carrying only *identity* dynamics (zero-spread
  fading, probability-0 faults, infinite budgets) is bit-identical to
  the static world, ledger for ledger;
* **stream hygiene** — every dynamics process draws from its own tagged
  rng stream, so configuring dynamics never perturbs the channel /
  policy / batcher draws the static world already made, and chunked
  planning equals monolithic planning draw-for-draw;
* **the tentpole pin** — under channel drift, closed-loop replanning
  (fresh gains at every chunk boundary) produces *different* allocations
  from the stale open-loop plan AND wins on the realized latency ledger;
* **weighted sampling** — the Horvitz-Thompson 1/p correction is an
  unbiased estimator of the full-participation aggregate (property
  test) and collapses bitwise onto the plain path at S == K.
"""
import numpy as np
import pytest

from repro.api import Experiment, ScenarioSpec, SerialExecutor, lowering
from repro.core import DeviceProfile, FeelScheduler
from repro.data.pipeline import ClassificationData
from repro.dynamics import (EnergyBudget, Fading, FadingProcess,
                            FaultProcess, Faults, TauAdapt)
from repro.testing.proptest import given, settings, strategies as st
from repro.topology import ParticipationSampler, Sampling, Topology

# distinctive shapes (no other module uses dim=30/hidden=44/b_max=10) so
# engine program caches never collide across test modules
DIM, HIDDEN, BMAX = 30, 44, 10
PERIODS = 4


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=400, dim=DIM, seed=0, spread=6.0)
    return full.split(80)


def _fleet(k):
    return tuple(DeviceProfile(kind="cpu", f_cpu=(0.6 + 0.3 * i) * 1e9)
                 for i in range(k))


def _spec(k, **kw):
    kw.setdefault("name", f"dyn{k}")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    kw.setdefault("fleet", _fleet(k))
    return ScenarioSpec(**kw)


def _sched(**kw):
    kw.setdefault("devices", _fleet(4))
    kw.setdefault("n_params", 4000)
    kw.setdefault("b_max", 16)
    kw.setdefault("seed", 3)
    return FeelScheduler(**kw)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.losses),
                                  np.asarray(b.losses))
    np.testing.assert_array_equal(np.asarray(a.accs), np.asarray(b.accs))
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.global_batch, b.global_batch)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_dynamics_value_validation():
    with pytest.raises(ValueError, match="states"):
        Fading(states=0)
    with pytest.raises(ValueError, match="spread"):
        Fading(spread=-0.1)
    with pytest.raises(ValueError, match="stickiness"):
        Fading(stickiness=1.0)
    with pytest.raises(ValueError, match="slow_prob"):
        Faults(slow_prob=1.5)
    with pytest.raises(ValueError, match="drop_prob"):
        Faults(drop_prob=1.0)
    with pytest.raises(ValueError, match="slow_factor"):
        Faults(slow_factor=0.5)
    with pytest.raises(ValueError, match="budget_j"):
        EnergyBudget(budget_j=0.0)
    with pytest.raises(ValueError, match="power draws"):
        EnergyBudget(comp_w=-1.0)
    with pytest.raises(ValueError, match="at least one"):
        EnergyBudget(comp_w=0.0, tx_w=0.0)
    with pytest.raises(ValueError, match="at least one choice"):
        TauAdapt(choices=())
    with pytest.raises(ValueError, match="positive ints"):
        TauAdapt(choices=(1, 0))
    with pytest.raises(ValueError, match="distinct"):
        TauAdapt(choices=(2, 2))


def test_spec_dynamics_validation():
    with pytest.raises(ValueError, match="no\\s+planner"):
        _spec(3, scheme="individual", fading=Fading())
    with pytest.raises(ValueError, match="topology"):
        _spec(4, scheme="feel", topology=Topology(cells=2, edges=2),
              faults=Faults(drop_prob=0.2))
    with pytest.raises(ValueError, match="replan"):
        _spec(3, scheme="feel", adapt_tau=TauAdapt(choices=(1, 2)))
    with pytest.raises(ValueError, match="starting point"):
        _spec(3, scheme="feel", replan=2, local_steps=3,
              adapt_tau=TauAdapt(choices=(1, 2)))
    with pytest.raises(TypeError, match="fading="):
        _spec(3, scheme="feel", fading=0.5)
    with pytest.raises(ValueError, match="hierarchical"):
        _spec(4, scheme="feel", topology=Topology(cells=2, edges=2),
              sampling=Sampling(size=2, weighted=True))
    with pytest.raises(ValueError, match="Horvitz-Thompson"):
        _spec(3, scheme="feel", sampling=Sampling(size=2, weighted=True),
              energy=EnergyBudget(budget_j=0.5))
    # the scheduler itself refuses dynamics on unknown/legacy policies
    # and on the hierarchical path
    with pytest.raises(ValueError, match="hierarchical"):
        FeelScheduler(devices=_fleet(4), n_params=4000, b_max=8,
                      topology=Topology(cells=2, edges=2),
                      fading=Fading())


def test_bucket_key_structural_vs_value_fields():
    base = _spec(3, scheme="feel")
    # Markov state count shapes nothing today but keys the program family
    # (belief arrays are (states,)-free; the count is the grid coordinate)
    assert _spec(3, scheme="feel", fading=Fading(states=3)).bucket_key() \
        != base.bucket_key()
    assert _spec(3, scheme="feel", fading=Fading(states=3)).bucket_key() \
        != _spec(3, scheme="feel", fading=Fading(states=4)).bucket_key()
    # value-only knobs: spread / faults / energy do not split buckets
    assert _spec(3, scheme="feel",
                 fading=Fading(states=3, spread=0.2)).bucket_key() == \
        _spec(3, scheme="feel",
              fading=Fading(states=3, spread=1.4)).bucket_key()
    assert _spec(3, scheme="feel",
                 faults=Faults(drop_prob=0.3)).bucket_key() == \
        base.bucket_key()
    assert _spec(3, scheme="feel",
                 energy=EnergyBudget(budget_j=0.5)).bucket_key() == \
        base.bucket_key()
    # adaptive-τ choices are structural (each realized τ is a program)
    assert _spec(3, scheme="feel", replan=2,
                 adapt_tau=TauAdapt(choices=(1, 2))).bucket_key() != \
        _spec(3, scheme="feel", replan=2).bucket_key()


# ---------------------------------------------------------------------------
# process determinism + stream hygiene
# ---------------------------------------------------------------------------


def test_processes_chunked_equal_monolithic():
    fad = Fading(states=4, spread=1.0, stickiness=0.8, seed=7)
    mono = FadingProcess(fad, k=5, seed=11).draw(6)
    chunked = FadingProcess(fad, k=5, seed=11)
    np.testing.assert_array_equal(
        mono, np.concatenate([chunked.draw(2) for _ in range(3)]))
    # seeded: same stream reproduces, different fading seed diverges
    np.testing.assert_array_equal(
        mono, FadingProcess(fad, k=5, seed=11).draw(6))
    assert not np.array_equal(
        mono, FadingProcess(Fading(states=4, spread=1.0, stickiness=0.8,
                                   seed=8), k=5, seed=11).draw(6))

    flt = Faults(slow_prob=0.4, drop_prob=0.3, seed=5)
    s_mono, k_mono = FaultProcess(flt, k=5, seed=11).draw(6)
    chunked = FaultProcess(flt, k=5, seed=11)
    parts = [chunked.draw(2) for _ in range(3)]
    np.testing.assert_array_equal(
        s_mono, np.concatenate([p[0] for p in parts]))
    np.testing.assert_array_equal(
        k_mono, np.concatenate([p[1] for p in parts]))
    assert set(np.unique(s_mono)) <= {1.0, flt.slow_factor}
    assert set(np.unique(k_mono)) <= {0.0, 1.0}


def test_scheduler_chunked_equals_monolithic_under_drift():
    """Open-loop chunked planning is bit-identical to monolithic even
    with every dynamics field live (the fixed g0 belief + per-period
    fixed-shape draws make the stream position chunking-invariant)."""
    kw = dict(fading=Fading(states=3, spread=1.2, stickiness=0.9),
              faults=Faults(slow_prob=0.3, drop_prob=0.2, seed=1),
              energy=EnergyBudget(budget_j=1.0))
    mono = _sched(**kw).plan_horizon(6)
    sch = _sched(**kw)
    chunks = [sch.plan_horizon(2, warm_start=(i > 0)) for i in range(3)]
    for f in ("batch", "tau_up", "latency", "participation", "energy",
              "slowdown"):
        np.testing.assert_array_equal(
            getattr(mono, f),
            np.concatenate([getattr(c, f) for c in chunks]), err_msg=f)


def test_identity_dynamics_bitwise_scheduler():
    """Zero-spread fading + prob-0 faults + infinite budget collapse to
    the static plan bitwise — including on the fixed baseline policies,
    whose rng draws must not shift when dynamics streams are live."""
    for policy in ("proposed", "online", "full", "random"):
        h0 = _sched(policy=policy).plan_horizon(5)
        h1 = _sched(policy=policy,
                    fading=Fading(states=3, spread=0.0),
                    faults=Faults(slow_prob=0.0, drop_prob=0.0),
                    energy=EnergyBudget()).plan_horizon(5)
        np.testing.assert_array_equal(h0.batch, h1.batch, err_msg=policy)
        np.testing.assert_array_equal(h0.tau_up, h1.tau_up, err_msg=policy)
        np.testing.assert_array_equal(h0.latency, h1.latency,
                                      err_msg=policy)
        # identity dynamics still surface the config-static ledgers
        assert h0.energy is None and h0.slowdown is None
        assert np.all(h1.participation == 1.0)
        assert np.all(h1.slowdown == 1.0)


def test_identity_dynamics_bitwise_experiment(dataset):
    """End to end: the identity-dynamics spec reproduces the static
    run's every ledger bitwise (losses/accs/times/global_batch)."""
    data, test = dataset
    static = Experiment(data, test, [_spec(3, scheme="feel")]).run(
        periods=PERIODS, executor=SerialExecutor())
    ident = Experiment(data, test, [_spec(
        3, scheme="feel",
        fading=Fading(states=3, spread=0.0),
        faults=Faults(slow_prob=0.0, drop_prob=0.0),
        energy=EnergyBudget())]).run(
            periods=PERIODS, executor=SerialExecutor())
    _assert_bitwise(static, ident)


# ---------------------------------------------------------------------------
# the tentpole pin: closed loop beats open loop under drift
# ---------------------------------------------------------------------------

_DRIFT = dict(devices=tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                            for f in (0.7, 2.1, 1.4, 0.9)),
              fading=Fading(states=3, spread=1.2, stickiness=0.95))


def test_closed_loop_diverges_and_wins_under_drift():
    open_loop = _sched(**_DRIFT).plan_horizon(8)
    sch = _sched(**_DRIFT)
    closed = [sch.plan_horizon(2, warm_start=(i > 0), closed_loop=True)
              for i in range(4)]
    tau_c = np.concatenate([c.tau_up for c in closed])
    lat_c = np.concatenate([c.latency for c in closed])
    # same drift realization either way (own stream, chunking-invariant)…
    assert not np.array_equal(open_loop.tau_up, tau_c)
    # …but re-pricing the TDMA slots at fresh gains wins on the realized
    # latency ledger (the stale g0 belief misallocates airtime)
    assert lat_c.sum() < open_loop.latency.sum()


def test_closed_loop_wins_end_to_end(dataset):
    data, test = dataset
    spec = _spec(4, scheme="feel", b_max=16, seeds=(3,),
                 fleet=_DRIFT["devices"],
                 fading=Fading(states=3, spread=1.2, stickiness=0.95))
    ro = Experiment(data, test, [spec]).run(periods=8,
                                            executor=SerialExecutor())
    rc = Experiment(data, test, [spec]).run(periods=8, replan=2,
                                            executor=SerialExecutor())
    assert not np.array_equal(ro.times, rc.times)
    assert rc.times[0, -1] < ro.times[0, -1]


# ---------------------------------------------------------------------------
# faults + energy ledgers
# ---------------------------------------------------------------------------


def test_straggler_slowdown_stretches_latency():
    h0 = _sched().plan_horizon(5)
    h1 = _sched(faults=Faults(slow_prob=1.0, slow_factor=4.0)) \
        .plan_horizon(5)
    # the solver's allocation is untouched (stragglers are realized,
    # not planned around) but the realized ledger pays the stretch
    np.testing.assert_array_equal(h0.batch, h1.batch)
    assert np.all(h1.slowdown == 4.0)
    assert np.all(h1.latency >= h0.latency)
    assert np.any(h1.latency > h0.latency)


def test_dropout_masks_participation():
    h = _sched(faults=Faults(drop_prob=0.5, seed=2)).plan_horizon(8)
    part = h.participation
    assert part is not None and set(np.unique(part)) <= {0.0, 1.0}
    assert 0.0 < part.mean() < 1.0            # some dropped, some kept
    np.testing.assert_array_equal(h.batch == 0, part == 0.0)


def test_energy_budget_sheds_and_respects_ledger():
    h0 = _sched().plan_horizon(5)
    tight = _sched(energy=EnergyBudget(budget_j=0.35))
    h1 = tight.plan_horizon(5)
    assert h1.energy is not None
    # shedding only ever reduces the allocation…
    assert np.all(h1.batch <= h0.batch)
    assert np.any(h1.batch < h0.batch)
    # …and every user still participating lands under budget
    active = h1.participation > 0.5
    assert np.all(h1.energy[active] <= 0.35 + 1e-9)
    assert np.all(h1.energy[~active] == 0.0)
    # a budget nobody can meet soft-floors instead of dropping the fleet
    h2 = _sched(energy=EnergyBudget(budget_j=1e-6)).plan_horizon(3)
    assert np.all(h2.participation.sum(axis=1) >= 1)


def test_energy_ledger_surfaces_through_lowering(dataset):
    data, _ = dataset
    specs = [_spec(3, scheme="feel", energy=EnergyBudget(budget_j=0.35))]
    (bucket,) = lowering.group_rows(specs)
    plan = lowering.plan_bucket(bucket, data, PERIODS)
    ledger = plan.payload.get("energy")
    assert ledger is not None and ledger.shape == (1, PERIODS, 3)
    assert np.all(ledger <= 0.35 + 1e-9)


# ---------------------------------------------------------------------------
# weighted (Horvitz-Thompson) sampling
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_weighted_sampling_unbiased_mean(seed):
    """The executed 1/p-corrected aggregate is an unbiased estimator of
    the full-participation weighted mean: averaging the HT estimator
    over many cohort draws converges on the static aggregate."""
    k, s, draws = 6, 3, 4000
    rng = np.random.default_rng(seed)
    bbar = rng.integers(1, 9, size=k).astype(float)   # planned batches
    grads = rng.normal(size=k)                        # per-user payloads
    samp = Sampling(size=s, weighted=True, seed=seed)
    mask = ParticipationSampler(samp, k, seed=seed).draw(draws)
    den = samp.p_of(k) * bbar.sum()                   # fixed denominator
    est = (mask * (bbar * grads)).sum(axis=1) / den
    target = (bbar * grads).sum() / bbar.sum()
    se = est.std(ddof=1) / np.sqrt(draws)
    assert abs(est.mean() - target) < 5.0 * se + 1e-12


def test_weighted_full_cohort_collapses_to_plain(dataset):
    """At S == K the inclusion probability is 1 and the HT denominator
    equals the executed batch sum — weighted == unweighted bitwise."""
    data, test = dataset
    runs = []
    for weighted in (False, True):
        runs.append(Experiment(data, test, [_spec(
            3, scheme="feel",
            sampling=Sampling(size=3, weighted=weighted))]).run(
                periods=PERIODS, executor=SerialExecutor()))
    _assert_bitwise(runs[0], runs[1])


def test_weighted_subsampling_changes_aggregate(dataset):
    data, test = dataset
    runs = []
    for weighted in (False, True):
        runs.append(Experiment(data, test, [_spec(
            4, scheme="feel",
            sampling=Sampling(size=2, weighted=weighted))]).run(
                periods=PERIODS, executor=SerialExecutor()))
    # the correction really reweights the executed aggregation
    assert not np.array_equal(np.asarray(runs[0].losses),
                              np.asarray(runs[1].losses))


# ---------------------------------------------------------------------------
# adaptive local steps
# ---------------------------------------------------------------------------


def test_recommend_tau_needs_feedback_then_scores():
    sch = _sched()
    # no realized chunk yet → conservatively keep the current τ
    assert sch.recommend_tau((1, 2, 4), 2) == 2
    sch.plan_horizon(2, closed_loop=True)
    tau = sch.recommend_tau((1, 2, 4), 1)
    assert tau in (1, 2, 4)
    # the score is deterministic given the same realized stats
    assert tau == sch.recommend_tau((1, 2, 4), 1)


def test_adaptive_tau_end_to_end(dataset):
    data, test = dataset
    spec = _spec(3, scheme="feel", replan=2, local_steps=1,
                 adapt_tau=TauAdapt(choices=(1, 2)))
    res = Experiment(data, test, [spec]).run(periods=PERIODS,
                                             executor=SerialExecutor())
    assert np.all(np.isfinite(np.asarray(res.losses)))
    assert np.all(np.diff(res.times[0]) > 0)

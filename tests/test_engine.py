"""Device-resident engine regression tests: the single jitted ``lax.scan``
trajectory must reproduce the seed's per-period Python loop (loss/acc/time
series), the vmap-over-seeds sweep must batch cleanly, and the big-model
multi-step scan must match sequential ``train_step`` calls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.fed.sweep import run_seed_batch, run_sweep
from repro.fed.trainer import FeelSimulation, run_scheme


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=700, dim=48, seed=0, spread=6.0)
    return full.split(120)


@pytest.fixture(scope="module")
def fleet():
    return [DeviceProfile(kind="cpu", f_cpu=f * 1e9) for f in [0.7, 1.4, 2.1]]


def _pair(dataset, fleet, policy, **kw):
    data, test = dataset
    mk = lambda eng: FeelSimulation(  # noqa: E731
        fleet, data, test, partition="noniid", policy=policy, b_max=32,
        base_lr=0.15, seed=5, engine=eng, **kw)
    return mk("scan"), mk("python")


@pytest.mark.parametrize("policy", ["proposed", "full"])
def test_scan_matches_python_loop(dataset, fleet, policy):
    """feel/proposed and gradient_fl (policy=full): identical schedules,
    loss/acc/time series equal to float tolerance."""
    sim_s, sim_p = _pair(dataset, fleet, policy)
    rs = sim_s.run(12, eval_every=4)
    rp = sim_p.run(12, eval_every=4)
    np.testing.assert_allclose(rs.times, rp.times, rtol=0, atol=0)
    assert rs.global_batches == rp.global_batches
    np.testing.assert_allclose(rs.losses, rp.losses, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(rs.accs, rp.accs, atol=1e-5, rtol=1e-5)
    # final params agree too (same trajectory, not just same metrics)
    for a, b in zip(jax.tree_util.tree_leaves(sim_s.params),
                    jax.tree_util.tree_leaves(sim_p.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_scan_matches_python_loop_local_steps(dataset, fleet):
    """tau>1 local updates go through the same scan port."""
    sim_s, sim_p = _pair(dataset, fleet, "proposed", local_steps=2)
    rs = sim_s.run(6, eval_every=3)
    rp = sim_p.run(6, eval_every=3)
    np.testing.assert_allclose(rs.losses, rp.losses, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(rs.times, rp.times, rtol=0, atol=0)


def test_xi_feedback_applied_post_hoc(dataset, fleet):
    sim, _ = _pair(dataset, fleet, "proposed")
    xi0 = sim.scheduler.xi_est.xi
    sim.run(8, eval_every=4)
    assert sim.scheduler.xi_est.xi != xi0


def test_vmap_over_seeds_shapes(dataset, fleet):
    """run_seed_batch: one compiled program, (n_seeds, periods) series."""
    data, test = dataset
    seeds, periods = [0, 1, 2, 3], 5
    sims = [FeelSimulation(fleet, data, test, partition="iid",
                           policy="full", b_max=32, base_lr=0.15, seed=s)
            for s in seeds]
    losses, accs, times, gb = run_seed_batch(sims, periods)
    assert losses.shape == accs.shape == times.shape == gb.shape \
        == (len(seeds), periods)
    assert np.all(np.isfinite(losses)) and np.all(np.diff(times, axis=1) > 0)
    # distinct seeds => distinct trajectories
    assert not np.allclose(losses[0], losses[1])
    # batched run must equal the per-seed scan run
    solo = FeelSimulation(fleet, data, test, partition="iid", policy="full",
                          b_max=32, base_lr=0.15, seed=seeds[2])
    r = solo.run(periods, eval_every=2)
    np.testing.assert_allclose(r.losses,
                               losses[2][[0, 2, 4]], atol=1e-5, rtol=1e-5)


def test_run_sweep_grid(dataset, fleet):
    data, test = dataset
    res = run_sweep({"cpu3": fleet}, data, test,
                    policies=("proposed", "online"), partitions=("iid",),
                    seeds=(0, 1), periods=4, b_max=32, base_lr=0.15)
    assert set(res) == {"cpu3/iid/proposed", "cpu3/iid/online"}
    cell = res["cpu3/iid/proposed"]
    assert cell.accs.shape == (2, 4)
    assert cell.speed(2.0).shape == (2,)          # unreachable => inf
    assert np.all(np.isinf(cell.speed(2.0)))
    rr = cell.run_result(seed_i=1, eval_every=2)
    assert len(rr.accs) == 3                       # periods 0, 2, 3


def test_dev_trajectory_schemes(dataset, fleet):
    """individual / model_fl ride the scan engine and stay finite."""
    data, test = dataset
    ri = run_scheme("individual", fleet, data, test, "noniid", 6,
                    eval_every=3)
    rm = run_scheme("model_fl", fleet, data, test, "noniid", 6,
                    eval_every=3)
    assert np.isfinite(ri.accs[-1]) and np.isfinite(rm.accs[-1])
    assert rm.times[-1] > ri.times[-1]


def test_multi_train_step_matches_sequential():
    """Big-model path: lax.scan of train_step == per-step Python loop."""
    from repro.configs import ARCHS
    from repro.fed.train_step import (TrainState, make_multi_train_step,
                                      make_train_step)
    from repro.models.model import Runtime, init
    from repro.optim import sgd

    cfg = ARCHS["qwen1.5-4b"].reduced()
    rt = Runtime()
    params = init(cfg, jax.random.key(0))
    opt = sgd()
    T, B, S = 3, 2, 8
    toks = jax.random.randint(jax.random.key(1), (T, B, S + 1), 0, cfg.vocab)
    batches = {"tokens": toks[..., :-1], "labels": toks[..., 1:],
               "weights": jnp.ones((T, B, S))}
    lrs = jnp.array([0.1, 0.05, 0.02], jnp.float32)

    state0 = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    many = jax.jit(make_multi_train_step(cfg, rt, opt))
    state_scan, metrics = many(state0, batches, lrs)
    assert metrics["loss"].shape == (T,)

    step = make_train_step(cfg, rt, opt)
    state_seq = state0
    seq_losses = []
    for t in range(T):
        b = {k: v[t] for k, v in batches.items()}
        state_seq, m = step(state_seq, b, lrs[t])
        seq_losses.append(float(m["loss"]))
    np.testing.assert_allclose(np.asarray(metrics["loss"]), seq_losses,
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(state_scan.params),
                    jax.tree_util.tree_leaves(state_seq.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)

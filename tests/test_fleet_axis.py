"""Fleet as a first-class sweep axis (ragged-fleet padded lowering).

Covers the ``users=`` grid axis (resize rule, labeled fleets, num_users
coordinate, validation), the ONE-compiled-program-per-padded-shape-family
acceptance (trace-count pattern), padded-vs-solo bit equality for the
ledgers AND the device trajectories (feel proposed/fixed policies and
both dev-family schemes), mask hygiene (padded user rows never leak into
batchsize / bandwidth / accuracy reductions), the masked Algorithm-1
rows solver, and cross-K fused host planning.
"""
import numpy as np
import pytest

from repro.api import AsyncExecutor, Experiment, ScenarioSpec, grid
from repro.api.lowering import plan_bucket
from repro.core import DeviceProfile, FeelScheduler
from repro.core.scheduler import plan_horizons_batch
from repro.core.solver import FleetRows, solve_uplink_rows
from repro.channels.model import Cell
from repro.data.pipeline import ClassificationData
from repro.fed import engine
from repro.testing import no_retrace

# distinctive shapes (no other test module uses dim=28 / hidden=56 /
# b_max=20) so the lru-cached engine programs are fresh and the
# trace-count assertions below are exact
DIM, HIDDEN, BMAX = 28, 56, 20


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=420, dim=DIM, seed=0, spread=6.0)
    return full.split(80)


def _fleet(k):
    return tuple(DeviceProfile(kind="cpu", f_cpu=(0.6 + 0.3 * i) * 1e9)
                 for i in range(k))


def _spec(k, **kw):
    kw.setdefault("name", f"K{k}")
    kw.setdefault("policy", "proposed")
    kw.setdefault("partition", "noniid")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    return ScenarioSpec(fleet=_fleet(k), **kw)


# ---------------------------------------------------------------------------
# the users= grid axis
# ---------------------------------------------------------------------------


def test_users_axis_resize_rule():
    base = _spec(3)
    study = grid(base, users=[2, 3, 7])
    assert study.coord_names == ("num_users",)
    assert [s.k for s in study] == [2, 3, 7]
    # truncation keeps the leading profiles; extension cycles round-robin
    assert study[0].fleet == base.fleet[:2]
    assert study[2].fleet == tuple(base.fleet[i % 3] for i in range(7))
    assert [study.axis_coords(s)["num_users"] for s in study] == [2, 3, 7]
    assert study[0].name == "K3/users=2"
    # K == base fleet size is the base fleet verbatim
    assert study[1].fleet == base.fleet


def test_users_axis_explicit_fleets():
    slow = tuple(DeviceProfile(kind="cpu", f_cpu=0.5e9) for _ in range(4))
    study = grid(_spec(3), users={"slow4": slow, "base2": _fleet(2)})
    assert [study.axis_coords(s)["num_users"] for s in study] \
        == ["slow4", "base2"]
    assert study[0].fleet == slow and study[1].k == 2


def test_users_axis_crosses_with_other_axes():
    study = grid(_spec(3), users=[2, 4], partition=["iid", "noniid"])
    assert len(study) == 4
    assert study.coord_names == ("num_users", "partition")
    assert {(s.k, s.partition) for s in study} \
        == {(2, "iid"), (2, "noniid"), (4, "iid"), (4, "noniid")}


def test_users_axis_validation():
    base = _spec(3)
    with pytest.raises(ValueError, match="positive int"):
        grid(base, users=[0])
    with pytest.raises(ValueError, match="positive int"):
        grid(base, users=[2.5])
    with pytest.raises(ValueError, match="positive int"):
        grid(base, users=[True])
    with pytest.raises(ValueError, match="empty"):
        grid(base, users={"none": ()})
    with pytest.raises(ValueError, match="no values"):
        grid(base, users=[])
    # a plain fleet axis is still rejected (its built-in coordinate holds
    # the spec *name*, not the swept fleet) — users= is the supported way
    with pytest.raises(ValueError, match="built-in"):
        grid(base, fleet=[base.fleet])


# ---------------------------------------------------------------------------
# acceptance: one compiled program per padded-shape family
# ---------------------------------------------------------------------------


def test_users_grid_is_one_bucket_one_trace(dataset):
    """ISSUE-4 acceptance: grid(base, users=[...]) lowers to ONE bucket
    and ONE trajectory trace for the whole K-sweep."""
    data, test = dataset
    study = grid(_spec(3, seeds=(0, 1)), users=[3, 5, 8])
    exp = Experiment(data, test, study)
    buckets = exp.lower()
    assert len(buckets) == 1
    assert buckets[0].k_pad == 8
    with no_retrace(expect=1):                    # 3 fleet sizes, 1 program
        res = exp.run(periods=4)
    assert res.n_buckets == 1
    assert res.rows == 6
    # num_users is a selectable Results coordinate
    assert res.unique("num_users") == (3, 5, 8)
    assert res.sel(num_users=5).rows == 2
    # global batch actually grows with K (the paper's K knob is live)
    gb = [res.sel(num_users=k).global_batch.mean() for k in (3, 5, 8)]
    assert gb[0] < gb[1] < gb[2], gb


# ---------------------------------------------------------------------------
# padded-vs-solo bit equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["proposed", "full"])
def test_padded_bucket_bit_identical_to_solo_runs(dataset, policy):
    """A K-heterogeneous grid (K ∈ {3, 5, 8}) run as one padded bucket
    reproduces three solo unpadded runs: ledgers and horizons (host
    planning) bit-for-bit, device trajectories to float tolerance — the
    masked math is value-exact (zeros add exactly), but XLA retiles its
    reductions when the vmap batch width changes, the same 1-ulp caveat
    the PR-2/PR-3 equivalence suites carry for cross-program compares."""
    data, test = dataset
    ks = (3, 5, 8)
    specs = [_spec(k, policy=policy, seeds=(0, 1)) for k in ks]
    exp = Experiment(data, test, specs)
    assert len(exp.lower()) == 1
    res = exp.run(periods=5, executor=AsyncExecutor())
    for k in ks:
        solo = Experiment(data, test,
                          [_spec(k, policy=policy, seeds=(0, 1))]
                          ).run(periods=5)
        cell = res.sel(fleet=f"K{k}")
        np.testing.assert_array_equal(cell.times, solo.times)
        np.testing.assert_array_equal(cell.global_batch, solo.global_batch)
        np.testing.assert_allclose(np.asarray(cell.losses),
                                   np.asarray(solo.losses),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(cell.accs),
                                   np.asarray(solo.accs),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("scheme", ["individual", "model_fl"])
def test_padded_dev_bucket_bit_identical_to_solo_runs(dataset, scheme):
    """The per-device-parameter schemes ride the same padded contract:
    masked parameter averages keep padded device rows out (ledger
    bit-for-bit, series to float tolerance as above)."""
    data, test = dataset
    specs = [_spec(k, scheme=scheme, seeds=(0,)) for k in (3, 6)]
    exp = Experiment(data, test, specs)
    assert len(exp.lower()) == 1
    res = exp.run(periods=4)
    for k in (3, 6):
        solo = Experiment(data, test,
                          [_spec(k, scheme=scheme, seeds=(0,))]
                          ).run(periods=4)
        cell = res.sel(fleet=f"K{k}")
        np.testing.assert_array_equal(cell.times, solo.times)
        np.testing.assert_allclose(np.asarray(cell.losses),
                                   np.asarray(solo.losses),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(cell.accs),
                                   np.asarray(solo.accs),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# mask hygiene: padded rows never leak into any reduction
# ---------------------------------------------------------------------------


def test_padded_plan_mask_hygiene(dataset):
    """Padded user rows of a planned bucket carry exactly zero weight,
    zero batch and zero sample contribution; global_batch sums active
    users only."""
    data, _ = dataset
    specs = [_spec(k, seeds=(0,)) for k in (3, 8)]
    [bucket] = Experiment(data, None, specs).lower()
    assert bucket.k_pad == 8
    plan = plan_bucket(bucket, data, periods=4)
    mask = plan.payload["active"]
    np.testing.assert_array_equal(mask[0], [1] * 3 + [0] * 5)
    np.testing.assert_array_equal(mask[1], [1] * 8)
    sched_k3 = plan.payload["schedules"][0]
    assert np.all(sched_k3.weight[:, 3:] == 0)
    assert np.all(sched_k3.batch[:, 3:] == 0)
    assert np.all(sched_k3.idx[:, 3:] == 0)
    # the ledger's global batch is the ACTIVE batch sum, not the padded
    np.testing.assert_array_equal(
        plan.global_batch[0],
        sched_k3.batch[:, :3].sum(1).astype(np.int64))


def test_active_mask_guards_engine_reductions(dataset):
    """The engine's active mask is a real guard, not dead weight: poison
    the padded columns of a padded schedule with garbage weights/batch
    and the masked trajectory must still reproduce the clean run."""
    data, test = dataset
    import jax
    import jax.numpy as jnp
    sim_spec = _spec(3, seeds=(0,))
    [bucket] = Experiment(data, test, [sim_spec]).lower()
    plan = plan_bucket(bucket, data, periods=3)
    clean = plan.payload["schedules"][0]
    padded = engine.pad_schedule(clean, 6)
    poisoned = engine.Schedule(
        idx=padded.idx.copy(), weight=padded.weight.copy(),
        batch=padded.batch.copy(), lr=padded.lr, times=padded.times,
        global_batch=padded.global_batch)
    poisoned.weight[:, 3:] = 1.0                  # garbage in padded rows
    poisoned.batch[:, 3:] = 7.0
    active = jnp.asarray([1.0] * 3 + [0.0] * 3, jnp.float32)

    key = jax.random.key(0)
    from repro.fed import feel_model
    params0 = feel_model.init(key, HIDDEN, depth=3, input_dim=DIM)
    res_clean = engine.run_trajectory(
        params0, engine.zero_residual(params0, 3), clean, data, test,
        ratio=sim_spec.compression)
    res_poisoned = engine.run_trajectory(
        params0, engine.zero_residual(params0, 6), poisoned, data, test,
        ratio=sim_spec.compression, active=active)
    for a, b in zip(res_clean[2], res_poisoned[2]):   # losses, accs, decays
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_masked_solver_rows_zero_padded_columns():
    """Padded columns of the masked Algorithm-1 solve get exactly zero
    batchsize and zero slot share, and active columns are bit-equal to
    the compact solve."""
    fleet = _fleet(3)
    rng = np.random.default_rng(3)
    rates = rng.uniform(2e7, 2e8, (4, 3))
    B = rng.uniform(6, 50, 4)
    dl = 0.05 * np.sqrt(B)
    bt0, tau0, e0, mu0 = solve_uplink_rows(list(fleet), rates, 1.2e4,
                                           0.010, B, dl, BMAX)
    fr = FleetRows.from_fleets([fleet] * 4, k_pad=7)
    rates_p = np.concatenate([rates, np.full((4, 4), 1e7)], axis=1)
    bt1, tau1, e1, mu1 = solve_uplink_rows(fr, rates_p, 1.2e4,
                                           0.010, B, dl, BMAX)
    assert np.all(bt1[:, 3:] == 0) and np.all(tau1[:, 3:] == 0)
    np.testing.assert_array_equal(bt0, bt1[:, :3])
    np.testing.assert_array_equal(tau0, tau1[:, :3])
    np.testing.assert_array_equal(e0, e1)
    np.testing.assert_array_equal(mu0, mu1)


def test_channel_pad_keeps_active_stream(dataset):
    """Padded rate columns never touch the rng stream: the active columns
    of a pad_to draw are bit-equal to the unpadded draw."""
    cell_a, cell_b = Cell.make(7), Cell.make(7)
    d = cell_a.drop_users(3)
    d2 = cell_b.drop_users(3)
    up0, down0 = cell_a.avg_rate_updown_rows(d, 5)
    up1, down1 = cell_b.avg_rate_updown_rows(d2, 5, pad_to=6)
    assert up1.shape == (5, 6)
    np.testing.assert_array_equal(up0, up1[:, :3])
    np.testing.assert_array_equal(down0, down1[:, :3])
    assert np.all(up1[:, 3:] == cell_b.cfg.bandwidth_hz)
    # follow-up draws consume identical streams afterwards too
    np.testing.assert_array_equal(cell_a.avg_rate(d), cell_b.avg_rate(d2))


def test_plan_horizons_batch_fuses_across_fleet_sizes():
    """Proposed-policy planning for different-K schedulers runs as one
    masked lockstep solve, bit-identical to solo planning — and the
    scheduler state advances exactly as the per-call path would."""
    mk = lambda: [FeelScheduler(devices=list(_fleet(k)), n_params=37000,  # noqa
                                policy="proposed", b_max=BMAX, seed=s)
                  for k in (3, 5, 9) for s in (0, 1)]
    fused, solo = mk(), mk()
    hs_fused = plan_horizons_batch(fused, 7)
    hs_solo = [s.plan_horizon(7) for s in solo]
    for a, b in zip(hs_fused, hs_solo):
        np.testing.assert_array_equal(a.batch, b.batch)
        np.testing.assert_array_equal(a.tau_up, b.tau_up)
        np.testing.assert_array_equal(a.tau_down, b.tau_down)
        np.testing.assert_array_equal(a.latency, b.latency)
        np.testing.assert_array_equal(a.lr, b.lr)
        np.testing.assert_array_equal(a.global_batch, b.global_batch)
    for a, b in zip(fused, solo):
        assert a._b_cache == b._b_cache and a._period == b._period

"""hlo_cost parser validation: must agree with XLA's own cost_analysis on
loop-free modules and correctly multiply scan bodies by trip count."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert shape_bytes("bf16[2,3,4]") == 24 * 2
    assert shape_bytes("(f32[2]{0}, s32[])") == 8 + 4
    assert shape_bytes("pred[]") == 1


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_dot_flops_match_xla():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    mine = analyze(c.as_text())
    want = 2 * 64 * 128 * 32
    assert mine.flops == pytest.approx(want, rel=0.05)


def test_scan_trip_count_multiplication():
    def body(x, w):
        return jax.nn.relu(x @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def unrolled(x, ws):
        for i in range(6):
            x = jax.nn.relu(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    fs = analyze(_compile(scanned, x, ws).as_text())
    fu = analyze(_compile(unrolled, x, ws).as_text())
    assert fs.flops == pytest.approx(fu.flops, rel=0.1)
    # XLA's own analysis counts the body once — ours must be ~6x larger
    ca = _compile(scanned, x, ws).cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert fs.flops > 4 * xla


def test_bytes_anchor_model():
    """Fusion counts its RESULT (the write); elementwise reads are fused.
    A lone a*2 therefore costs ~1 buffer; a matmul costs in+in+out."""
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = _compile(lambda a: a * 2.0, x)
    mine = analyze(c.as_text())
    assert mine.bytes == pytest.approx(1024 * 1024 * 4, rel=0.3)

    w = jax.ShapeDtypeStruct((1024, 512), jnp.float32)
    cd = _compile(lambda a, b: a @ b, x, w)
    md = analyze(cd.as_text())
    want = (1024 * 1024 + 1024 * 512 + 1024 * 512) * 4
    assert md.bytes == pytest.approx(want, rel=0.3)


def test_collective_regex_on_synthetic_hlo():
    """Collectives + while trip counts on a hand-written HLO module."""
    hlo = """
%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  %g = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%g), replica_groups={}
  ROOT %t = (s32[], f32[64]{0}) tuple(%g, %ar)
}

%cond (p2: (s32[], f32[64])) -> pred[] {
  %p2 = (s32[], f32[64]{0}) parameter(0)
  %c = s32[] constant(8)
  %i = s32[] get-tuple-element(%p2), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  %z = s32[] constant(0)
  %init = (s32[], f32[64]{0}) tuple(%z, %a)
  %w = (s32[], f32[64]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    t = analyze(hlo)
    # all-gather once (512B) + all-reduce 5x (5*256B)
    assert t.collective_by_op["all-gather"] == 128 * 4
    assert t.collective_by_op["all-reduce"] == 5 * 64 * 4

"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (the brief's per-kernel allclose contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.sbc import sbc_stats, sbc_apply
from repro.testing.proptest import given, settings, strategies as st

KEY = jax.random.key(42)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,hd", [(2, 128, 64), (4, 256, 64),
                                     (1, 256, 128), (3, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(bh, s, hd, dtype):
    q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (bh, s, hd),
                                 dtype) for i in range(3))
    out = flash_attention_bhsd(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 100])
def test_flash_attention_window(window):
    q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (2, 256, 64))
               for i in range(3))
    out = flash_attention_bhsd(q, k, v, causal=True, window=window,
                               block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_noncausal():
    q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (2, 128, 64))
               for i in range(3))
    out = flash_attention_bhsd(q, k, v, causal=False, block_q=64,
                               block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_gqa_wrapper():
    """ops.flash_attention expands GQA groups and agrees with the model's
    naive attention path."""
    from repro.models.attention import attend_naive
    B, S, Hq, Hkv, hd = 2, 128, 8, 2, 64
    q = jax.random.normal(KEY, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, hd))
    pos = jnp.arange(S)
    out = ops.flash_attention(q, k, v, interpret=True, block_q=64,
                              block_k=64)
    want = attend_naive(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ctx,block_s,pos", [(256, 64, 100), (512, 128, 511),
                                             (128, 128, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vs_ref(ctx, block_s, pos, dtype):
    from repro.kernels.flash_decode import flash_decode_bhd
    BH, hd = 4, 64
    q = jax.random.normal(KEY, (BH, 1, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, ctx, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, ctx, hd), dtype)
    out = flash_decode_bhd(q, k, v, pos, block_s=block_s, interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_flash_decode_ring_buffer_window():
    """Ring-buffer semantics: cache size == window, pos beyond ctx."""
    from repro.kernels.flash_decode import flash_decode_bhd
    BH, ctx, hd = 2, 128, 64
    q = jax.random.normal(KEY, (BH, 1, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, ctx, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, ctx, hd))
    pos = 1000                      # far past the ring size
    out = flash_decode_bhd(q, k, v, pos, window=ctx, block_s=64,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, pos, window=ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_decode_gqa_wrapper_matches_model_decode_math():
    from repro.kernels import ops
    B, ctx, Hq, Hkv, hd = 2, 128, 8, 2, 64
    q = jax.random.normal(KEY, (B, 1, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, ctx, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, ctx, Hkv, hd))
    out_i = ops.flash_decode(q, k, v, 64, interpret=True, block_s=64)
    out_r = ops.flash_decode(q, k, v, 64)          # ref fallback on CPU
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 32, 2, 16, 32),
    (1, 64, 2, 64, 1, 32, 16),
    (2, 256, 8, 32, 4, 64, 64),
    (1, 128, 4, 32, 4, 16, 128),   # single chunk
])
def test_ssd_scan_shapes(b, s, h, p, g, n, chunk):
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, g, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, g, n)) * 0.5
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=3e-4,
                               rtol=3e-4)


def test_ssd_scan_bf16():
    b, s, h, p, g, n = 1, 128, 2, 32, 1, 16
    x = jax.random.normal(KEY, (b, s, h, p), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (b, s, h))).astype(jnp.bfloat16)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    Bm = (jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, g, n)) * 0.5
          ).astype(jnp.bfloat16)
    Cm = (jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, g, n)) * 0.5
          ).astype(jnp.bfloat16)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    want = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), atol=0.05,
                               rtol=0.05)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == the literal per-token SSM recurrence (the decode
    path's update rule) — the strongest correctness anchor."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 4
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, g, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, g, n)) * 0.5
    y_chunk = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=16)

    # sequential: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t
    state = np.zeros((b, h, p, n), np.float32)
    ys = []
    rep = h // g
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A))       # (b,h)
        Bt = np.repeat(np.asarray(Bm[:, t]), rep, axis=1)        # (b,h,n)
        Ct = np.repeat(np.asarray(Cm[:, t]), rep, axis=1)
        upd = (np.asarray(dt[:, t])[:, :, None, None]
               * np.asarray(x[:, t])[..., None] * Bt[:, :, None, :])
        state = state * dA[:, :, None, None] + upd
        ys.append(np.einsum("bhpn,bhn->bhp", state, Ct))
    y_seq = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_seq, atol=2e-4,
                               rtol=2e-4)


def test_ssd_chunk_invariance():
    """The chunked algorithm must be exact: chunk size cannot change y."""
    b, s, h, p, g, n = 1, 128, 2, 16, 1, 8
    x = jax.random.normal(KEY, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, g, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, g, n)) * 0.5
    y32 = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=32)
    y128 = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=128)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# SBC kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,ratio,block", [(2048, 0.01, 256),
                                           (4096, 0.005, 512),
                                           (1000, 0.05, 128),
                                           (65536, 0.001, 8192)])
def test_sbc_pipeline_vs_oracle(n, ratio, block):
    g = jax.random.normal(KEY, (n,)) * jnp.linspace(0.1, 3.0, n)
    out = ops.sbc_compress(g, ratio, block=block, interpret=True)
    want = ref.sbc_ref(g, ratio)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


def test_sbc_stats_kernel():
    x = jnp.asarray([3.0, -4.0, 1.0, -0.5, 2.5, -2.5, 0.1, 0.0])
    thr = jnp.asarray([2.0])
    st = sbc_stats(x, thr, block=8, interpret=True)[0]
    assert float(st[0]) == pytest.approx(5.5)    # pos magnitudes 3 + 2.5
    assert float(st[1]) == pytest.approx(6.5)    # neg magnitudes 4 + 2.5
    assert float(st[2]) == 2 and float(st[3]) == 2


def test_sbc_apply_kernel():
    x = jnp.asarray([3.0, -4.0, 1.0, -0.5, 2.5, -2.5, 0.1, 0.0])
    scal = jnp.asarray([2.0, 0.0, -3.25])        # thr, vpos(drop), vneg
    out = sbc_apply(x, scal, block=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), [0, -3.25, 0, 0, 0, -3.25, 0, 0], atol=1e-6)


def test_sbc_edge_semantics():
    """Pinned edge behavior, oracle and kernel pipeline agreeing: all-zero
    input stays all-zero (thr=0 keeps everything, but neither sign group
    has members and the count clamp prevents 0/0), a k=1 tiny leaf keeps
    exactly its largest magnitude, and boundary ties all survive with the
    positive group winning the >= tie-break."""
    z = jnp.zeros(512)
    np.testing.assert_array_equal(np.asarray(ref.sbc_ref(z, 0.01)),
                                  np.zeros(512))
    np.testing.assert_array_equal(
        np.asarray(ops.sbc_compress(z, 0.01, block=128, interpret=True)),
        np.zeros(512))

    tiny = jnp.asarray([0.1, -5.0, 0.2])         # n*ratio < 1 → k = 1
    np.testing.assert_allclose(np.asarray(ref.sbc_ref(tiny, 0.01)),
                               [0.0, -5.0, 0.0], atol=1e-7)

    ties = jnp.asarray([2.0, -2.0, 2.0, -2.0, 1.0, -1.0, 0.5, 0.0])
    want = [2.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0]
    out = ref.sbc_ref(ties, 0.25)                # k=2, four tied at thr=2
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-7)
    out = ops.sbc_compress(ties, 0.25, block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(32, 2048), ratio=st.floats(0.005, 0.1),
       seed=st.integers(0, 50))
def test_sbc_kernel_composition_matches_oracle(n, ratio, seed):
    """Property: the two-kernel composition (``sbc_stats`` + ``sbc_apply``
    through ``ops.sbc_compress``) reproduces the ``sbc_tensor`` oracle in
    interpret mode across sizes, ratios, and draws — including sizes that
    need block padding."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=n) * np.linspace(0.1, 2.0, n),
                    jnp.float32)
    out = ops.sbc_compress(g, ratio, block=256, interpret=True)
    want = ref.sbc_ref(g, ratio)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)

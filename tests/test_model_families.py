"""Big-model FEEL workloads (PR 10): ``model_family`` as a structural grid
axis, the transformer / Mamba-2 train-step scan wired into the lowering,
kernel-vs-ref parity on the family shapes, and the SBC error-feedback
fixes (``TrainState.residual`` threading, ``sbc_uplink`` == oracle on CPU,
the windowed ``input_specs`` decode-cache contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Experiment, ScenarioSpec, grid
from repro.compression.sbc import compress_dense, sbc_uplink
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.fed import model_engine
from repro.fed.model_engine import KERNEL_RT, family_arch, tokenize
from repro.fed.train_step import (TrainState, input_specs, make_loss_fn,
                                  make_multi_train_step, make_train_step,
                                  zero_residual)
from repro.fed.engine import Schedule
from repro.kernels import ops, ref
from repro.models.mamba2 import ssd_reference
from repro.models.model import Runtime, forward
from repro.models.model import init as model_init
from repro.optim import sgd
from repro.testing import no_retrace

tree_map = jax.tree_util.tree_map

# distinctive shapes: no other module runs hidden=8 / b_max=12 / K=2
# model-family buckets, so the trace-count assertions below are exact
DIM, HIDDEN, DEPTH, BMAX = 12, 8, 2, 12
FAMILIES = ["feel_mlp", "transformer", "mamba2"]


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=120, dim=DIM, seed=0, spread=6.0)
    return full.split(40)


@pytest.fixture(scope="module")
def fleet():
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7, 1.4])


def _spec(fleet, **kw):
    kw.setdefault("name", "fam")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    kw.setdefault("depth", DEPTH)
    kw.setdefault("seeds", (0,))
    return ScenarioSpec(fleet=fleet, **kw)


# ---------------------------------------------------------------------------
# spec axis: structural bucketing + validation
# ---------------------------------------------------------------------------


def test_model_family_is_structural(dataset, fleet):
    """Each family compiles a different scan body, so the grid must split
    into one bucket per family — model_family lives in ``bucket_key``."""
    data, test = dataset
    study = grid(_spec(fleet), model_family=FAMILIES)
    buckets = Experiment(data, test, study).lower()
    assert len(buckets) == 3
    assert len({b.key for b in buckets}) == 3
    assert {b.key[-1] for b in buckets} == set(FAMILIES)


def test_model_family_validation(fleet):
    with pytest.raises(ValueError):
        _spec(fleet, model_family="rnn")
    with pytest.raises(ValueError):                  # big models are FEEL-only
        _spec(fleet, model_family="transformer", scheme="individual")
    with pytest.raises(ValueError):                  # no hierarchy yet
        from repro.topology import Topology
        _spec(fleet, model_family="mamba2",
              topology=Topology(cells=2, edges=2, agg_every=2))
    with pytest.raises(ValueError):                  # one period == one step
        _spec(fleet, model_family="transformer", local_steps=2)
    with pytest.raises(ValueError):                  # head-divisibility
        _spec(fleet, model_family="transformer", hidden=10)


# ---------------------------------------------------------------------------
# tentpole acceptance: the family grid end-to-end, audited
# ---------------------------------------------------------------------------


def test_family_grid_end_to_end_with_audit(dataset, fleet):
    """``grid(base, model_family=[...])`` through ``Experiment.run`` with
    ``audit=True``: one program per family bucket, taint/hygiene/trace
    passes certify all three program families, coordinates select."""
    data, test = dataset
    study = grid(_spec(fleet), model_family=FAMILIES)
    with no_retrace(expect=3):                       # one program per family
        res = Experiment(data, test, study).run(periods=2, audit=True)
    assert res.n_buckets == 3
    assert res.audit is not None and res.audit.ok
    for fam in FAMILIES:
        losses = np.asarray(res.sel(model_family=fam).losses)
        assert losses.shape[-1] == 2
        assert np.all(np.isfinite(losses))


def test_family_pricing_uses_true_param_count(fleet):
    """The planner prices big-model uplinks at the derived ArchConfig's
    parameter count, not the MLP formula."""
    from repro.api.lowering import _n_params
    for fam in ("transformer", "mamba2"):
        spec = _spec(fleet, model_family=fam)
        assert _n_params(spec, DIM) == family_arch(
            fam, HIDDEN, DEPTH).param_count()
    mlp = _spec(fleet)
    dims = [DIM] + [HIDDEN] * (DEPTH - 1) + [10]
    assert _n_params(mlp, DIM) == sum(
        i * o + o for i, o in zip(dims[:-1], dims[1:]))


# ---------------------------------------------------------------------------
# engine wiring: the bucket scan IS make_multi_train_step's trajectory
# ---------------------------------------------------------------------------


def test_engine_matches_multi_train_step(dataset):
    """A 1-user uncompressed bucket trajectory equals driving
    ``make_multi_train_step`` over the same gathered schedule batches."""
    data, test = dataset
    P, slot = 3, 4
    rng = np.random.default_rng(3)
    idx = rng.integers(0, len(data.y), (P, 1, slot)).astype(np.int32)
    sched = Schedule(idx=idx,
                     weight=np.ones((P, 1, slot), np.float32),
                     batch=np.full((P, 1), float(slot), np.float32),
                     lr=np.full(P, 0.1, np.float32),
                     times=np.zeros(P), global_batch=np.full(P, slot))

    keys = jnp.stack([jax.random.key(7)])
    params0 = model_engine.init_params_batch("transformer", HIDDEN, 1, keys)
    residual0 = tree_map(
        lambda p: jnp.zeros((p.shape[0], 1) + p.shape[1:], p.dtype), params0)
    params, _, (losses, _, decays) = model_engine.run_model_trajectory_batch(
        params0, residual0, [sched], data, test,
        model_family="transformer", hidden=HIDDEN, depth=1, compress=False)

    cfg = family_arch("transformer", HIDDEN, 1)
    tok, lab = tokenize(data)
    S = tok.shape[1]
    batches = {"tokens": tok[idx[:, 0]].astype(np.int32),
               "labels": lab[idx[:, 0]].astype(np.int32),
               "weights": np.ones((P, slot, S), np.float32)}
    opt = sgd()
    single = tree_map(lambda a: a[0], params0)
    state0 = TrainState(single, opt.init(single), jnp.zeros((), jnp.int32))
    many = make_multi_train_step(cfg, KERNEL_RT, opt)
    final, metrics = many(state0, batches, jnp.full(P, 0.1, jnp.float32))

    for got, want in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(final.params)):
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)
    # the engine emits loss AFTER the update; before == after + decay
    np.testing.assert_allclose(np.asarray(losses[0] + decays[0]),
                               np.asarray(metrics["loss"]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# kernel-vs-ref parity on the family shapes
# ---------------------------------------------------------------------------


def test_transformer_forward_kernel_path_matches_naive(dataset):
    """The engine runtime (attn_impl="pallas", ref-dispatched off-TPU)
    agrees with the naive jnp attention on the family's exact shapes."""
    data, _ = dataset
    tok, _ = tokenize(data)
    tok = jnp.asarray(tok[:4], jnp.int32)
    cfg = family_arch("transformer", HIDDEN, DEPTH)
    params = model_init(cfg, jax.random.key(0))
    got, _ = forward(cfg, params, tok, rt=KERNEL_RT)
    want, _ = forward(cfg, params, tok,
                      rt=Runtime(dtype=jnp.float32, attn_impl="naive"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_mamba2_ssd_kernel_matches_reference_on_family_shapes():
    """interpret-mode ``ssd_scan`` vs ``ssd_reference`` at the exact
    (H, P, G, N, chunk) the mamba2 family derives from the spec."""
    cfg = family_arch("mamba2", HIDDEN, DEPTH)
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    B, S = 2, 12
    key = jax.random.key(1)
    x = jax.random.normal(key, (B, S, H, s.head_dim))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3),
                           (B, S, s.n_groups, s.d_state)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4),
                           (B, S, s.n_groups, s.d_state)) * 0.5
    got = ops.ssd(x, dt, A, Bm, Cm, chunk=s.chunk, interpret=True)
    want, _ = ssd_reference(x, dt, A, Bm, Cm, s.chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-4, rtol=3e-4)


def test_mamba2_forward_routes_through_ops(dataset, monkeypatch):
    """``mamba2_forward`` reaches its SSD scan via ``kernels.ops.ssd`` —
    the backend dispatch point — not by calling the reference directly."""
    data, _ = dataset
    tok, _ = tokenize(data)
    tok = jnp.asarray(tok[:2], jnp.int32)
    cfg = family_arch("mamba2", HIDDEN, 1)
    params = model_init(cfg, jax.random.key(0))
    calls = []
    real = ops.ssd

    def spy(*a, **kw):
        calls.append(kw.get("chunk"))
        return real(*a, **kw)

    monkeypatch.setattr(ops, "ssd", spy)
    forward(cfg, params, tok, rt=KERNEL_RT)
    assert calls == [cfg.ssm.chunk]


# ---------------------------------------------------------------------------
# SBC error feedback (satellite 1) + uplink dispatch
# ---------------------------------------------------------------------------


def test_sbc_uplink_is_compress_dense_on_cpu():
    """Off-TPU the dispatching entry point IS the oracle — bitwise, which
    is what makes the engine path and ``compress_dense`` interchangeable
    in CPU CI."""
    if jax.default_backend() == "tpu":
        pytest.skip("CPU dispatch contract")
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(64, 8)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    res = tree_map(lambda g: g * 0.25, grads)
    got_g, got_r = sbc_uplink(grads, 0.02, res)
    want_g, want_r = compress_dense(grads, 0.02, res)
    for a, b in zip(jax.tree_util.tree_leaves((got_g, got_r)),
                    jax.tree_util.tree_leaves((want_g, want_r))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_error_feedback_matches_compress_dense_loop(dataset):
    """``make_train_step(compress_uplink=True)`` threads the residual
    through ``TrainState`` exactly like a hand-rolled ``compress_dense``
    error-feedback loop (the convergence-preserving contract), and the
    scanned ``make_multi_train_step`` reproduces the same trajectory."""
    data, _ = dataset
    tok, lab = tokenize(data)
    S = tok.shape[1]
    batch = {"tokens": jnp.asarray(tok[:4], jnp.int32),
             "labels": jnp.asarray(lab[:4], jnp.int32),
             "weights": jnp.ones((4, S), jnp.float32)}
    cfg = family_arch("transformer", HIDDEN, 1)
    opt = sgd()
    params = model_init(cfg, jax.random.key(2))
    steps, ratio, lr = 4, 0.02, 0.1

    step = make_train_step(cfg, KERNEL_RT, opt, compress_uplink=True,
                           compress_ratio=ratio)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    for _ in range(steps):
        state, metrics = step(state, batch, lr)
    assert state.residual is not None

    loss_fn = make_loss_fn(cfg, KERNEL_RT)
    p_manual, res = params, zero_residual(params)
    for _ in range(steps):
        grads = jax.grad(lambda p: loss_fn(p, batch)[0])(p_manual)
        approx, res = compress_dense(grads, ratio, res)
        p_manual = tree_map(lambda p, g: p - lr * g, p_manual, approx)

    for got, want in zip(jax.tree_util.tree_leaves(state.params),
                         jax.tree_util.tree_leaves(p_manual)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-7, rtol=1e-7)
    for got, want in zip(jax.tree_util.tree_leaves(state.residual),
                         jax.tree_util.tree_leaves(res)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-7, rtol=1e-7)
    # sparsification dropped mass somewhere → the residual is live
    assert any(float(jnp.abs(r).max()) > 0
               for r in jax.tree_util.tree_leaves(state.residual))

    # the scan materializes the residual from None and matches step-by-step
    many = make_multi_train_step(cfg, KERNEL_RT, opt, compress_uplink=True,
                                 compress_ratio=ratio)
    stacked = tree_map(lambda a: jnp.broadcast_to(a, (steps,) + a.shape),
                       batch)
    state0 = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    final, _ = many(state0, stacked, jnp.full(steps, lr, jnp.float32))
    for got, want in zip(jax.tree_util.tree_leaves(final.params),
                         jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# windowed input_specs decode cache (satellite 2)
# ---------------------------------------------------------------------------


def _decode_shape(seq_len):
    return ShapeConfig("d", seq_len=seq_len, global_batch=2, mode="decode")


def test_input_specs_windowed_decode_cache_allocation():
    """The decode-cache spec allocates min(seq_len, window) context — the
    documented ``init_cache`` contract — so sliding-window archs price the
    ring buffer, not the full sequence."""
    base = dict(name="w", family="dense", n_layers=2, d_model=16, n_heads=4,
                n_kv_heads=2, d_ff=32, vocab=64)
    rt = Runtime(dtype=jnp.float32)
    windowed = ArchConfig(attn_window=8, **base)
    cache = input_specs(windowed, _decode_shape(32), rt)["cache"]
    assert cache["k"].shape[2] == 8 == cache["v"].shape[2]
    # short sequences never over-allocate past seq_len
    cache = input_specs(windowed, _decode_shape(4), rt)["cache"]
    assert cache["k"].shape[2] == 4
    # no window → full context; runtime override wins over the arch
    cache = input_specs(ArchConfig(**base), _decode_shape(32), rt)["cache"]
    assert cache["k"].shape[2] == 32
    cache = input_specs(windowed, _decode_shape(32),
                        Runtime(dtype=jnp.float32, window=4))["cache"]
    assert cache["k"].shape[2] == 4

"""Per-architecture smoke tests (reduced configs, CPU) + decode/forward
consistency: every assigned family must produce correct shapes, no NaNs,
and an autoregressive decode path identical to the full-sequence forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import init, forward, init_cache, decode_step
from repro.models.layers import padded_vocab
from repro.models.model import Runtime


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


def make_inputs(cfg, B, S, key):
    if cfg.n_codebooks > 1:
        toks = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    prefix = None
    if cfg.vlm_prefix:
        prefix = jnp.full((B, cfg.vlm_prefix, cfg.d_model), 0.01,
                          jnp.float32)
    return toks, prefix


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch, key):
    """One forward + one SGD step on the reduced config: shapes + no NaNs."""
    cfg = ARCHS[arch].reduced()
    params = init(cfg, key)
    B, S = 2, 32
    toks, prefix = make_inputs(cfg, B, S, key)
    logits, aux = forward(cfg, params, toks, prefix_embeds=prefix)
    pv = padded_vocab(cfg.vocab)
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, S, cfg.n_codebooks, pv)
    else:
        assert logits.shape == (B, S, pv)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)

    # one gradient step through the full model
    def loss(p):
        lg, a = forward(cfg, p, toks, prefix_embeds=prefix)
        lab = toks % cfg.vocab
        lp = jax.nn.log_softmax(lg[..., :cfg.vocab].astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(lp, lab[..., None], -1)) + a

    l0, grads = jax.value_and_grad(loss)(params)
    assert jnp.isfinite(l0)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in
                jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0
    new = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g, params, grads)
    l1 = loss(new)
    assert jnp.isfinite(l1)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode(arch, key):
    cfg = ARCHS[arch].reduced()
    params = init(cfg, key)
    B = 2
    cache = init_cache(cfg, B, 16)
    toks, _ = make_inputs(cfg, B, 1, key)
    logits, cache2 = decode_step(cfg, params, cache, toks)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert not jnp.isnan(logits).any()
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["granite-34b", "minicpm3-4b", "mamba2-2.7b",
                                  "zamba2-7b", "musicgen-large",
                                  "deepseek-v2-lite-16b"])
def test_decode_matches_forward(arch, key):
    """Autoregressive decode must reproduce the full-sequence forward."""
    cfg = ARCHS[arch].reduced()
    params = init(cfg, key)
    B, S = 2, 12
    toks, _ = make_inputs(cfg, B, S, key)
    rt = Runtime(capacity_factor=64.0)      # drop-free MoE for the check
    full, _ = forward(cfg, params, toks, rt=rt)
    cache = init_cache(cfg, B, S, rt)
    outs = []
    for t in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1], rt=rt)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    fv = jax.nn.log_softmax(full[..., :cfg.vocab].astype(jnp.float32))
    dv = jax.nn.log_softmax(dec[..., :cfg.vocab].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(fv - dv))) < 2e-3


def test_sliding_window_matches_masked_forward(key):
    """SWA forward == naive attention with a window mask."""
    import dataclasses
    cfg = dataclasses.replace(ARCHS["granite-34b"].reduced(), attn_window=8)
    params = init(cfg, key)
    toks = jax.random.randint(key, (1, 32), 0, cfg.vocab)
    lg_win, _ = forward(cfg, params, toks)
    # decode with ring buffer of size=window must agree
    cache = init_cache(cfg, 1, 32)          # kv_ctx = min(32, window=8)
    assert cache["k"].shape[2] == 8
    outs = []
    for t in range(32):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    fv = jax.nn.log_softmax(lg_win[..., :cfg.vocab].astype(jnp.float32))
    dv = jax.nn.log_softmax(dec[..., :cfg.vocab].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(fv - dv))) < 2e-3


def test_vlm_prefix_changes_output(key):
    cfg = ARCHS["llava-next-mistral-7b"].reduced()
    params = init(cfg, key)
    toks = jax.random.randint(key, (1, 40), 0, cfg.vocab)
    p1 = jnp.full((1, cfg.vlm_prefix, cfg.d_model), 0.01)
    p2 = -p1
    l1, _ = forward(cfg, params, toks, prefix_embeds=p1)
    l2, _ = forward(cfg, params, toks, prefix_embeds=p2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_musicgen_codebook_shapes(key):
    cfg = ARCHS["musicgen-large"].reduced()
    assert cfg.n_codebooks == 4
    params = init(cfg, key)
    toks = jax.random.randint(key, (2, 16, 4), 0, cfg.vocab)
    lg, _ = forward(cfg, params, toks)
    assert lg.shape == (2, 16, 4, padded_vocab(cfg.vocab))


def test_param_counts_full_configs():
    """Full-config parameter counts are in the right ballpark."""
    expect = {
        "granite-34b": (30e9, 40e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "qwen1.5-4b": (3e9, 5e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "arctic-480b": (380e9, 520e9),
        "minicpm3-4b": (3.5e9, 5e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_below_total():
    cfg = ARCHS["arctic-480b"]
    assert cfg.active_param_count() < 0.2 * cfg.param_count()

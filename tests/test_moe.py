"""MoE layer semantics: drop-free dispatch equals the dense oracle,
capacity drops are bounded, router aux-loss behaves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import ffn
from repro.models.moe import capacity, moe_forward, moe_init

KEY = jax.random.key(7)


def small_cfg(n_experts=4, top_k=2, n_shared=0, dense_residual=False):
    return ArchConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab=64,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                      n_shared=n_shared, dense_residual=dense_residual))


def dense_oracle(params, cfg, x):
    """Compute ALL experts on all tokens, combine with normalized top-k
    gates — the exact semantics dispatch must reproduce when nothing is
    dropped."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, m.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ex = params["experts"]
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xt, ex["w_gate"]))
    u = jnp.einsum("td,edf->tef", xt, ex["w_up"])
    all_out = jnp.einsum("tef,efd->ted", g * u, ex["w_down"])
    mask = jax.nn.one_hot(gi, m.n_experts, dtype=jnp.float32)  # (t,k,e)
    w = jnp.einsum("tk,tke->te", gv, mask)
    y = jnp.einsum("te,ted->td", w, all_out)
    if m.n_shared:
        y = y + ffn(params["shared"], xt)
    if m.dense_residual:
        y = y + ffn(params["dense"], xt)
    return y.reshape(B, S, d)


@pytest.mark.parametrize("n_shared,dense_residual", [(0, False), (1, False),
                                                     (0, True), (2, True)])
def test_dispatch_matches_dense_oracle(n_shared, dense_residual):
    cfg = small_cfg(n_shared=n_shared, dense_residual=dense_residual)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8, 32))
    y, aux = moe_forward(params, cfg, x, cap=16 * cfg.moe.top_k)
    want = dense_oracle(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5,
                               rtol=1e-4)
    assert float(aux) >= 0


def test_capacity_drops_are_partial_not_catastrophic():
    cfg = small_cfg(n_experts=8, top_k=2)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (4, 16, 32))
    y_small, _ = moe_forward(params, cfg, x, capacity_factor=0.5)
    y_full, _ = moe_forward(params, cfg, x, cap=64 * 2)
    # dropped tokens -> some rows differ, but output stays finite
    assert np.isfinite(np.asarray(y_small)).all()
    assert float(jnp.max(jnp.abs(y_small))) > 0


def test_capacity_formula():
    cfg = small_cfg(n_experts=8, top_k=2)
    c = capacity(1024, cfg, 1.25)
    assert c >= 1024 * 2 * 1.25 / 8 - 4
    assert c % 4 == 0


def test_aux_loss_prefers_balance():
    """Uniform routing must score a lower aux loss than collapsed routing."""
    cfg = small_cfg(n_experts=4, top_k=1)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 32, 32))

    # collapse the router to one expert
    bad = dict(params)
    bad["router"] = params["router"] * 0 + \
        jnp.asarray([10.0, 0, 0, 0])[None, :]
    _, aux_bad = moe_forward(bad, cfg, x, cap=64)
    _, aux_any = moe_forward(params, cfg, x, cap=64)
    assert float(aux_bad) > float(aux_any)


def test_expert_choice_impl():
    """EC routing: drop-free per-expert top-C; finite, grads flow, and
    every expert processes exactly C tokens."""
    cfg = small_cfg(n_experts=4, top_k=2)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 16, 32))
    y, aux = moe_forward(params, cfg, x, impl="expert_choice")
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.abs(y).max()) > 0

    def loss(p):
        yy, a = moe_forward(p, cfg, x, impl="expert_choice")
        return jnp.sum(jnp.square(yy)) + a

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["experts"]["w_up"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_gradients_flow_through_dispatch():
    cfg = small_cfg()
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 8, 32))

    def loss(p):
        y, aux = moe_forward(p, cfg, x, cap=8 * 2)
        return jnp.sum(jnp.square(y)) + aux

    g = jax.grad(loss)(params)
    gr = np.asarray(jnp.abs(g["router"]).sum())
    ge = np.asarray(jnp.abs(g["experts"]["w_gate"]).sum())
    assert gr > 0 and ge > 0

"""Experiment-as-a-service: streaming admissions, the online bucketer,
the persistent compile-cache index (warm admissions ⇒ zero new
TraceEvents), chunk-granular preemption with bit-identical resume, and
the deterministic clock/arrival fixtures the serving tests run on."""
import numpy as np
import pytest

from repro.api import Experiment, ScenarioSpec, SerialExecutor, lowering
from repro.core import DeviceProfile
from repro.data.pipeline import ClassificationData
from repro.serve import (AdmissionQueue, ExperimentService, PendingRequest,
                         ProgramCache)
from repro.testing import (VirtualClock, WallClock, assign_templates,
                           burst_arrivals, no_retrace, poisson_arrivals)

# distinctive shapes (no other module uses dim=26/hidden=32/b_max=14) so
# engine program caches never collide across test modules
DIM, HIDDEN, BMAX = 26, 32, 14
PERIODS = 4
CHUNK = 2


@pytest.fixture(scope="module")
def dataset():
    full = ClassificationData.synthetic(n=320, dim=DIM, seed=0, spread=6.0)
    return full.split(64)


@pytest.fixture(scope="module")
def fleet():
    return tuple(DeviceProfile(kind="cpu", f_cpu=f * 1e9)
                 for f in [0.7, 1.4, 2.1])


def _spec(fleet, **kw):
    kw.setdefault("name", "srv3")
    kw.setdefault("b_max", BMAX)
    kw.setdefault("base_lr", 0.15)
    kw.setdefault("hidden", HIDDEN)
    return ScenarioSpec(fleet=fleet, **kw)


def _service(data, test, **kw):
    """A deterministic service: virtual clock + isolated cache index, so
    every test's hit/miss counters start from zero."""
    kw.setdefault("chunk_periods", CHUNK)
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("cache", ProgramCache(shared=False))
    return ExperimentService(data, test, **kw)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.losses),
                                  np.asarray(b.losses))
    np.testing.assert_array_equal(np.asarray(a.accs), np.asarray(b.accs))
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.global_batch, b.global_batch)


# ---------------------------------------------------------------------------
# deterministic fixtures: clocks + seeded arrival processes
# ---------------------------------------------------------------------------


def test_virtual_clock_and_arrival_fixtures():
    t1 = poisson_arrivals(4.0, 20, seed=3, start=0.5)
    np.testing.assert_array_equal(t1, poisson_arrivals(4.0, 20, seed=3,
                                                       start=0.5))
    assert not np.array_equal(t1, poisson_arrivals(4.0, 20, seed=4,
                                                   start=0.5))
    assert len(t1) == 20 and t1[0] > 0.5 and np.all(np.diff(t1) > 0)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(0.0, 5, seed=0)

    b = burst_arrivals(bursts=3, size=4, spacing=2.0, intra=0.01, seed=1)
    assert len(b) == 12 and np.all(np.diff(b) >= 0)
    assert b[4] - b[3] > 1.0                  # inter-burst gap dominates
    np.testing.assert_array_equal(
        b, burst_arrivals(bursts=3, size=4, spacing=2.0, intra=0.01,
                          seed=1))

    tape = assign_templates(np.array([0.1, 0.2, 0.3]), ["x", "y"])
    assert [t for _, t in tape] == ["x", "y", "x"]     # round-robin

    clk = VirtualClock(start=1.0)
    assert clk.advance(0.5) == 1.5
    assert clk.advance_to(1.2) == 1.5         # never moves backwards
    assert clk.advance_to(3.0) == 3.0
    with pytest.raises(ValueError, match="negative"):
        clk.advance(-0.1)
    assert WallClock().now() >= 0.0


# ---------------------------------------------------------------------------
# the online bucketer (pure host logic — no device work)
# ---------------------------------------------------------------------------


def _req(spec, periods, t, seq, priority=0, deadline=None):
    return PendingRequest(ticket=None, spec=spec, periods=periods,
                          priority=priority, submitted_at=t, seq=seq,
                          deadline=deadline)


def test_admission_queue_windows_merge_and_slice(fleet):
    a = _spec(fleet, partition="iid", seeds=(0,))
    b = _spec(fleet, partition="noniid", base_lr=0.3, seeds=(1,))
    c = _spec(fleet, b_max=BMAX - 4, seeds=(0,))
    q = AdmissionQueue(window=1.0)
    q.push(_req(a, 4, 0.0, 0))
    q.push(_req(b, 4, 0.2, 1))                # non-structural diffs merge
    q.push(_req(c, 4, 0.1, 2))                # b_max splits
    q.push(_req(a, 6, 0.3, 3))                # horizon splits
    assert q.pending == 4
    assert q.pop_due(0.5) == []               # everyone inside the window
    assert q.next_due_at() == 1.0
    assert [[r.seq for r in g] for g in q.pop_due(1.05)] == [[0, 1]]
    assert [[r.seq for r in g] for g in q.pop_due(5.0)] == [[2], [3]]
    assert q.pending == 0 and q.next_due_at() is None

    # max_batch bounds the micro-batch SIZE: an oversize group slices
    # into full batches; the remainder keeps waiting for its window
    q = AdmissionQueue(window=10.0, max_batch=2)
    for s in range(5):
        q.push(_req(a, 4, float(s), s))
    assert [[r.seq for r in g]
            for g in q.pop_due(4.5)] == [[0, 1], [2, 3]]
    assert q.pending == 1
    assert q.pop_due(4.6) == []               # remainder not window-due
    assert [[r.seq for r in g]
            for g in q.pop_due(0.0, flush=True)] == [[4]]

    with pytest.raises(ValueError, match="window"):
        AdmissionQueue(window=-0.5)
    with pytest.raises(ValueError, match="max_batch"):
        AdmissionQueue(max_batch=0)


def test_admission_deadline_slack_ordering(fleet):
    """Due micro-batches admit tightest-slack first; a group's slack is
    its most urgent member's; deadline-less groups keep FIFO among
    themselves (infinite slack, seq tiebreak)."""
    a = _spec(fleet, seeds=(0,))
    b = _spec(fleet, b_max=BMAX - 4, seeds=(0,))
    c = _spec(fleet, b_max=BMAX - 6, seeds=(0,))
    q = AdmissionQueue(window=0.0)
    q.push(_req(a, 4, 0.0, 0))                      # no deadline (FIFO)
    q.push(_req(b, 4, 0.1, 1, deadline=5.0))
    q.push(_req(c, 4, 0.2, 2, deadline=2.0))        # tightest → first
    assert [[r.seq for r in g] for g in q.pop_due(1.0)] == [[2], [1], [0]]

    # a group inherits its most urgent member's slack: the late urgent
    # arrival drags its whole (compatible) micro-batch up the order
    q = AdmissionQueue(window=1.0)
    q.push(_req(a, 4, 0.0, 0))
    q.push(_req(b, 4, 0.0, 1))
    q.push(_req(a, 4, 0.5, 2, deadline=1.5))        # merges with seq 0
    assert [[r.seq for r in g]
            for g in q.pop_due(1.1)] == [[0, 2], [1]]

    # no deadlines anywhere: order is bit-for-bit the old FIFO
    q = AdmissionQueue(window=0.0)
    q.push(_req(b, 4, 0.0, 0))
    q.push(_req(a, 4, 0.1, 1))
    assert [[r.seq for r in g] for g in q.pop_due(1.0)] == [[0], [1]]
    assert PendingRequest(ticket=None, spec=a, periods=4, priority=0,
                          submitted_at=0.0, seq=0).slack(99.0) == \
        float("inf")


def test_program_keys_and_chunk_lengths(dataset, fleet):
    assert lowering.chunk_lengths(7, 3) == (3, 3, 1)
    assert lowering.chunk_lengths(4, None) == (4,)
    assert lowering.chunk_lengths(4, 9) == (4,)
    data, test = dataset
    b = lowering.group_rows([_spec(fleet, seeds=(0, 1))])[0]
    keys = lowering.bucket_program_keys(b, 2, 7, 3, data, test)
    assert len(keys) == 2                     # distinct chunk lengths 3, 1
    keys44 = lowering.bucket_program_keys(b, 2, 4, 2, data, test)
    assert len(keys44) == 1
    # structural twins share program keys; row counts split them
    b2 = lowering.group_rows([_spec(fleet, partition="iid", base_lr=0.3,
                                    seeds=(5, 6))])[0]
    assert lowering.bucket_program_keys(b2, 2, 4, 2, data, test) == keys44
    assert lowering.bucket_program_keys(b, 3, 4, 2, data, test) != keys44


def test_program_cache_index_scopes():
    ProgramCache.clear_shared()
    k1, k2 = ("tsrv-fake", 1), ("tsrv-fake", 2)
    a, b = ProgramCache(), ProgramCache()
    assert a.admit([k1, k2]) == (0, 2)
    assert b.admit([k1]) == (1, 0)            # process-shared registry
    assert b.use_count(k1) == 2 and k2 in b and len(b) == 2
    iso = ProgramCache(shared=False)
    assert iso.admit([k1]) == (0, 1)          # isolated index
    assert len(iso) == 1 and a.use_count(k1) == 2
    ProgramCache.clear_shared()
    assert a.admit([k1]) == (0, 1)
    ProgramCache.clear_shared()


# ---------------------------------------------------------------------------
# the service: streaming, warm admissions, preemption, fan-out
# ---------------------------------------------------------------------------


def test_service_streams_chunks_bit_identical_to_experiment(dataset,
                                                            fleet):
    """A submitted request streams in CHUNK-period increments and its
    final Results are bit-identical (ledgers AND device series) to the
    static Experiment running the same spec chunked."""
    data, test = dataset
    spec = _spec(fleet, partition="noniid", seeds=(0, 1))
    svc = _service(data, test)
    t = svc.submit(spec, periods=PERIODS)
    assert not t.admitted and not t.done
    with pytest.raises(RuntimeError, match="not complete"):
        t.result()
    growth = []
    while not t.done:
        assert svc.step()                     # work available every turn
        part = t.partial()
        assert part.complete == t.done
        growth.append(part.losses.shape[1])
        if not t.done:                        # valid-but-absent selects
            assert part.sel(scheme="individual").rows == 0    # empty
    assert growth == [CHUNK, PERIODS]         # one chunk per step
    assert t.admitted and svc.idle
    assert svc.stats.admissions == 1 and svc.stats.completed == 1
    assert svc.stats.chunks == PERIODS // CHUNK

    twin = Experiment(data, test, [spec]).run(
        PERIODS, executor=SerialExecutor(chunk_periods=CHUNK))
    res = t.result()
    assert res.complete and res.rows == 2
    _assert_bitwise(res, twin)
    with pytest.raises(ValueError, match="matches no row"):
        res.sel(scheme="no-such-scheme")


def test_warm_admission_records_zero_traces(dataset, fleet):
    """The compile-cache contract: an admission whose every program key
    was dispatched before is warm — it must add ZERO new TraceEvents to
    the engine ledger, and the stats must say so."""
    data, test = dataset
    svc = _service(data, test)
    t0 = svc.submit(_spec(fleet, partition="noniid", seeds=(0, 1)),
                    periods=PERIODS)
    svc.drain()
    assert t0.done
    assert svc.stats.cold_admissions == 1 and svc.stats.cache_misses == 1

    # structurally identical, every non-structural knob different
    warm_spec = _spec(fleet, name="w2", partition="iid", base_lr=0.05,
                      seeds=(5, 6))
    with no_retrace():
        t1 = svc.submit(warm_spec, periods=PERIODS)
        svc.drain()
    assert t1.done
    assert svc.stats.warm_admissions == 1 and svc.stats.cache_hits == 1
    assert svc.stats.warm_admission_traces == 0
    assert t1.result().rows == 2


def test_preempt_park_resume_bit_identity(dataset, fleet):
    """Chunk-granular preemption: a hot arrival takes the slot from a
    long-horizon run at its chunk boundary; the parked run resumes and
    finishes bit-identical (ledgers AND device series) to its
    uninterrupted Experiment twin."""
    data, test = dataset
    svc = _service(data, test)
    long_spec = _spec(fleet, partition="iid", seeds=(0,))
    t_long = svc.submit(long_spec, periods=6, priority=5)
    assert svc.step()                         # admit + run first chunk
    assert t_long.collected == CHUNK and not t_long.done

    hot_spec = _spec(fleet, partition="noniid", base_lr=0.2, seeds=(1,))
    t_hot = svc.submit(hot_spec, periods=PERIODS, priority=0)
    svc.drain()
    assert t_long.done and t_hot.done
    assert svc.stats.preemptions == 1 and svc.stats.resumes == 1
    # the hot run's program shape matches the long run's chunk shape, so
    # the preempting admission itself was cache-warm
    assert svc.stats.warm_admissions == 1
    assert svc.stats.warm_admission_traces == 0

    _assert_bitwise(t_long.result(),
                    Experiment(data, test, [long_spec]).run(
                        6, executor=SerialExecutor(chunk_periods=CHUNK)))
    _assert_bitwise(t_hot.result(),
                    Experiment(data, test, [hot_spec]).run(
                        PERIODS, executor=SerialExecutor(
                            chunk_periods=CHUNK)))


def test_out_of_order_completion_partial_views(dataset, fleet):
    """A hotter later submission finishes first; the still-running
    earlier ticket exposes a complete=False partial whose sel() is a
    working (and forgiving) selection surface the whole time."""
    data, test = dataset
    svc = _service(data, test)
    slow = _spec(fleet, partition="iid", seeds=(0,))
    fast = _spec(fleet, scheme="individual", seeds=(0,))
    t_slow = svc.submit(slow, periods=6, priority=1)
    t_fast = svc.submit(fast, periods=PERIODS, priority=0)
    while not t_fast.done:
        svc.step()
    assert not t_slow.done                    # earlier ticket still going
    part = t_slow.partial()
    assert not part.complete
    assert part.sel(scheme="individual").rows == 0    # empty, no raise
    assert part.sel(partition="iid").rows == 1
    assert t_fast.result().sel(scheme="individual").rows == 1
    svc.drain()
    assert t_slow.done
    _assert_bitwise(t_slow.result(),
                    Experiment(data, test, [slow]).run(
                        6, executor=SerialExecutor(chunk_periods=CHUNK)))


def test_window_batches_duplicates_onto_shared_rows(dataset, fleet):
    """Two compatible requests inside the batching window admit as ONE
    micro-batch; duplicate (spec, seed) pairs share computed rows and
    both tickets receive the (identical) results."""
    data, test = dataset
    clock = VirtualClock()
    svc = _service(data, test, window=1.0, clock=clock)
    spec = _spec(fleet, partition="noniid", seeds=(0, 1))
    t1 = svc.submit(spec, periods=PERIODS)
    t2 = svc.submit(spec, periods=PERIODS)
    assert not svc.step()                     # window holds both back
    assert not t1.admitted
    assert svc.next_admission_at() == 1.0
    clock.advance_to(1.0)
    assert svc.step()                         # window expired: one batch
    assert t1.admitted and t2.admitted
    svc.drain()
    assert svc.stats.admissions == 1 and svc.stats.admitted_requests == 2
    _assert_bitwise(t1.result(), t2.result())


def test_closed_loop_replan_through_service(dataset, fleet):
    """A replan= spec chunks at its replan interval inside the service
    (overriding chunk_periods) and matches the static closed-loop run."""
    data, test = dataset
    spec = _spec(fleet, partition="iid", replan=2, seeds=(0,))
    svc = _service(data, test, chunk_periods=3)   # replan must win
    t = svc.submit(spec, periods=PERIODS)
    svc.drain()
    assert t.done and t.collected == PERIODS
    _assert_bitwise(t.result(),
                    Experiment(data, test, [spec]).run(PERIODS))


def test_audit_runs_on_cold_admissions_only(dataset, fleet):
    """audit=True runs the PR-6 static passes over each cold admission's
    program before dispatch; warm admissions skip the probe."""
    data, test = dataset
    svc = _service(data, test, audit=True)
    t = svc.submit(_spec(fleet, partition="noniid", seeds=(0, 1)),
                   periods=PERIODS)
    svc.drain()
    assert t.done
    report = svc.audit_report
    assert report is not None and report.ok and not report.errors()
    n_findings = len(report.findings)
    svc.submit(_spec(fleet, partition="iid", seeds=(2, 3)),
               periods=PERIODS)
    svc.drain()
    assert len(svc.audit_report.findings) == n_findings   # warm: no probe


def test_submit_and_construction_validation(dataset, fleet):
    data, test = dataset
    svc = _service(data, test)
    with pytest.raises(TypeError, match="ScenarioSpec"):
        svc.submit("not-a-spec", periods=3)
    with pytest.raises(ValueError, match="periods"):
        svc.submit(_spec(fleet), periods=0)
    with pytest.raises(ValueError, match="adapt_tau"):
        from repro.dynamics import TauAdapt
        svc.submit(_spec(fleet, replan=2,
                         adapt_tau=TauAdapt(choices=(1, 2))), periods=3)
    with pytest.raises(ValueError, match="chunk_periods"):
        _service(data, test, chunk_periods=0)
    with pytest.raises(ValueError, match="window"):
        _service(data, test, window=-0.1)
    with pytest.raises(ValueError, match="max_batch"):
        _service(data, test, max_batch=0)

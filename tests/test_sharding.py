"""Sharding-rule unit tests (1-device mesh: rules must emit valid specs
for every param/cache leaf of every assigned architecture)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, ASSIGNED
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh, data_axes
from repro.models.model import Runtime, cache_spec, param_spec


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def test_data_axes(mesh):
    assert data_axes(mesh) == ("data",)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_rules_cover_all_leaves(arch, mesh):
    cfg = ARCHS[arch].reduced()
    spec = param_spec(cfg)
    sh = shd.params_shardings(mesh, spec)
    for (path, leaf), (_, s) in zip(
            jax.tree_util.tree_leaves_with_path(spec),
            jax.tree_util.tree_leaves_with_path(sh)):
        parts = tuple(s.spec)
        assert len(parts) <= len(leaf.shape), (path, parts, leaf.shape)
        # any sharded dim must exist and (on this 1-dev mesh) divide
        for d, ax in enumerate(parts):
            if ax is not None:
                assert leaf.shape[d] >= 1


def test_projection_rules_hit_expected_dims(mesh):
    cfg = ARCHS["qwen1.5-4b"]
    # wq: (L, d, H*hd) -> shard last
    assert shd.param_spec_for("['layers']['attn']['wq']",
                              (40, 2560, 2560), mesh) == P(None, None, "model")
    # wo: (L, H*hd, d) -> shard -2
    assert shd.param_spec_for("['layers']['attn']['wo']",
                              (40, 2560, 2560), mesh) == P(None, "model", None)
    # experts w_gate: (L, E, d, f) -> shard E
    assert shd.param_spec_for("['layers']['moe']['experts']['w_gate']",
                              (35, 128, 7168, 4864), mesh) == \
        P(None, "model", None, None)
    # norms replicate
    assert shd.param_spec_for("['layers']['ln1']['scale']",
                              (40, 2560), mesh) == P()
    # embed table: (pv, d) -> shard vocab
    assert shd.param_spec_for("['embed']['table']",
                              (151936, 2560), mesh) == P("model", None)


def test_cache_shardings_ctx_dim(mesh):
    cfg = ARCHS["qwen1.5-4b"].reduced()
    spec = cache_spec(cfg, 4, 64, Runtime())
    sh = shd.cache_shardings(mesh, spec)
    assert tuple(sh["k"].spec)[2] == "model"     # ctx (flash-decode style)
    assert tuple(sh["pos"].spec) == ()


def test_zero1_shards_opt_only(mesh):
    from repro.fed.train_step import TrainState
    from repro.optim import momentum
    cfg = ARCHS["qwen1.5-4b"].reduced()
    pspec = param_spec(cfg)
    opt = momentum()
    st = jax.eval_shape(lambda: TrainState(pspec, opt.init(pspec),
                                           jnp.zeros((), jnp.int32)))
    sh = shd.state_shardings_zero1(mesh, st)
    # params unchanged vs base rules; opt leaves gain a 'data' axis
    base = shd.state_shardings(mesh, st)
    n_extra = 0
    for (p1, a), (p2, b) in zip(jax.tree_util.tree_leaves_with_path(sh),
                                jax.tree_util.tree_leaves_with_path(base)):
        ka = jax.tree_util.keystr(p1)
        if ka.startswith("[<flat index 1>]"):
            if tuple(a.spec) != tuple(b.spec):
                n_extra += 1
                assert any(ax == "data" or (isinstance(ax, tuple)
                                            and "data" in ax)
                           for ax in a.spec if ax)
        else:
            assert tuple(a.spec) == tuple(b.spec), ka
    assert n_extra > 0


def test_logits_sharding_divisibility():
    # divisibility logic needs axes > 1: emulate a 16x16 mesh shape check
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    import repro.launch.sharding as S

    # batch 1 not divisible by 16 -> replicated; vocab 100 not divisible
    orig = S.NamedSharding
    S.NamedSharding = lambda mesh, spec: spec       # bypass device check
    try:
        s = S.logits_sharding(FakeMesh, 3, batch=1, vocab=100)
        assert tuple(s) == (None, None, None)
        s2 = S.logits_sharding(FakeMesh, 3, batch=32, vocab=128)
        assert tuple(s2)[0] == "data" and tuple(s2)[-1] == "model"
    finally:
        S.NamedSharding = orig

"""Solver tests: Theorem 1/2 structure, Corollary bounds, Algorithm 1
convergence, Lemma 2, optimality over baseline policies, and property
tests over random device fleets / channels / masks — running on real
``hypothesis`` when installed, or on ``repro.testing.proptest``'s
API-compatible fallback otherwise (never skipped)."""
import numpy as np
import pytest

from repro.testing.proptest import given, settings, strategies as st

from repro.core import (DeviceProfile, POLICIES, batch_closed_form,
                        e_up_bounds, gradient_bits, solve_downlink,
                        solve_period, solve_uplink, tau_closed_form)
from repro.core.latency import uplink_latency
from repro.core.solver import (FleetRows, optimize_batch_rows,
                               solve_period_rows)

FRAME = 0.010
S_BITS = gradient_bits(1_000_000)


def fleet_cpu(freqs):
    return [DeviceProfile(kind="cpu", f_cpu=f) for f in freqs]


def rates(k, lo=20e6, hi=200e6, seed=0):
    return np.random.default_rng(seed).uniform(lo, hi, size=k)


# ---------------------------------------------------------------------------
# deterministic structure tests
# ---------------------------------------------------------------------------


class TestTheorem1:
    def test_finish_time_equalization(self):
        """Remark 3: every device finishes local+upload at the same time."""
        devs = fleet_cpu([0.7e9, 1.4e9, 2.1e9, 1.0e9])
        r = rates(4)
        dl = 0.05 * np.sqrt(64)
        sol = solve_uplink(devs, r, S_BITS, FRAME, 64, dl, 128)
        t_local = np.array([d.local_grad_latency(b)
                            for d, b in zip(devs, sol.batch)])
        t_up = uplink_latency(S_BITS, sol.tau, FRAME, r)
        finish = t_local + t_up
        assert finish.std() / finish.mean() < 1e-6

    def test_batch_scales_with_speed(self):
        """Remark 2: batchsize increases with local training speed."""
        devs = fleet_cpu([0.5e9, 1.0e9, 2.0e9, 4.0e9])
        r = np.full(4, 100e6)
        dl = 0.05 * np.sqrt(100)
        sol = solve_uplink(devs, r, S_BITS, FRAME, 100, dl, 10_000)
        assert np.all(np.diff(sol.batch) > 0)
        # linear in V_k: ratios of unclipped batches track freq ratios
        ratio = sol.batch[2] / sol.batch[1]
        assert ratio == pytest.approx(2.0, rel=0.35)

    def test_constraints_active(self):
        devs = fleet_cpu([1e9] * 5)
        r = rates(5, seed=3)
        dl = 0.05 * np.sqrt(50)
        sol = solve_uplink(devs, r, S_BITS, FRAME, 50, dl, 128)
        assert sol.tau.sum() == pytest.approx(FRAME, rel=1e-6)
        assert sol.batch.sum() == pytest.approx(50, rel=0.02)
        assert np.all(sol.batch >= 1 - 1e-9)
        assert np.all(sol.batch <= 128 + 1e-9)

    def test_closed_form_matches_paper_form(self):
        """Affine generalization reduces to the paper's Theorem 1 (a=0,
        b=1/V_k, rho' = training-priority ratio)."""
        devs = fleet_cpu([0.7e9, 1.4e9, 2.8e9])
        r = np.array([50e6, 80e6, 120e6])
        dl, e_up, mu, bmax = 0.4, 2.0, 1e-4, 512
        got = batch_closed_form(e_up, mu, devs, r, S_BITS, FRAME, dl, bmax)
        f = np.array([d.f_cpu for d in devs])
        V = f / devs[0].cycles_per_sample
        rho = f / f.sum()
        want = np.clip(
            (dl * e_up - np.sqrt(dl * S_BITS * FRAME * mu / (rho * r))) * V,
            1, bmax)
        np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_tau_closed_form_nonneg(self):
        devs = fleet_cpu([1e9, 2e9])
        r = np.array([50e6, 100e6])
        tau = tau_closed_form(5.0, 1e-6, devs, r, S_BITS, FRAME, 0.3, 128)
        assert np.all(tau >= 0)


class TestCorollary1:
    def test_bounds_bracket_solution(self):
        devs = fleet_cpu([0.7e9, 1.4e9, 2.1e9, 3.0e9])
        r = rates(4, seed=7)
        B = 80.0
        dl = 0.05 * np.sqrt(B)
        lo, hi = e_up_bounds(B, devs, r, S_BITS, FRAME, dl)
        sol = solve_uplink(devs, r, S_BITS, FRAME, B, dl, 128)
        assert lo <= sol.e_up * (1 + 1e-6)
        assert sol.e_up <= hi * (1 + 1e-6)


class TestTheorem2:
    def test_downlink_fills_frame_and_equalizes(self):
        devs = fleet_cpu([0.7e9, 1.4e9, 2.1e9])
        r = rates(3, seed=5)
        dl = 0.05 * np.sqrt(64)
        sol = solve_downlink(devs, r, S_BITS, FRAME, dl)
        assert sol.tau.sum() == pytest.approx(FRAME, rel=1e-6)
        t_down = uplink_latency(S_BITS, sol.tau, FRAME, r)
        t_upd = np.array([d.update_latency() for d in devs])
        finish = t_down + t_upd
        assert finish.std() / finish.mean() < 1e-6


class TestGpuScenario:
    def test_lemma2_compute_bound_region(self):
        """Optimal batchsize lies in the compute-bound region."""
        devs = [DeviceProfile(kind="gpu", gpu_t_low=0.02, gpu_slope=5e-4,
                              gpu_b_th=16 + 4 * i) for i in range(4)]
        r = rates(4, seed=11)
        sol = solve_period(devs, r, r, S_BITS, FRAME, FRAME, xi=0.05,
                           b_max=128)
        lo = np.array([d.gpu_b_th for d in devs])
        assert np.all(sol.batch >= lo - 1e-6)

    def test_gpu_latency_function_shape(self):
        d = DeviceProfile(kind="gpu", gpu_t_low=0.05, gpu_slope=1e-3,
                          gpu_b_th=32)
        b = np.arange(1, 129)
        t = d.local_grad_latency(b)
        assert np.all(t[:32] == 0.05)                 # data-bound: flat
        assert np.all(np.diff(t[32:]) > 0)            # compute-bound: rising
        assert t[31] == pytest.approx(0.05)           # continuous at B_th


class TestOptimality:
    def test_proposed_beats_baselines(self):
        """Table II / Figs 4-5 core claim: learning efficiency of the
        proposed policy dominates online/full/random."""
        devs = fleet_cpu([0.7e9] * 2 + [1.4e9] * 2 + [2.1e9] * 2)
        r_up, r_down = rates(6, seed=1), rates(6, seed=2)
        xi = 0.05
        effs = {}
        for name, pol in POLICIES.items():
            kw = {"rng": np.random.default_rng(0)}
            if name == "proposed":
                kw["xi"] = xi
            res = pol(devs, r_up, r_down, S_BITS, FRAME, FRAME, 128, **kw)
            effs[name] = xi * np.sqrt(res.global_batch) / res.latency
        assert effs["proposed"] >= max(v for k, v in effs.items()
                                       if k != "proposed") * 0.999


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    freqs=st.lists(st.floats(0.3e9, 5e9), min_size=2, max_size=8),
    b=st.floats(10, 400),
    seed=st.integers(0, 1000),
)
def test_uplink_properties(freqs, b, seed):
    devs = fleet_cpu(freqs)
    k = len(devs)
    r = rates(k, seed=seed)
    dl = 0.05 * np.sqrt(b)
    b = min(max(b, k), 128 * k)
    sol = solve_uplink(devs, r, S_BITS, FRAME, b, dl, 128)
    assert np.all(sol.batch >= 1 - 1e-9)
    assert np.all(sol.batch <= 128 + 1e-9)
    assert np.all(sol.tau >= -1e-12)
    assert sol.tau.sum() == pytest.approx(FRAME, rel=1e-5)
    # feasibility: uplink efficiency bound satisfied by every device
    t_local = np.array([d.local_grad_latency(x)
                        for d, x in zip(devs, sol.batch)])
    t_up = uplink_latency(S_BITS, sol.tau, FRAME, r)
    assert np.all(t_local + t_up <= dl * sol.e_up * (1 + 1e-4))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_period_solution_feasible(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 7))
    devs = fleet_cpu(rng.uniform(0.5e9, 3e9, size=k))
    r_up = rng.uniform(10e6, 300e6, size=k)
    r_down = rng.uniform(10e6, 300e6, size=k)
    sol = solve_period(devs, r_up, r_down, S_BITS, FRAME, FRAME,
                       xi=0.05, b_max=128)
    assert k <= sol.global_batch <= 128 * k
    assert sol.latency > 0 and np.isfinite(sol.latency)
    assert sol.efficiency > 0


# ---------------------------------------------------------------------------
# FleetRows property tests: padding invariance + masked bisection
# feasibility over random ragged fleets (the PR-4 bucket contract)
# ---------------------------------------------------------------------------

BMAX_ROWS = 128


def _rand_fleet(rng, k):
    devs = []
    for _ in range(k):
        if rng.integers(2):
            devs.append(DeviceProfile(kind="cpu",
                                      f_cpu=float(rng.uniform(0.3e9, 5e9))))
        else:
            devs.append(DeviceProfile(
                kind="gpu", gpu_t_low=float(rng.uniform(0.005, 0.05)),
                gpu_slope=float(rng.uniform(1e-4, 1e-3)),
                gpu_b_th=int(rng.integers(8, 33))))
    return tuple(devs)


def _rand_rows(rng, n_fleets):
    """Random ragged fleets + per-row rates/ξ/B drawn inside each row's
    feasible batch range."""
    sizes = [int(rng.integers(2, 7)) for _ in range(n_fleets)]
    fleets = [_rand_fleet(rng, k) for k in sizes]
    M, K = len(fleets), max(sizes)
    up = rng.uniform(10e6, 300e6, size=(M, K))
    down = rng.uniform(10e6, 300e6, size=(M, K))
    xi = rng.uniform(0.01, 0.2, size=M)
    B = np.array([rng.uniform(sum(d.batch_lo() for d in f),
                              BMAX_ROWS * len(f)) for f in fleets])
    return fleets, up, down, xi, B


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), extra=st.integers(1, 4),
       n_fleets=st.integers(1, 3))
def test_fleet_rows_padding_invariance(seed, extra, n_fleets):
    """Padding a FleetRows problem to ANY K' >= K is array_equal on every
    ledger (batch/τ/latency/efficiency): padded columns carry exactly
    zero batch and slot share and never perturb an active column's bits,
    whatever rate values ride in the masked columns."""
    rng = np.random.default_rng(seed)
    fleets, up, down, xi, B = _rand_rows(rng, n_fleets)
    M, K = up.shape
    Kp = K + extra

    def pad(r):
        # masked columns may carry any benign rate — it must not matter
        return np.concatenate(
            [r, rng.uniform(10e6, 300e6, size=(M, Kp - K))], axis=1)

    base = solve_period_rows(FleetRows.from_fleets(fleets, k_pad=K),
                             up, down, S_BITS, FRAME, FRAME, xi, B,
                             BMAX_ROWS)
    wide = solve_period_rows(FleetRows.from_fleets(fleets, k_pad=Kp),
                             pad(up), pad(down), S_BITS, FRAME, FRAME,
                             xi, B, BMAX_ROWS)
    for name in ("batch", "tau_up", "tau_down"):
        np.testing.assert_array_equal(base[name], wide[name][:, :K])
        assert np.all(wide[name][:, K:] == 0.0)
    np.testing.assert_array_equal(base["latency"], wide["latency"])
    np.testing.assert_array_equal(base["e_total"], wide["e_total"])


@settings(deadline=None)
@given(seed=st.integers(0, 10_000), extra=st.integers(0, 3))
def test_fleet_rows_bisection_feasibility(seed, extra):
    """Masked Algorithm-1/Theorem-2 rows stay feasible on random ragged
    fleets: batches within [lo, b_max] on active users and exactly zero
    on padded ones; slot shares non-negative, exactly zero on padded
    columns, summing to at most one frame."""
    rng = np.random.default_rng(seed)
    fleets, up, down, xi, B = _rand_rows(rng, int(rng.integers(1, 4)))
    M, K = up.shape
    Kp = K + extra
    up = np.concatenate([up, np.full((M, Kp - K), 1e8)], axis=1)
    down = np.concatenate([down, np.full((M, Kp - K), 1e8)], axis=1)
    fr = FleetRows.from_fleets(fleets, k_pad=Kp)
    sol = solve_period_rows(fr, up, down, S_BITS, FRAME, FRAME, xi, B,
                            BMAX_ROWS)
    act = fr.active
    assert np.all(sol["batch"][~act] == 0.0)
    assert np.all(sol["batch"][act] >= fr.lo[act] - 1e-9)
    assert np.all(sol["batch"][act] <= BMAX_ROWS + 1e-9)
    for name in ("tau_up", "tau_down"):
        tau = sol[name]
        assert np.all(tau[~act] == 0.0)
        assert np.all(tau >= -1e-15)
        assert np.all(np.isfinite(tau[act]))
        # allocated slot shares sum to <= 1 frame (== after normalization)
        assert np.all(tau.sum(axis=1) <= FRAME * (1 + 1e-6))
    assert np.all(np.isfinite(sol["latency"])) and np.all(
        sol["latency"] > 0)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_optimize_batch_rows_warm_start_feasible(seed):
    """The warm-started candidate grid stays inside the row's feasible
    range and returns a batch the full grid also contains."""
    rng = np.random.default_rng(seed)
    fleets, up, down, xi, _ = _rand_rows(rng, 2)
    fr = FleetRows.from_fleets(fleets)
    cold = optimize_batch_rows(fr, up, down, S_BITS, FRAME, FRAME, xi,
                               BMAX_ROWS)
    warm = optimize_batch_rows(fr, up, down, S_BITS, FRAME, FRAME, xi,
                               BMAX_ROWS, b_prev=cold, n_candidates=33)
    lo = np.array([sum(d.batch_lo() for d in f) for f in fleets])
    hi = np.array([BMAX_ROWS * len(f) for f in fleets])
    for b in (cold, warm):
        assert np.all(b >= lo - 1e-9) and np.all(b <= hi + 1e-9)
    # the warm grid brackets the cold optimum, so it must stay close
    assert np.all(warm >= cold / 2 - 1) and np.all(warm <= cold * 2 + 1)
